//! Fig. 7/8 standalone: the paper's 96-node gigabit testbed, ResNet-50
//! gradients, baseline vs importance-weighted pruning — prints node-0's
//! Networks-I/O trace as an ASCII strip chart.
//!
//! ```bash
//! cargo run --release --example bandwidth_trace -- --nodes 96 --steps 4
//! ```

use ringiwp::compress::Method;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::zoo;
use ringiwp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let nodes = args.usize_or("nodes", 96);
    let steps = args.usize_or("steps", 4);
    let seed = args.u64_or("seed", 42);

    for method in [Method::Baseline, Method::IwpFixed] {
        let cfg = SimCfg {
            nodes,
            method: method.spec(),
            seed,
            ..Default::default()
        };
        let mut engine = SimEngine::new(zoo::resnet50(), cfg);
        for s in 0..steps {
            engine.step(s);
        }
        let trace = engine.net().trace();
        let series = trace.kbps_series(0);
        let peak_all = series.iter().map(|&(_, v)| v).fold(0.0, f64::max);
        println!(
            "\n=== {} — node-0 I/O over {:.1} virtual seconds (peak {:.0} KB/s) ===",
            method.table_label(),
            engine.net().clock(),
            peak_all
        );
        // Strip chart: one row per bucket, scaled to the BASELINE peak so
        // the two plots are visually comparable like Fig 7 vs Fig 8.
        let gigabit_kbps = 117.0 * 1024.0;
        for &(t, v) in series.iter().take(60) {
            let frac = v / gigabit_kbps;
            let bar = "█".repeat((frac * 50.0).round() as usize);
            println!("{t:>6.2}s {v:>12.0} KB/s |{bar}");
        }
        println!(
            "mean {:.0} KB/s — {:.2}% of gigabit line rate",
            trace.mean_kbps(0),
            trace.mean_kbps(0) / gigabit_kbps * 100.0
        );
    }
    println!("\npaper: Fig 7 (baseline) rides the full-load line; Fig 8 (IWP) is a sparse trickle");
    Ok(())
}
