//! Section-II standalone: why DGC breaks on rings. Per-node top-1%
//! supports union as they travel; the shared-mask schedule doesn't.
//!
//! ```bash
//! cargo run --release --example dgc_density
//! ```

use ringiwp::net::{LinkSpec, RingNet};
use ringiwp::ring;
use ringiwp::sparse::BitMask;
use ringiwp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let len = 1_000_000;
    let d0 = 0.01;
    let k = (len as f64 * d0) as usize;

    println!("per-node top-{:.0}% supports on a {}-coordinate gradient\n", d0 * 100.0, len);
    println!("{:>6} {:>18} {:>18} {:>14}", "nodes", "DGC final density", "IWP final density", "model");
    for n in [4usize, 8, 16, 32, 64, 96] {
        let mut rng = Rng::new(7 + n as u64);
        // DGC: independent per-node supports.
        let supports: Vec<BitMask> = (0..n)
            .map(|_| {
                let mut m = BitMask::zeros(len);
                for _ in 0..k {
                    m.set(rng.below(len));
                }
                m
            })
            .collect();
        let mut net = RingNet::new(n, LinkSpec::gigabit_ethernet(), 1.0);
        let rep = ring::sparse::allreduce_support(&mut net, &supports);
        let dgc_final = *rep.density_per_hop.last().unwrap();

        // IWP: one shared mask at the same density — invariant by
        // construction; run it through the masked schedule to prove it.
        let shared = supports[0].clone();
        let mut net2 = RingNet::new(n, LinkSpec::gigabit_ethernet(), 1.0);
        let (mask, rep2) = ring::masked::allreduce_bytes_only(&mut net2, &[&shared]);
        let iwp_final = *rep2.density_per_hop.last().unwrap();
        assert_eq!(mask.count(), shared.count());

        println!(
            "{n:>6} {:>17.3}% {:>17.3}% {:>13.3}%",
            dgc_final * 100.0,
            iwp_final * 100.0,
            ring::sparse::expected_final_density(d0, n) * 100.0
        );
    }
    println!("\npaper (Sec. II): \"as the number of nodes increases, the gradient carried\nby the nodes will continue become denser\" — DGC loses the sparsity, IWP keeps it.");
    Ok(())
}
