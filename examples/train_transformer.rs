//! End-to-end validation driver (the brief's required example): train the
//! char-LM transformer for a few hundred steps on the embedded corpus
//! across a simulated ring with IWP compression, logging the loss curve.
//! The reference run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_transformer -- \
//!     --steps 300 --nodes 4 --method iwp-layerwise
//! ```
//!
//! All three layers are on the path: the PJRT train step executes the L2
//! JAX transformer HLO; the importance masks come from the L1 Pallas
//! kernel artifact; this binary is the L3 coordinator.

use ringiwp::compress::Method;
use ringiwp::config::Config;
use ringiwp::coordinator::Trainer;
use ringiwp::csv_row;
use ringiwp::metrics::CsvWriter;
use ringiwp::runtime::Runtime;
use ringiwp::util::cli::Args;
use ringiwp::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = Config {
        model: "tfm_tiny".into(),
        method: Method::IwpLayerwise.spec(),
        nodes: 4,
        steps: 300,
        lr: 0.08,        // stable for plain SGD + sparse updates at this scale
        threshold: 75.0, // early-training importance is O(1); see DESIGN.md
        steps_per_epoch: 75,
        ..Config::default()
    };
    let cfg = cfg.apply_args(&args)?;

    let rt = Runtime::cpu(&cfg.artifacts_dir)?;
    println!(
        "e2e transformer: {} steps, {} nodes, {}, lr={} thr={}",
        cfg.steps,
        cfg.nodes,
        cfg.method.table_label(),
        cfg.lr,
        cfg.threshold
    );
    let steps = cfg.steps;
    let out_dir = cfg.out_dir.clone();
    let mut trainer = Trainer::new(cfg, &rt)?;
    println!(
        "model: {} parameters across {} layers\n",
        trainer.layout().total_params(),
        trainer.layout().n_layers()
    );

    let t0 = std::time::Instant::now();
    let out = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!(" step   train_loss   (eval loss at checkpoints)");
    let evals: std::collections::BTreeMap<usize, f64> =
        out.evals.iter().map(|&(s, l, _)| (s, l)).collect();
    for &(s, l) in out.losses.iter().step_by((steps / 30).max(1)) {
        match evals.get(&s) {
            Some(el) => println!("{s:>5}   {l:>9.4}    eval {el:.4}"),
            None => println!("{s:>5}   {l:>9.4}"),
        }
    }

    let first = out.losses.first().map(|&(_, l)| l).unwrap_or(0.0);
    let last = out.losses.last().map(|&(_, l)| l).unwrap_or(0.0);
    println!("\ntrain loss: {first:.4} -> {last:.4} over {steps} steps");
    println!("final eval loss: {:.4}", out.final_eval_loss);
    println!(
        "compression: {:.1}x ({} wire vs {} dense), density {:.4}%",
        out.account.ratio(),
        human_bytes(out.account.total_wire_bytes() as f64),
        human_bytes(out.account.total_dense_bytes() as f64),
        out.account.mean_density() * 100.0
    );
    println!(
        "virtual net time: {:.2}s, peak node-0 I/O {:.0} KB/s",
        out.net_seconds, out.peak_kbps
    );
    println!("wall: {wall:.1}s ({:.2} s/step)", wall / steps as f64);

    std::fs::create_dir_all(&out_dir)?;
    let mut csv = CsvWriter::create(
        format!("{out_dir}/e2e_transformer_loss.csv"),
        &["step", "train_loss"],
    )?;
    for &(s, l) in &out.losses {
        csv_row!(csv, s, l)?;
    }
    csv.flush()?;
    println!("wrote {out_dir}/e2e_transformer_loss.csv");

    anyhow::ensure!(
        last < first * 0.8,
        "loss did not decrease enough ({first:.3} -> {last:.3})"
    );
    println!("E2E OK — all three layers composed");
    Ok(())
}
