//! Quickstart: train the MLP classifier on an 8-node simulated ring with
//! importance-weighted pruning, and print what the paper cares about —
//! the loss curve and the bandwidth saved.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ringiwp::compress::Method;
use ringiwp::config::Config;
use ringiwp::coordinator::Trainer;
use ringiwp::runtime::Runtime;
use ringiwp::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let cfg = Config {
        nodes: 8,
        model: "mlp".into(),
        method: Method::IwpLayerwise.spec(),
        steps: 60,
        seed: 42,
        ..Config::default()
    };

    let rt = Runtime::cpu(&cfg.artifacts_dir)?;
    println!(
        "quickstart: {} nodes, {}, model={} (PJRT: {})",
        cfg.nodes,
        cfg.method.table_label(),
        cfg.model,
        rt.platform()
    );

    let mut trainer = Trainer::new(cfg, &rt)?;
    let out = trainer.run()?;

    println!("\n step   train_loss");
    for &(s, l) in out.losses.iter().step_by(5) {
        let bar = "#".repeat((l * 12.0) as usize);
        println!("{s:>5}   {l:>8.4}  {bar}");
    }
    println!(
        "\nfinal eval accuracy: {:.3} (loss {:.4})",
        out.final_eval_acc, out.final_eval_loss
    );
    println!(
        "gradient compression ratio: {:.1}x — {} on the wire vs {} dense",
        out.account.ratio(),
        human_bytes(out.account.total_wire_bytes() as f64),
        human_bytes(out.account.total_dense_bytes() as f64),
    );
    println!(
        "mean transmitted density: {:.4}%",
        out.account.mean_density() * 100.0
    );
    Ok(())
}
