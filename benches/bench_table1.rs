//! End-to-end per-step cost of every registered compression pipeline on
//! the ResNet50 inventory (the workload the paper's evaluation runs) —
//! one bench per paper table row family plus the two new stage
//! compositions (DESIGN.md §12), and the Fig. 7/8 trace workload.

use ringiwp::exp::bench::step_specs;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::zoo;
use ringiwp::util::timer::bench;

fn main() {
    println!("bench_table1 — SimEngine step time per pipeline (ResNet50, 16-node ring)\n");
    for method in step_specs() {
        let cfg = SimCfg {
            nodes: 16,
            method,
            seed: 5,
            ..Default::default()
        };
        let mut engine = SimEngine::new(zoo::resnet50(), cfg);
        let mut step = 0usize;
        let stats = bench(1, 3, || {
            std::hint::black_box(engine.step(step));
            step += 1;
        });
        println!(
            "{}  ratio so far {:.1}x",
            stats.row(&format!("step/{}", method.name())),
            engine.account.ratio()
        );
    }
    println!("\n(bench_table1 done)");
}
