//! Ring-transport benchmarks (criterion is unreachable offline; this is
//! a `harness = false` bench using `util::timer`).
//!
//! Covers the transport behind Figs. 7/8 and Table I: dense vs masked vs
//! sparse schedules across ring sizes and payloads, plus the support-only
//! fast path the 96-node sims rely on.

use ringiwp::net::{LinkSpec, PipeInner, RingNet, TopoKind, Topology};
use ringiwp::ring;
use ringiwp::ring::{Arena, Executor};
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::rng::Rng;
use ringiwp::util::timer::bench;

fn net(n: usize) -> RingNet {
    RingNet::new(n, LinkSpec::gigabit_ethernet(), 1.0)
}

fn main() {
    println!("bench_ring — ring all-reduce schedules\n");
    let mut rng = Rng::new(42);

    for (nodes, len) in [(4usize, 1 << 16), (8, 1 << 18), (16, 1 << 20)] {
        let base: Vec<Vec<f32>> = (0..nodes)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();

        let stats = bench(2, 8, || {
            let mut nw = net(nodes);
            let mut bufs = base.clone();
            std::hint::black_box(ring::dense::allreduce(&mut nw, &mut bufs));
        });
        println!(
            "{}",
            stats.row(&format!("dense_allreduce n={nodes} len={len}"))
        );
        println!(
            "    -> {:.2} Melem/s reduced",
            stats.per_sec(len as f64) / 1e6
        );

        // Masked (IWP) at 1% density.
        let mut mask = BitMask::zeros(len);
        for _ in 0..len / 100 {
            mask.set(rng.below(len));
        }
        let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
        let stats = bench(2, 8, || {
            let mut nw = net(nodes);
            std::hint::black_box(ring::masked::allreduce(&mut nw, &[&mask], &refs));
        });
        println!(
            "{}",
            stats.row(&format!("masked_allreduce n={nodes} len={len} d=1%"))
        );

        // Sparse (DGC) at 1% density.
        let sparses: Vec<SparseVec> = base
            .iter()
            .map(|v| SparseVec::top_k(v, len / 100))
            .collect();
        let stats = bench(1, 5, || {
            let mut nw = net(nodes);
            std::hint::black_box(ring::sparse::allreduce(&mut nw, &sparses));
        });
        println!(
            "{}",
            stats.row(&format!("sparse_allreduce n={nodes} len={len} d=1%"))
        );
        println!();
    }

    // Persistent staging arena vs per-call scratch (DESIGN.md §9): same
    // schedule, same inputs — the only difference is buffer reuse across
    // calls, i.e. the steady-state behaviour of SimEngine/Trainer.
    println!("== staging arena reuse (sparse 1%, per-call vs persistent) ==");
    let exec = Executor::sequential();
    for (nodes, len) in [(8usize, 1 << 18), (16, 1 << 18)] {
        let base: Vec<Vec<f32>> = (0..nodes)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let sparses: Vec<SparseVec> = base
            .iter()
            .map(|v| SparseVec::top_k(v, len / 100))
            .collect();
        let stats = bench(1, 5, || {
            let mut nw = net(nodes);
            std::hint::black_box(ring::sparse::allreduce_exec(&mut nw, &sparses, &exec));
        });
        println!(
            "{}",
            stats.row(&format!("sparse per-call scratch n={nodes} len={len}"))
        );
        let fresh_median = stats.median_ns;
        let mut arena = Arena::for_nodes(nodes);
        let stats = bench(1, 5, || {
            let mut nw = net(nodes);
            std::hint::black_box(ring::sparse::allreduce_in(&mut nw, &sparses, &exec, &mut arena));
        });
        println!(
            "{}",
            stats.row(&format!("sparse persistent arena n={nodes} len={len}"))
        );
        println!(
            "    -> {:.2}x vs per-call scratch, {} arena grows total",
            fresh_median / stats.median_ns,
            arena.grows()
        );
    }
    println!();

    // Support-only fast path at paper scale.
    for nodes in [32usize, 96] {
        let len = 25_557_032; // ResNet50
        let mut supports = Vec::with_capacity(nodes);
        for _ in 0..nodes {
            let mut m = BitMask::zeros(len);
            for _ in 0..len / 100 {
                m.set(rng.below(len));
            }
            supports.push(m);
        }
        let stats = bench(1, 3, || {
            let mut nw = net(nodes);
            std::hint::black_box(ring::sparse::allreduce_support(&mut nw, &supports));
        });
        println!(
            "{}",
            stats.row(&format!("support_allreduce n={nodes} len=25.6M d=1%"))
        );
    }
    println!();

    // Topology sweep (DESIGN.md §10-§11): the same dense reduce over
    // the flat ring, a group-4 hierarchy, the binomial tree, and the
    // 4-chunk pipelined flat ring — wall clock here, virtual wire time
    // in BENCH_ring.json.
    println!("== dense allreduce per topology ==");
    let exec = Executor::sequential();
    for (nodes, len) in [(8usize, 1 << 18), (16, 1 << 18)] {
        let base: Vec<Vec<f32>> = (0..nodes)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        for kind in [
            TopoKind::Flat,
            TopoKind::Hier { group: 4 },
            TopoKind::Tree,
            TopoKind::Pipeline {
                chunks: 4,
                inner: PipeInner::Flat,
            },
        ] {
            let topo = kind.build(nodes);
            let mut arena = Arena::for_nodes(nodes);
            let mut work = base.clone();
            let mut virtual_s = 0.0;
            // Restore the preallocated work buffers per sample (a
            // memcpy, no allocation) so the row times the schedule, not
            // a multi-MB clone.
            let stats = bench(1, 5, || {
                for (w, b) in work.iter_mut().zip(&base) {
                    w.copy_from_slice(b);
                }
                let mut nw = net(nodes);
                let rep =
                    std::hint::black_box(topo.dense(&mut nw, &mut work, &exec, &mut arena));
                virtual_s = rep.seconds;
            });
            println!(
                "{}",
                stats.row(&format!(
                    "dense topo={} n={nodes} len={len}",
                    kind.name()
                ))
            );
            println!("    -> {virtual_s:.6} virtual wire seconds");
        }
    }
    println!("\n(bench_ring done)");
}
