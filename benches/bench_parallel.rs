//! Node-parallel executor speedups (DESIGN.md §4, EXPERIMENTS.md §5):
//! the full `SimEngine` IWP step over the paper's real AlexNet/ResNet50
//! inventories, swept across worker counts and ring sizes, plus the
//! dense-schedule transport in isolation. `harness = false` (criterion
//! is unreachable offline; `util::timer` provides the stats).
//!
//! The headline row is ResNet50 @ 4 workers: the per-node work
//! (synthetic gradient fill, residual accumulation, broadcaster
//! scoring, momentum masking) fans out per node/broadcaster, so the
//! step should run ≥2x faster than the sequential oracle on a 4-core
//! machine. Results are bit-identical at every width — the equivalence
//! tests enforce that; this bench only measures time.

use ringiwp::compress::Method;
use ringiwp::exp::simrun::{SimCfg, SimEngine};
use ringiwp::model::zoo;
use ringiwp::net::LinkSpec;
use ringiwp::ring;
use ringiwp::ring::Executor;
use ringiwp::util::rng::Rng;
use ringiwp::util::timer::{bench, fmt_ns};

const WORKERS: [usize; 4] = [1, 2, 4, 8];

fn sim_step_median_ns(layout_name: &str, nodes: usize, workers: usize) -> f64 {
    let layout = zoo::by_name(layout_name).expect("zoo layout");
    let cfg = SimCfg {
        nodes,
        method: Method::IwpFixed.spec(),
        link: LinkSpec::gigabit_ethernet(),
        parallelism: workers,
        seed: 42,
        ..Default::default()
    };
    let mut engine = SimEngine::new(layout, cfg);
    let mut step = 0usize;
    let stats = bench(1, 3, || {
        std::hint::black_box(engine.step(step));
        step += 1;
    });
    stats.median_ns
}

fn main() {
    println!("bench_parallel — node-parallel execution engine\n");

    // ---- SimEngine IWP step over the real inventories ----------------
    for (layout_name, label) in [("alexnet", "AlexNet 61.1M"), ("resnet50", "ResNet50 25.6M")] {
        println!("== {label} — IWP sim step (median of 3) ==");
        println!(
            "{:>6} {:>10} {:>10} {:>10} {:>10}   speedup vs 1 worker",
            "nodes", "w=1", "w=2", "w=4", "w=8"
        );
        for nodes in [4usize, 16, 96] {
            let medians: Vec<f64> = WORKERS
                .iter()
                .map(|&w| sim_step_median_ns(layout_name, nodes, w))
                .collect();
            let speedups: Vec<String> = medians
                .iter()
                .map(|&m| format!("{:.2}x", medians[0] / m))
                .collect();
            println!(
                "{:>6} {:>10} {:>10} {:>10} {:>10}   [{}]",
                nodes,
                fmt_ns(medians[0]),
                fmt_ns(medians[1]),
                fmt_ns(medians[2]),
                fmt_ns(medians[3]),
                speedups.join(" ")
            );
        }
        println!();
    }

    // ---- Dense ring transport in isolation ---------------------------
    println!("== dense ring all-reduce (1M f32, median of 5) ==");
    let len = 1 << 20;
    let mut rng = Rng::new(7);
    for nodes in [4usize, 8, 16] {
        let base: Vec<Vec<f32>> = (0..nodes)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let mut row = format!("{nodes:>6}");
        let mut baseline = 0.0f64;
        for &w in &WORKERS {
            let exec = Executor::new(w);
            let stats = bench(1, 5, || {
                let mut net =
                    ringiwp::net::RingNet::new(nodes, LinkSpec::gigabit_ethernet(), 1.0);
                let mut bufs = base.clone();
                std::hint::black_box(ring::dense::allreduce_exec(&mut net, &mut bufs, &exec));
            });
            if w == 1 {
                baseline = stats.median_ns;
            }
            row.push_str(&format!(
                " {:>10} ({:.2}x)",
                fmt_ns(stats.median_ns),
                baseline / stats.median_ns
            ));
        }
        println!("{row}");
    }

    println!("\n(bench_parallel done — widths sweep {WORKERS:?}; equivalence is enforced by tests/parallel_equivalence.rs)");
}
