//! PJRT hot-path latencies: the L2 train-step executions and the L1
//! importance-kernel calls as the Rust coordinator drives them. Skips
//! gracefully when artifacts are missing.

use ringiwp::data::SynthClassification;
use ringiwp::runtime::{ImportanceKernel, Runtime};
use ringiwp::util::rng::Rng;
use ringiwp::util::timer::bench;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = match Runtime::cpu(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP bench_step: {e}");
            return Ok(());
        }
    };
    println!("bench_step — PJRT latencies (platform: {})\n", rt.platform());

    // MLP train step.
    let art = rt.load("train_step_mlp_b32")?;
    let layout = art.meta.layout()?;
    let mut rng = Rng::new(1);
    let params: Vec<Vec<f32>> = layout
        .layers()
        .iter()
        .map(|l| {
            let mut p = vec![0.0f32; l.size];
            rng.fill_normal(&mut p, 0.0, 0.05);
            p
        })
        .collect();
    let data = SynthClassification::cifar_like(2);
    let (x, y) = data.batch(&mut rng, 32);
    let stats = bench(3, 15, || {
        let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        inputs.push(&x);
        inputs.push(&y);
        std::hint::black_box(art.run_f32(&inputs).unwrap());
    });
    println!("{}", stats.row("mlp train_step (B=32, 820k params)"));

    // Transformer train step.
    let art = rt.load("train_step_tfm_tiny_b8")?;
    let layout = art.meta.layout()?;
    let params: Vec<Vec<f32>> = layout
        .layers()
        .iter()
        .map(|l| {
            let mut p = vec![0.0f32; l.size];
            rng.fill_normal(&mut p, 0.0, 0.02);
            p
        })
        .collect();
    let corpus = ringiwp::data::CharCorpus::tiny();
    let tokens = corpus.batch(&mut rng, 8, 64);
    let stats = bench(2, 10, || {
        let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
        inputs.push(&tokens);
        std::hint::black_box(art.run_f32(&inputs).unwrap());
    });
    println!("{}", stats.row("tfm train_step (B=8, 430k params)"));

    // Importance kernel across buffer sizes (incl. padded-tail path).
    let mut kernel = ImportanceKernel::load(&rt)?;
    for len in [8192usize, 65_536, 786_432, 1_000_000] {
        let mut g = vec![0.0f32; len];
        let mut w = vec![0.0f32; len];
        rng.fill_normal(&mut g, 0.0, 1e-4);
        rng.fill_normal(&mut w, 0.0, 0.05);
        let u = vec![1.0f32; len];
        let stats = bench(2, 10, || {
            std::hint::black_box(kernel.score(&g, &w, &u, 0.01, 1e-8).unwrap());
        });
        println!(
            "{}  ({:.0} Mcoord/s)",
            stats.row(&format!("importance kernel len={len}")),
            stats.per_sec(len as f64) / 1e6
        );
    }
    println!("\n(bench_step done)");
    Ok(())
}
