//! Compression hot-path benchmarks: importance scoring (the CPU mirror
//! of the L1 kernel), mask packing/OR, top-k selection, TernGrad
//! encoding, residual accumulation — the per-step L3 costs that must
//! stay far below the PJRT train-step time (DESIGN.md §8).

use ringiwp::compress::fuse;
use ringiwp::compress::importance::{score_and_mask, LayerStats, EPS};
use ringiwp::compress::quant::{QBlob, QuantWidth};
use ringiwp::compress::residual::ResidualStore;
use ringiwp::compress::select;
use ringiwp::compress::terngrad::TernGrad;
use ringiwp::model::{LayerKind, ParamLayout};
use ringiwp::sparse::{BitMask, SparseVec};
use ringiwp::util::rng::Rng;
use ringiwp::util::timer::bench;

fn main() {
    println!("bench_compress — per-coordinate hot paths\n");
    let mut rng = Rng::new(7);
    let len = 1 << 21; // 2M coords ~ one large layer

    let mut g = vec![0.0f32; len];
    let mut w = vec![0.0f32; len];
    rng.fill_normal(&mut g, 0.0, 1e-4);
    rng.fill_normal(&mut w, 0.0, 0.05);
    let u = vec![1.0f32; len];
    let mut imp = vec![0.0f32; len];

    let stats = bench(3, 10, || {
        let mut mask = BitMask::zeros(len);
        std::hint::black_box(score_and_mask(&g, &w, &u, 0.01, EPS, &mut imp, &mut mask));
    });
    println!("{}", stats.row("score_and_mask 2M coords"));
    println!(
        "    -> {:.0} Mcoord/s ({:.2} GB/s read)",
        stats.per_sec(len as f64) / 1e6,
        stats.per_sec(len as f64) * 12.0 / 1e9
    );

    let mask = BitMask::from_threshold(&imp, 0.01);
    let stats = bench(3, 20, || {
        let mut m2 = mask.clone();
        m2.or_assign(std::hint::black_box(&mask));
        std::hint::black_box(m2.count());
    });
    println!("{}", stats.row("mask OR + popcount 2M bits"));

    let stats = bench(3, 10, || {
        std::hint::black_box(mask.encode_u8());
    });
    println!("{}", stats.row("mask encode_u8 2M bits"));

    let stats = bench(2, 8, || {
        std::hint::black_box(SparseVec::top_k(&g, len / 100));
    });
    println!("{}", stats.row("top_k 1% of 2M (DGC select)"));

    let stats = bench(2, 8, || {
        std::hint::black_box(SparseVec::from_mask(&g, &mask));
    });
    println!("{}", stats.row("sparse gather from mask"));

    let layout = ParamLayout::new(
        "bench",
        vec![("big".into(), vec![len], LayerKind::Conv)],
    );
    let stats = bench(1, 5, || {
        let mut r = Rng::new(3);
        std::hint::black_box(TernGrad::encode(&g, &layout, &mut r));
    });
    println!("{}", stats.row("terngrad encode 2M coords"));

    let mut store = ResidualStore::new(len, 0.9);
    let stats = bench(2, 10, || {
        store.accumulate(std::hint::black_box(&g));
    });
    println!("{}", stats.row("residual accumulate 2M coords"));

    // The fused one-pass IWP kernel vs the multi-pass chain it replaces
    // (DESIGN.md §11): same math, one memory sweep instead of three.
    println!("\n== fused vs multi-pass IWP step (2M coords) ==");
    for random_select in [false, true] {
        let label = if random_select { "random" } else { "hard" };
        let thrs = vec![0.01f32; layout.n_layers()];
        let mut m_store = ResidualStore::new(len, 0.9);
        let mut m_rng = Rng::new(11);
        let mut m_u = vec![1.0f32; len];
        let stats = bench(2, 8, || {
            m_store.accumulate(std::hint::black_box(&g));
            select::fill_u(&mut m_rng, random_select, &mut m_u);
            let mut mask = BitMask::zeros(len);
            std::hint::black_box(score_and_mask(
                m_store.pending(),
                &w,
                &m_u,
                thrs[0],
                EPS,
                &mut imp,
                &mut mask,
            ));
        });
        println!("{}", stats.row(&format!("multipass chain ({label})")));

        let mut f_store = ResidualStore::new(len, 0.9);
        let mut f_rng = Rng::new(11);
        let mut f_mask = BitMask::zeros(len);
        let mut f_stats: Vec<LayerStats> = Vec::new();
        let stats = bench(2, 8, || {
            fuse::score_select_compact(
                &layout,
                &thrs,
                &w,
                std::hint::black_box(&g),
                EPS,
                random_select,
                &mut f_rng,
                &mut f_store,
                &mut f_mask,
                &mut f_stats,
            );
        });
        println!("{}", stats.row(&format!("fuse::score_select_compact ({label})")));
        println!(
            "    -> {:.0} Mcoord/s",
            stats.per_sec(len as f64) / 1e6
        );
    }

    // The word-wise post-wire kernel: support walk via trailing_zeros
    // instead of the per-bit iterator (DESIGN.md §11, §17).
    let mut t_store = ResidualStore::new(len, 0.9);
    t_store.accumulate(&g);
    let mut compacted: Vec<f32> = Vec::with_capacity(mask.count());
    let stats = bench(2, 10, || {
        t_store.accumulate(std::hint::black_box(&g));
        std::hint::black_box(fuse::take_compact(&mut t_store, &mask, &mut compacted));
    });
    println!("{}", stats.row("take_compact 2M coords (1% support)"));

    // The +q:<bits> payload codecs over a compacted 1%-support payload
    // (DESIGN.md §17): blocked two-phase stochastic rounding for the
    // k-bit widths, scalar float conversion for bf16/f16.
    println!("\n== QBlob encode/decode ({} compacted values) ==", compacted.len());
    let nnz = compacted.len() as f64;
    for width in QuantWidth::ALL {
        let stats = bench(2, 10, || {
            let mut r = Rng::new(5);
            std::hint::black_box(QBlob::encode(
                std::hint::black_box(&compacted),
                width,
                &mut r,
            ));
        });
        println!("{}", stats.row(&format!("qblob encode {width}")));
        println!("    -> {:.0} Mval/s", stats.per_sec(nnz) / 1e6);
        let blob = {
            let mut r = Rng::new(5);
            QBlob::encode(&compacted, width, &mut r)
        };
        let mut acc = vec![0.0f32; compacted.len()];
        let stats = bench(2, 10, || {
            blob.add_decoded_into(std::hint::black_box(&mut acc));
        });
        println!("{}", stats.row(&format!("qblob decode+add {width}")));
    }

    println!("\n(bench_compress done)");
}
