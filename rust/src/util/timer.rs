//! Wall-clock timing helpers for the bench harness (criterion is
//! unreachable offline; `benches/*` use these with `harness = false`).

use std::time::Instant;

/// Measure `f` repeatedly: warmup runs, then `iters` timed runs.
/// Returns per-iteration stats in nanoseconds.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchStats::from_samples(samples)
}

/// Per-iteration timing statistics in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Timed iterations.
    pub iters: usize,
    /// Mean ns/iteration.
    pub mean_ns: f64,
    /// Median ns/iteration.
    pub median_ns: f64,
    /// Fastest iteration.
    pub min_ns: f64,
    /// Slowest iteration.
    pub max_ns: f64,
    /// Population std of the samples.
    pub std_ns: f64,
}

impl BenchStats {
    /// Compute stats from raw per-iteration samples (ns).
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        BenchStats {
            iters: samples.len(),
            mean_ns: mean,
            median_ns: samples[samples.len() / 2],
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
            std_ns: var.sqrt(),
        }
    }

    /// `name  median  mean ±std  min..max` row, auto-scaled units.
    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<44} {:>12}  {:>12} ±{:<10} [{} .. {}]  n={}",
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.max_ns),
            self.iters
        )
    }

    /// Throughput helper: items processed per second at the median.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns / 1e9)
    }
}

/// Render nanoseconds with auto-scaled units (`1.50 µs`, `2.50 ms`).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = BenchStats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.mean_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
    }

    #[test]
    fn bench_runs_and_measures() {
        let mut count = 0u64;
        let stats = bench(2, 10, || {
            count += 1;
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(count, 12);
        assert!(stats.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
