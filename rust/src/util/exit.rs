//! Typed process exit classes (DESIGN.md §16).
//!
//! `ringiwp serve` and `ringiwp chaos` are run by CI scripts and
//! operators who triage failures from the exit *code*, not the log
//! text. An [`ExitClass`] rides an `anyhow` error chain as context
//! (`err.context(ExitClass::Config)`) and `main` maps it to a stable
//! code:
//!
//! | code | class                  | typical cause                       |
//! |------|------------------------|-------------------------------------|
//! | 0    | —                      | success                             |
//! | 1    | unclassified           | anything untagged                   |
//! | 2    | [`ExitClass::Config`]    | bad flag / grammar / plan         |
//! | 3    | [`ExitClass::Transport`] | socket, frame, or recovery failure (includes exhausted wire-fault retries) |
//! | 4    | [`ExitClass::Invariant`] | a recovery/accounting invariant broke |
//!
//! A bare [`crate::net::WireError`] in the chain (without an explicit
//! class) also maps to 3 — the transport taxonomy lives in one place.

use std::fmt;

/// Failure class carried as `anyhow` context; see the module table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitClass {
    /// Malformed flags, config keys, or schedule grammar (exit 2).
    Config,
    /// Socket, frame, or recovery failure — including an unrecoverable
    /// wire-fault schedule exhausting its retry budget (exit 3).
    Transport,
    /// A recovery or accounting invariant was violated (exit 4).
    Invariant,
}

impl ExitClass {
    /// The process exit code this class maps to.
    pub fn code(self) -> i32 {
        match self {
            ExitClass::Config => 2,
            ExitClass::Transport => 3,
            ExitClass::Invariant => 4,
        }
    }

    /// Stable lowercase name (printed next to the error).
    pub fn name(self) -> &'static str {
        match self {
            ExitClass::Config => "config",
            ExitClass::Transport => "transport",
            ExitClass::Invariant => "invariant",
        }
    }

    /// Classify an `anyhow` error: an explicit [`ExitClass`] context
    /// wins; otherwise a [`crate::net::WireError`] anywhere in the
    /// chain means transport; anything else is unclassified (`None`,
    /// exit 1).
    pub fn of(err: &anyhow::Error) -> Option<ExitClass> {
        if let Some(c) = err.downcast_ref::<ExitClass>() {
            return Some(*c);
        }
        if err
            .chain()
            .any(|c| c.downcast_ref::<crate::net::WireError>().is_some())
        {
            return Some(ExitClass::Transport);
        }
        None
    }
}

impl fmt::Display for ExitClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} failure (exit {})", self.name(), self.code())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::WireError;

    #[test]
    fn codes_are_stable() {
        assert_eq!(ExitClass::Config.code(), 2);
        assert_eq!(ExitClass::Transport.code(), 3);
        assert_eq!(ExitClass::Invariant.code(), 4);
        assert_eq!(format!("{}", ExitClass::Config), "config failure (exit 2)");
    }

    #[test]
    fn explicit_class_wins_over_chain_scan() {
        let err = anyhow::Error::from(WireError::BadMagic).context(ExitClass::Invariant);
        assert_eq!(ExitClass::of(&err), Some(ExitClass::Invariant));
    }

    #[test]
    fn bare_wire_errors_classify_as_transport() {
        let err = anyhow::Error::from(WireError::Exhausted { attempts: 4 })
            .context("step 3 failed");
        assert_eq!(ExitClass::of(&err), Some(ExitClass::Transport));
    }

    #[test]
    fn untagged_errors_stay_unclassified() {
        let err = anyhow::anyhow!("some other failure");
        assert_eq!(ExitClass::of(&err), None);
    }
}
