//! Streaming statistics + histograms.
//!
//! The layerwise threshold controller (Eq. 4) consumes mean/var of the
//! per-layer importance distribution; Figs. 2–4 are histograms and
//! var/mean time-series over these same statistics.

/// Welford accumulator — single pass, numerically stable mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one observation in.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Fold a whole f32 slice in.
    pub fn extend(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Smallest observation (+∞ when empty).
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest observation (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// var/mean — the dispersion factor of the Eq. 4 controller.
    pub fn var_over_mean(&self) -> f64 {
        if self.mean.abs() < 1e-30 {
            0.0
        } else {
            self.var() / self.mean
        }
    }
}

/// Merge two sets of moment sums (sum, sumsq, n) into (mean, var).
/// This is how the kernel's per-layer stats [ΣI, ΣI², n_sel, n] become
/// the controller inputs without a second pass.
pub fn mean_var_from_sums(sum: f64, sumsq: f64, n: f64) -> (f64, f64) {
    if n <= 0.0 {
        return (0.0, 0.0);
    }
    let mean = sum / n;
    let var = (sumsq / n - mean * mean).max(0.0);
    (mean, var)
}

/// Fixed-bin histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
    /// Equal-width bin counts over [lo, hi).
    pub bins: Vec<u64>,
    /// Count of observations below `lo`.
    pub under: u64,
    /// Count of observations at/above `hi`.
    pub over: u64,
}

impl Histogram {
    /// Equal-width histogram over [lo, hi) with `n_bins` bins.
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; n_bins],
            under: 0,
            over: 0,
        }
    }

    /// Log-scale histogram helper for importance values spanning decades
    /// (Fig. 2/3 plot log-spaced importance distributions).
    pub fn log10(lo_exp: i32, hi_exp: i32, bins_per_decade: usize) -> Self {
        let n = ((hi_exp - lo_exp) as usize) * bins_per_decade;
        Histogram::new(lo_exp as f64, hi_exp as f64, n)
    }

    /// Bin one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    /// Bin `log10(x)`; non-positive values count as underflow.
    pub fn push_log10(&mut self, x: f64) {
        if x > 0.0 {
            self.push(x.log10());
        } else {
            self.under += 1;
        }
    }

    /// Total observations including under/overflow.
    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    /// (bin_center, count) rows for CSV export.
    pub fn rows(&self) -> Vec<(f64, u64)> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * w, c))
            .collect()
    }
}

/// Exact percentile on a scratch copy (fine at experiment scale).
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty() && (0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() - 1) as f64).floor() as usize;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_known_values() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.var() - 4.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn sums_match_welford() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() + 2.0).collect();
        let mut w = Welford::new();
        let (mut s, mut s2) = (0.0, 0.0);
        for &x in &xs {
            w.push(x);
            s += x;
            s2 += x * x;
        }
        let (mean, var) = mean_var_from_sums(s, s2, xs.len() as f64);
        assert!((mean - w.mean()).abs() < 1e-9);
        assert!((var - w.var()).abs() < 1e-9);
    }

    #[test]
    fn var_over_mean_guards_zero() {
        let w = Welford::new();
        assert_eq!(w.var_over_mean(), 0.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(42.0);
        assert!(h.bins.iter().all(|&c| c == 1));
        assert_eq!(h.under, 1);
        assert_eq!(h.over, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn log_histogram() {
        let mut h = Histogram::log10(-6, 0, 10);
        h.push_log10(1e-3); // -3 -> in range
        h.push_log10(0.0); // underflow
        assert_eq!(h.under, 1);
        assert_eq!(h.total(), 2);
    }

    #[test]
    fn percentile_basics() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
    }
}
