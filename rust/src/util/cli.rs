//! Tiny CLI argument substrate (clap is unreachable offline).
//!
//! Grammar: `ringiwp <subcommand> [--flag value] [--switch] [positional…]`.
//! Typed getters with defaults; unknown-flag detection; auto-generated
//! usage text assembled by `main.rs`.

use std::collections::BTreeMap;

/// Parsed command line (subcommand + flags + switches + positionals).
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare token (`train`, `exp`, …).
    pub subcommand: Option<String>,
    /// Remaining bare tokens after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Flags the program actually queried — used to report unknown flags.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--k=v`, `--k v`, or boolean `--k`.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// `--key value` if present.
    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    /// `--key value` or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    /// Integer flag with default; panics with a usage message on junk.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")),
            None => default,
        }
    }

    /// Float flag with default; panics with a usage message on junk.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects a number, got `{v}`")),
            None => default,
        }
    }

    /// u64 flag with default (seeds); panics on junk.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        match self.flags.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")),
            None => default,
        }
    }

    /// Boolean `--key` switch presence.
    pub fn switch(&self, key: &str) -> bool {
        self.mark(key);
        self.switches.iter().any(|s| s == key)
    }

    /// Flags/switches present on the command line but never queried.
    pub fn unknown(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        // NOTE the grammar: `--flag token` binds the token as the flag's
        // value, so boolean switches must come last or use `--flag=`.
        let a = args("train extra --nodes 8 --thr 0.01 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("nodes", 1), 8);
        assert!((a.f64_or("thr", 0.0) - 0.01).abs() < 1e-12);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = args("exp --id=table1 --steps=50");
        assert_eq!(a.str_or("id", ""), "table1");
        assert_eq!(a.usize_or("steps", 0), 50);
    }

    #[test]
    fn defaults_apply() {
        let a = args("train");
        assert_eq!(a.usize_or("nodes", 4), 4);
        assert_eq!(a.str_or("model", "mlp"), "mlp");
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn trailing_switch() {
        let a = args("bench --quick");
        assert!(a.switch("quick"));
    }

    #[test]
    fn unknown_flag_reporting() {
        let a = args("train --nodes 4 --oops 1");
        let _ = a.usize_or("nodes", 1);
        assert_eq!(a.unknown(), vec!["oops".to_string()]);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        let a = args("x --n abc");
        // `--n abc` parses as flag n=abc; getter panics on parse.
        let _ = a.usize_or("n", 0);
    }
}
