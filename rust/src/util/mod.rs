//! Substrate utilities built in-tree (the offline registry has no serde /
//! clap / rand / proptest — DESIGN.md §2 records the substitution).

pub mod cli;
pub mod exit;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

/// Number of elements implied by a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Human-readable byte count (`12.3 MiB`).
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_basics() {
        assert_eq!(numel(&[2, 3, 4]), 24);
        assert_eq!(numel(&[]), 1); // scalar
        assert_eq!(numel(&[0, 5]), 0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512.00 B");
        assert_eq!(human_bytes(2048.0), "2.00 KiB");
        assert_eq!(human_bytes(3.5 * 1024.0 * 1024.0), "3.50 MiB");
    }
}
