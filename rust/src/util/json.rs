//! Minimal JSON substrate (serde is unreachable offline — DESIGN.md §2).
//!
//! Covers exactly what the repo needs: parsing artifact manifests written
//! by `python/compile/aot.py` and serializing experiment results. Full
//! JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null); numbers are held as f64 (manifest ints are < 2^53, lossless).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (held as f64; manifest ints are < 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What was expected/found.
    pub msg: String,
}

impl Json {
    // ---- accessors --------------------------------------------------

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Bool payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object view, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Required-field helpers (anyhow context for manifest loading).
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/non-string field `{key}`"))
    }

    /// Required numeric field (anyhow context for manifest loading).
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("missing/non-numeric field `{key}`"))
    }

    /// Required array field (anyhow context for manifest loading).
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/non-array field `{key}`"))
    }

    // ---- construction helpers ---------------------------------------

    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Numeric array.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// String array.
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

// ---- parser ----------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError {
            pos: self.pos,
            msg: msg.into(),
        })
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected `{}`", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte `{}`", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected `{word}`"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            match ch {
                                Some(c) => out.push(c),
                                None => return self.err("invalid \\u escape"),
                            }
                            continue; // hex4 advanced pos already
                        }
                        _ => return self.err("invalid escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (manifests are ASCII, but be correct).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| JsonError {
                            pos: self.pos,
                            msg: "invalid utf-8".into(),
                        })?
                        .chars()
                        .next()
                        .unwrap();
                    out.push(s);
                    self.pos += s.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or(JsonError {
                pos: self.pos,
                msg: "eof in \\u".into(),
            })?;
            let d = (c as char).to_digit(16).ok_or(JsonError {
                pos: self.pos,
                msg: "bad hex digit".into(),
            })?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| JsonError {
                pos: start,
                msg: format!("bad number `{s}`: {e}"),
            })
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

// ---- writer ----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
 "name": "importance_m8192",
 "m": 8192,
 "inputs": [{"name": "g", "shape": [8192], "dtype": "float32"}]
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "importance_m8192");
        assert_eq!(v.req_usize("m").unwrap(), 8192);
        let inputs = v.req_arr("inputs").unwrap();
        assert_eq!(inputs[0].get("shape").as_arr().unwrap()[0].as_usize(), Some(8192));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nulltrue").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn display_escapes_control_chars() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }
}
