//! Property-testing substrate (proptest is unreachable offline).
//!
//! A deliberately small harness: deterministic seeded generators, N cases
//! per property, and on failure a report of the seed + case index so the
//! exact counterexample replays. No shrinking — generators are sized so
//! raw counterexamples stay readable.
//!
//! ```no_run
//! use ringiwp::util::prop::{forall, Gen};
//! forall("sum is commutative", 100, |g| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Case-local generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Case index, exposed so properties can scale sizes deterministically.
    pub case: usize,
}

impl Gen {
    /// Direct access to the case's RNG stream.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform integer in [lo, hi).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.rng.below(hi - lo)
    }

    /// Uniform float in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo, hi)
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of uniform f32 values.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of normals — gradient-like data.
    pub fn vec_normal(&mut self, len: usize, mu: f32, sigma: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_with(mu, sigma)).collect()
    }

    /// Sparse-ish vector: each element nonzero with probability `density`.
    pub fn vec_sparse(&mut self, len: usize, density: f64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if (self.rng.uniform() as f64) < density {
                    self.rng.normal()
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Pick one of the provided values.
    pub fn choice<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.rng.below(xs.len())]
    }
}

/// Environment knob: RINGIWP_PROP_SEED replays a failing run.
fn base_seed() -> u64 {
    std::env::var("RINGIWP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `property` against `cases` generated inputs; panics with replay
/// info on the first failure.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, property: F) {
    let seed = base_seed();
    for case in 0..cases {
        let mut gen = Gen {
            rng: Rng::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut gen)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay: RINGIWP_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("abs is non-negative", 50, |g| {
            let x = g.f32_in(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn reports_failure_with_case() {
        forall("always fails", 10, |g| {
            let _ = g.bool();
            assert!(false, "boom");
        });
    }

    #[test]
    fn generators_in_bounds() {
        forall("generator bounds", 100, |g| {
            let n = g.usize_in(1, 50);
            assert!((1..50).contains(&n));
            let v = g.vec_f32(n, -2.0, 2.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|x| (-2.0..2.0).contains(x)));
            let s = g.vec_sparse(200, 0.1);
            let nnz = s.iter().filter(|x| **x != 0.0).count();
            assert!(nnz < 100, "density way off: {nnz}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall("collect", 5, |g| {
            // note: can't mutate outer state through RefUnwindSafe easily;
            // instead just assert the stream is stable per case index.
            let v = g.rng().next_u64();
            let mut g2 = Rng::new(
                base_seed() ^ (g.case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            assert_eq!(v, g2.next_u64());
        });
        first.push(0u8); // silence unused warning pattern
        assert_eq!(first.len(), 1);
    }
}
