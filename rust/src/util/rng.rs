//! Deterministic RNG substrate (SplitMix64 core).
//!
//! Every stochastic piece of the system — synthetic gradients, random
//! gradient selection (Sec. III-C), random mask-node choice (Alg. 1), data
//! shuffling — draws from seeded `Rng` instances so whole experiments are
//! reproducible bit-for-bit. `rand` is unavailable offline; SplitMix64 has
//! excellent statistical quality for simulation workloads and is trivially
//! splittable for per-node streams.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded stream (any seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point and decorrelate small seeds.
        Rng {
            state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xD1B5_4A32_D192_ED03,
        }
    }

    /// Derive an independent stream (e.g. one per simulated node).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64();
        Rng::new(s ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high-quality bits -> f32 mantissa.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style widening multiply keeps this unbiased-enough for
        // simulation use and branch-free.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box–Muller; one value per call, cached pair dropped
    /// deliberately to keep the struct `Copy`-light and splittable).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f32::consts::TAU * u2).cos();
            }
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_with(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Log-normal with underlying N(mu, sigma^2).
    pub fn lognormal(&mut self, mu: f32, sigma: f32) -> f32 {
        self.normal_with(mu, sigma).exp()
    }

    /// Fill a slice with uniforms in [0,1).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform();
        }
    }

    /// Fill a slice with N(mu, sigma^2). Uses paired Box–Muller (both
    /// the cos and sin branches), which halves the ln/sqrt/trig cost on
    /// the bulk-generation hot path (synthetic 25M-param gradients).
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        let mut i = 0;
        let n = out.len();
        while i + 1 < n {
            let (a, b) = self.normal_pair();
            out[i] = mu + sigma * a;
            out[i + 1] = mu + sigma * b;
            i += 2;
        }
        if i < n {
            out[i] = self.normal_with(mu, sigma);
        }
    }

    /// One Box–Muller draw yielding both independent normals.
    #[inline]
    pub fn normal_pair(&mut self) -> (f32, f32) {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                let (s, c) = (std::f32::consts::TAU * u2).sin_cos();
                return (r * c, r * s);
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from [0, n) (Alg. 1's random node pick).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot choose {k} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k swaps are needed.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0f64;
        for _ in 0..100_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let mut picks = r.choose_distinct(96, 5);
            picks.sort_unstable();
            picks.dedup();
            assert_eq!(picks.len(), 5);
            assert!(picks.iter().all(|&p| p < 96));
        }
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut root = Rng::new(5);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
