//! Experiment metrics: loss/accuracy series, compression accounting,
//! CSV, and the schema-versioned `BENCH_*.json` payloads.

pub mod accounting;
pub mod bench;
pub mod csv;

pub use accounting::CompressionAccount;
pub use bench::BenchReport;
pub use csv::CsvWriter;
