//! Experiment metrics: loss/accuracy series, compression accounting, CSV.

pub mod accounting;
pub mod csv;

pub use accounting::CompressionAccount;
pub use csv::CsvWriter;
