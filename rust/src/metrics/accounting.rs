//! Compression-ratio accounting — the paper's headline metric.
//!
//! Paper (Sec. IV-A):
//! `GradientCompressionRatio = size[G] / size[encode(sparse(G))]`
//! (reported as "64×" etc., i.e. dense-over-compressed). We measure it
//! from *actual wire bytes per node per step*, including the amortized
//! mask-AllGather share for Algorithm 1, so nothing is flattered.

/// Running account over a training run.
///
/// Two ratios are kept, because the paper's metric and the honest
/// end-to-end metric differ:
/// * **payload ratio** — the paper's Sec. IV-A definition,
///   `size[G] / size[encode(sparse(G))]` per node: dense gradient bytes
///   over the *encoded gradient payload* a node emits.
/// * **wire ratio** — everything on the wire per node per step,
///   including Algorithm 1's mask AllGather share and the 2(N-1)/N ring
///   transport factor.
#[derive(Debug, Clone, Default)]
pub struct CompressionAccount {
    steps: u64,
    /// Dense wire reference (2(N-1)/N x gradient bytes, summed).
    dense_bytes: u64,
    /// Actual wire bytes per node (summed).
    wire_bytes: u64,
    /// Dense payload reference (4 x params, summed).
    dense_payload: u64,
    /// Encoded gradient payload per node (summed) — the paper's metric.
    payload_bytes: u64,
    /// Selected-coordinate density per step (for density curves).
    densities: Vec<f64>,
}

impl CompressionAccount {
    /// Fresh, empty account.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one step's per-node costs.
    pub fn record(&mut self, dense_bytes: u64, wire_bytes: u64, density: f64) {
        self.record_full(dense_bytes, wire_bytes, dense_bytes, wire_bytes, density);
    }

    /// Record with distinct wire and payload accounting.
    pub fn record_full(
        &mut self,
        dense_wire: u64,
        wire_bytes: u64,
        dense_payload: u64,
        payload_bytes: u64,
        density: f64,
    ) {
        self.steps += 1;
        self.dense_bytes += dense_wire;
        self.wire_bytes += wire_bytes;
        self.dense_payload += dense_payload;
        self.payload_bytes += payload_bytes;
        self.densities.push(density);
    }

    /// Number of recorded steps.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Actual per-node wire bytes summed over the run.
    pub fn total_wire_bytes(&self) -> u64 {
        self.wire_bytes
    }

    /// Dense-reference wire bytes summed over the run.
    pub fn total_dense_bytes(&self) -> u64 {
        self.dense_bytes
    }

    /// End-to-end wire ratio: dense transport / actual transport.
    pub fn ratio(&self) -> f64 {
        if self.wire_bytes == 0 {
            1.0
        } else {
            self.dense_bytes as f64 / self.wire_bytes as f64
        }
    }

    /// The paper's Sec. IV-A compression ratio:
    /// `size[G] / size[encode(sparse(G))]`.
    pub fn payload_ratio(&self) -> f64 {
        if self.payload_bytes == 0 {
            1.0
        } else {
            self.dense_payload as f64 / self.payload_bytes as f64
        }
    }

    /// Mean selected density over all recorded steps.
    pub fn mean_density(&self) -> f64 {
        if self.densities.is_empty() {
            0.0
        } else {
            self.densities.iter().sum::<f64>() / self.densities.len() as f64
        }
    }

    /// Per-step density series (for density curves).
    pub fn density_series(&self) -> &[f64] {
        &self.densities
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_is_dense_over_wire() {
        let mut a = CompressionAccount::new();
        a.record(6400, 100, 0.01);
        a.record(6400, 100, 0.01);
        assert!((a.ratio() - 64.0).abs() < 1e-9);
        assert!((a.payload_ratio() - 64.0).abs() < 1e-9); // record() mirrors
        assert_eq!(a.steps(), 2);
    }

    #[test]
    fn payload_and_wire_tracked_separately() {
        let mut a = CompressionAccount::new();
        a.record_full(8000, 1000, 4000, 100, 0.01);
        assert!((a.ratio() - 8.0).abs() < 1e-9);
        assert!((a.payload_ratio() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn empty_account_is_neutral() {
        let a = CompressionAccount::new();
        assert_eq!(a.ratio(), 1.0);
        assert_eq!(a.mean_density(), 0.0);
    }

    #[test]
    fn density_tracking() {
        let mut a = CompressionAccount::new();
        a.record(100, 100, 0.02);
        a.record(100, 100, 0.04);
        assert!((a.mean_density() - 0.03).abs() < 1e-12);
        assert_eq!(a.density_series().len(), 2);
    }
}
