//! Schema-versioned machine-readable bench payloads — the `BENCH_*.json`
//! substrate behind the perf-regression CI gate (DESIGN.md §9,
//! EXPERIMENTS.md §6).
//!
//! A payload is a single JSON object: `schema_version`, `name`,
//! provenance (`commit`, `timestamp`), the harness `config`, and a flat
//! `rows` array. Every row carries a unique `id` plus a mix of
//! *deterministic* fields (bytes on the wire, virtual seconds, densities
//! — pure functions of config and seed) and *volatile* fields (measured
//! `ns_op` wall time). [`canonical`] strips the volatile set so two runs
//! of the same commit compare equal byte-for-byte; [`compare`] gates a
//! current payload against a checked-in baseline: hard-fails on any
//! deterministic drift and on `ns_op` regressions beyond the allowed
//! fraction.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Version of the `BENCH_*.json` schema this crate emits.
pub const SCHEMA_VERSION: usize = 1;

/// Fields [`canonical`] strips before determinism comparisons: measured
/// wall time and provenance. Everything else must replay bit-for-bit.
pub const VOLATILE_FIELDS: [&str; 3] = ["ns_op", "commit", "timestamp"];

/// An in-flight bench payload; build rows with [`BenchReport::push`],
/// serialize with [`BenchReport::to_json`] / [`BenchReport::write`].
#[derive(Debug, Clone)]
pub struct BenchReport {
    name: String,
    config: Json,
    rows: Vec<Json>,
}

impl BenchReport {
    /// Start a payload named `name` (e.g. `"ring"`, `"step"`) under the
    /// given harness `config` object.
    pub fn new(name: &str, config: Json) -> Self {
        BenchReport {
            name: name.to_string(),
            config,
            rows: Vec::new(),
        }
    }

    /// Append one row. Rows must carry a unique `"id"` string —
    /// [`compare`] matches baseline rows by it.
    pub fn push(&mut self, row: Json) {
        debug_assert!(
            row.get("id").as_str().is_some(),
            "bench rows must carry an `id`"
        );
        self.rows.push(row);
    }

    /// Number of rows so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows were recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The full schema-versioned payload, provenance stamped from the
    /// environment ([`commit`], [`timestamp`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::from(SCHEMA_VERSION)),
            ("name", Json::from(self.name.as_str())),
            ("commit", Json::from(commit().as_str())),
            ("timestamp", Json::from(timestamp() as f64)),
            ("config", self.config.clone()),
            ("rows", Json::Arr(self.rows.clone())),
        ])
    }

    /// Serialize to `path` (single-line JSON, trailing newline).
    pub fn write(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }
}

/// Commit id for payload provenance: `RINGIWP_COMMIT`, else the CI's
/// `GITHUB_SHA`, else `"unknown"` (no subprocess spawning — the harness
/// must run identically inside and outside git checkouts).
pub fn commit() -> String {
    std::env::var("RINGIWP_COMMIT")
        .or_else(|_| std::env::var("GITHUB_SHA"))
        .unwrap_or_else(|_| "unknown".to_string())
}

/// Seconds since the Unix epoch (payload provenance only — stripped by
/// [`canonical`]).
pub fn timestamp() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Deep-copy `v` with every [`VOLATILE_FIELDS`] key removed, at any
/// nesting depth. Two runs of the same commit+config must produce equal
/// canonical payloads — the determinism contract CI enforces.
pub fn canonical(v: &Json) -> Json {
    match v {
        Json::Obj(o) => {
            let mut out = BTreeMap::new();
            for (k, val) in o {
                if !VOLATILE_FIELDS.contains(&k.as_str()) {
                    out.insert(k.clone(), canonical(val));
                }
            }
            Json::Obj(out)
        }
        Json::Arr(a) => Json::Arr(a.iter().map(canonical).collect()),
        other => other.clone(),
    }
}

fn rows_by_id(payload: &Json) -> BTreeMap<String, &Json> {
    let mut out = BTreeMap::new();
    if let Some(rows) = payload.get("rows").as_arr() {
        for row in rows {
            if let Some(id) = row.get("id").as_str() {
                out.insert(id.to_string(), row);
            }
        }
    }
    out
}

/// Gate `current` against `baseline` (both full `BENCH_*` payloads).
/// Returns human-readable failures, empty when the gate passes:
///
/// * a baseline row missing from `current` — coverage regressed;
/// * any *deterministic* row field (everything but [`VOLATILE_FIELDS`])
///   differing — the payload is supposed to replay bit-for-bit, so this
///   is either nondeterminism or an unacknowledged behaviour change
///   (re-baseline deliberately when the change is intended);
/// * `ns_op` above `baseline * (1 + max_regression)` — a perf
///   regression.
///
/// Rows present only in `current` are allowed (new coverage never
/// fails the gate). Schema-version and config-profile mismatches fail
/// loudly rather than comparing apples to oranges.
pub fn compare(baseline: &Json, current: &Json, max_regression: f64) -> Vec<String> {
    let mut failures = Vec::new();
    let (bv, cv) = (
        baseline.get("schema_version").as_usize(),
        current.get("schema_version").as_usize(),
    );
    if bv != cv {
        failures.push(format!("schema_version mismatch: baseline {bv:?} vs current {cv:?}"));
        return failures;
    }
    let (bp, cp) = (
        baseline.get("config").get("profile").as_str().unwrap_or(""),
        current.get("config").get("profile").as_str().unwrap_or(""),
    );
    if bp != cp {
        failures.push(format!(
            "config profile mismatch: baseline `{bp}` vs current `{cp}` — reseed the baseline"
        ));
        return failures;
    }

    let base_rows = rows_by_id(baseline);
    let cur_rows = rows_by_id(current);
    let mut ns_gated = 0usize;
    for (id, brow) in &base_rows {
        let Some(crow) = cur_rows.get(id) else {
            failures.push(format!("row `{id}`: present in baseline, missing from current"));
            continue;
        };
        // Deterministic fields must replay exactly.
        let (bc, cc) = (canonical(brow), canonical(crow));
        if bc != cc {
            failures.push(format!(
                "row `{id}`: deterministic fields drifted (baseline {bc} vs current {cc})"
            ));
        }
        // Volatile ns_op gates on relative regression.
        if let (Some(b_ns), Some(c_ns)) =
            (brow.get("ns_op").as_f64(), crow.get("ns_op").as_f64())
        {
            ns_gated += 1;
            if b_ns > 0.0 && c_ns > b_ns * (1.0 + max_regression) {
                failures.push(format!(
                    "row `{id}`: ns_op regressed {:.1}% ({b_ns:.0} -> {c_ns:.0} ns, \
                     gate {:.0}%)",
                    (c_ns / b_ns - 1.0) * 100.0,
                    max_regression * 100.0
                ));
            }
        }
    }
    // A perf gate that compared zero timings is vacuous — fail loudly
    // rather than print PASS having verified nothing (happens when the
    // baseline was seeded from a --no-timing payload, or the current run
    // passed --no-timing alongside --baseline).
    if !base_rows.is_empty() && ns_gated == 0 {
        failures.push(
            "no ns_op rows compared: baseline or current payload lacks timing — re-seed \
             the baseline from a timed run, or drop --baseline for deterministic-only \
             checks"
                .to_string(),
        );
    }
    failures
}

/// Human-readable ns/op comparison of `current` against `baseline`,
/// one line per row both payloads time, sorted worst regression first —
/// the bench-smoke job prints this next to the pass/fail gate so a CI
/// log shows *where* the time went, not just whether it regressed
/// (EXPERIMENTS.md §6). Rows missing `ns_op` on either side are
/// skipped; returns an empty Vec when nothing is comparable.
pub fn ns_op_summary(baseline: &Json, current: &Json) -> Vec<String> {
    let base_rows = rows_by_id(baseline);
    let cur_rows = rows_by_id(current);
    let mut rows: Vec<(f64, String)> = Vec::new();
    for (id, brow) in &base_rows {
        let Some(crow) = cur_rows.get(id) else {
            continue;
        };
        let (Some(b_ns), Some(c_ns)) = (brow.get("ns_op").as_f64(), crow.get("ns_op").as_f64())
        else {
            continue;
        };
        if b_ns <= 0.0 {
            continue;
        }
        let delta = c_ns / b_ns - 1.0;
        rows.push((
            delta,
            format!(
                "{:>+7.1}%  {:>12.0} -> {:>12.0} ns/op  {id}",
                delta * 100.0,
                b_ns,
                c_ns
            ),
        ));
    }
    rows.sort_by(|a, b| b.0.total_cmp(&a.0));
    rows.into_iter().map(|(_, line)| line).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn row(id: &str, ns: f64, bytes: f64) -> Json {
        Json::obj(vec![
            ("id", Json::from(id)),
            ("ns_op", Json::Num(ns)),
            ("bytes_per_node", Json::Num(bytes)),
        ])
    }

    fn payload(rows: Vec<Json>) -> Json {
        Json::obj(vec![
            ("schema_version", Json::from(SCHEMA_VERSION)),
            ("name", Json::from("ring")),
            ("commit", Json::from("abc")),
            ("timestamp", Json::Num(1.0)),
            (
                "config",
                Json::obj(vec![("profile", Json::from("quick"))]),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }

    #[test]
    fn canonical_strips_volatile_fields_everywhere() {
        let p = payload(vec![row("a", 100.0, 64.0)]);
        let c = canonical(&p);
        assert_eq!(c.get("commit"), &Json::Null);
        assert_eq!(c.get("timestamp"), &Json::Null);
        let rows = c.get("rows").as_arr().unwrap();
        assert_eq!(rows[0].get("ns_op"), &Json::Null);
        assert_eq!(rows[0].get("bytes_per_node").as_f64(), Some(64.0));
    }

    #[test]
    fn canonical_equates_same_run_different_provenance() {
        let a = payload(vec![row("a", 100.0, 64.0)]);
        let mut b = payload(vec![row("a", 250.0, 64.0)]);
        if let Json::Obj(o) = &mut b {
            o.insert("commit".into(), Json::from("def"));
            o.insert("timestamp".into(), Json::Num(9.0));
        }
        assert_eq!(canonical(&a), canonical(&b));
    }

    #[test]
    fn compare_passes_within_gate_and_fails_beyond() {
        let base = payload(vec![row("a", 1000.0, 64.0)]);
        let ok = payload(vec![row("a", 1150.0, 64.0)]);
        assert!(compare(&base, &ok, 0.2).is_empty());
        let slow = payload(vec![row("a", 1300.0, 64.0)]);
        let fails = compare(&base, &slow, 0.2);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("regressed"), "{fails:?}");
    }

    #[test]
    fn compare_fails_on_deterministic_drift_and_missing_rows() {
        let base = payload(vec![row("a", 1000.0, 64.0), row("b", 1.0, 8.0)]);
        let drifted = payload(vec![row("a", 1000.0, 65.0)]);
        let fails = compare(&base, &drifted, 0.2);
        assert_eq!(fails.len(), 2, "{fails:?}");
        assert!(fails.iter().any(|f| f.contains("drifted")));
        assert!(fails.iter().any(|f| f.contains("missing")));
        // New rows in current never fail the gate.
        let grown = payload(vec![row("a", 1000.0, 64.0), row("b", 1.0, 8.0), row("c", 1.0, 1.0)]);
        assert!(compare(&base, &grown, 0.2).is_empty());
    }

    #[test]
    fn compare_fails_when_no_timings_were_compared() {
        fn quiet_row(id: &str, bytes: f64) -> Json {
            Json::obj(vec![
                ("id", Json::from(id)),
                ("bytes_per_node", Json::Num(bytes)),
            ])
        }
        // Baseline seeded without timing: the gate must not report PASS.
        let base = payload(vec![quiet_row("a", 64.0)]);
        let cur = payload(vec![row("a", 100.0, 64.0)]);
        let fails = compare(&base, &cur, 0.2);
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("no ns_op rows compared"));
        // Current run without timing: same vacuity failure.
        let base = payload(vec![row("a", 100.0, 64.0)]);
        let cur = payload(vec![quiet_row("a", 64.0)]);
        let fails = compare(&base, &cur, 0.2);
        assert!(fails.iter().any(|f| f.contains("no ns_op rows compared")), "{fails:?}");
    }

    #[test]
    fn compare_fails_loudly_on_profile_mismatch() {
        let base = payload(vec![row("a", 1.0, 1.0)]);
        let mut cur = payload(vec![row("a", 1.0, 1.0)]);
        if let Json::Obj(o) = &mut cur {
            o.insert(
                "config".into(),
                Json::obj(vec![("profile", Json::from("full"))]),
            );
        }
        let fails = compare(&base, &cur, 0.2);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("profile mismatch"));
    }

    #[test]
    fn ns_op_summary_sorts_worst_regression_first() {
        let base = payload(vec![row("a", 1000.0, 1.0), row("b", 1000.0, 1.0)]);
        let cur = payload(vec![row("a", 1100.0, 1.0), row("b", 2000.0, 1.0)]);
        let lines = ns_op_summary(&base, &cur);
        assert_eq!(lines.len(), 2);
        assert!(lines[0].ends_with(" b") && lines[0].contains("+100.0%"), "{lines:?}");
        assert!(lines[1].ends_with(" a") && lines[1].contains("+10.0%"), "{lines:?}");
        // Untimed payloads produce no lines rather than garbage.
        let quiet = payload(vec![Json::obj(vec![("id", Json::from("a"))])]);
        assert!(ns_op_summary(&quiet, &cur).is_empty());
    }

    #[test]
    fn report_serializes_with_schema_and_roundtrips() {
        let mut rep = BenchReport::new(
            "ring",
            Json::obj(vec![("profile", Json::from("quick"))]),
        );
        rep.push(row("dense/n4", 5.0, 10.0));
        assert_eq!(rep.len(), 1);
        assert!(!rep.is_empty());
        let j = rep.to_json();
        assert_eq!(j.get("schema_version").as_usize(), Some(SCHEMA_VERSION));
        assert_eq!(j.get("name").as_str(), Some("ring"));
        let reparsed = parse(&j.to_string()).unwrap();
        assert_eq!(canonical(&reparsed), canonical(&j));
    }
}
