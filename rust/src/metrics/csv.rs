//! CSV output for every experiment (the figures' data files).

use std::io::Write;
use std::path::Path;

/// Minimal CSV writer: header row + typed value rows, RFC-4180 quoting
/// for strings.
pub struct CsvWriter {
    out: Box<dyn Write>,
    n_cols: usize,
}

impl CsvWriter {
    /// Create (or truncate) `path`, creating parent dirs, and write the
    /// header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path)?;
        Self::from_writer(Box::new(std::io::BufWriter::new(file)), header)
    }

    /// Wrap any writer (tests, stdout) and emit the header row.
    pub fn from_writer(mut out: Box<dyn Write>, header: &[&str]) -> anyhow::Result<Self> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            n_cols: header.len(),
        })
    }

    /// Write one row; arity must match the header.
    pub fn row(&mut self, cells: &[CsvCell]) -> anyhow::Result<()> {
        anyhow::ensure!(
            cells.len() == self.n_cols,
            "row has {} cells, header has {}",
            cells.len(),
            self.n_cols
        );
        let rendered: Vec<String> = cells.iter().map(|c| c.render()).collect();
        writeln!(self.out, "{}", rendered.join(","))?;
        Ok(())
    }

    /// Flush the underlying writer.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// A single CSV cell.
pub enum CsvCell {
    /// String cell (RFC-4180 quoted when needed).
    Str(String),
    /// Float cell.
    F64(f64),
    /// Unsigned cell.
    U64(u64),
    /// Index/count cell.
    Usize(usize),
}

impl CsvCell {
    fn render(&self) -> String {
        match self {
            CsvCell::Str(s) => {
                if s.contains(',') || s.contains('"') || s.contains('\n') {
                    format!("\"{}\"", s.replace('"', "\"\""))
                } else {
                    s.clone()
                }
            }
            CsvCell::F64(v) => format!("{v}"),
            CsvCell::U64(v) => format!("{v}"),
            CsvCell::Usize(v) => format!("{v}"),
        }
    }
}

impl From<&str> for CsvCell {
    fn from(s: &str) -> Self {
        CsvCell::Str(s.to_string())
    }
}
impl From<String> for CsvCell {
    fn from(s: String) -> Self {
        CsvCell::Str(s)
    }
}
impl From<&String> for CsvCell {
    fn from(s: &String) -> Self {
        CsvCell::Str(s.clone())
    }
}
impl From<f64> for CsvCell {
    fn from(v: f64) -> Self {
        CsvCell::F64(v)
    }
}
impl From<u64> for CsvCell {
    fn from(v: u64) -> Self {
        CsvCell::U64(v)
    }
}
impl From<usize> for CsvCell {
    fn from(v: usize) -> Self {
        CsvCell::Usize(v)
    }
}

/// Convenience macro: `csv_row!(writer, "name", 1.5, 42usize)`.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($cell:expr),+ $(,)?) => {
        $w.row(&[$($crate::metrics::csv::CsvCell::from($cell)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render_rows(header: &[&str], rows: Vec<Vec<CsvCell>>) -> String {
        let buf = std::sync::Arc::new(std::sync::Mutex::new(Vec::<u8>::new()));
        struct Shared(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);
        impl Write for Shared {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut w =
            CsvWriter::from_writer(Box::new(Shared(buf.clone())), header).unwrap();
        for r in rows {
            w.row(&r).unwrap();
        }
        w.flush().unwrap();
        drop(w);
        let bytes = buf.lock().unwrap().clone();
        String::from_utf8(bytes).unwrap()
    }

    #[test]
    fn writes_header_and_rows() {
        let text = render_rows(
            &["a", "b"],
            vec![vec![CsvCell::from("x"), CsvCell::from(1.5f64)]],
        );
        assert_eq!(text, "a,b\nx,1.5\n");
    }

    #[test]
    fn quotes_commas_and_quotes() {
        let text = render_rows(
            &["s"],
            vec![vec![CsvCell::from("he said \"hi, there\"")]],
        );
        assert_eq!(text, "s\n\"he said \"\"hi, there\"\"\"\n");
    }

    #[test]
    fn rejects_wrong_arity() {
        let buf: Vec<u8> = Vec::new();
        let mut w = CsvWriter::from_writer(Box::new(buf), &["a", "b"]).unwrap();
        assert!(w.row(&[CsvCell::from(1.0f64)]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir().join("ringiwp_csv_test.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            csv_row!(w, 0usize, 2.5f64).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n0,2.5\n");
        let _ = std::fs::remove_file(path);
    }
}
