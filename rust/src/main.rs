//! `ringiwp` — CLI entrypoint for the Importance-Weighted-Pruning
//! ring-all-reduce system (see README.md).
//!
//! Subcommands:
//!   train   — run the N-node simulated-ring trainer on a real model
//!   exp     — regenerate a paper table/figure (table1, fig2, …, all)
//!   bench   — emit machine-readable BENCH_*.json perf payloads
//!   serve   — run one wire-transport rank (net::wire, DESIGN.md §13)
//!   chaos   — sweep a deterministic failure schedule across every
//!             pipeline × topology × recovery mode (DESIGN.md §15)
//!   methods — list the registered compression-pipeline specs
//!   info    — show artifacts, platform, model inventories
//!   help    — this text

use ringiwp::config::Config;
use ringiwp::coordinator::Trainer;
use ringiwp::exp;
use ringiwp::model::zoo;
use ringiwp::runtime::Runtime;
use ringiwp::util::cli::Args;
use ringiwp::util::human_bytes;

const USAGE: &str = "\
ringiwp — Bandwidth Reduction using Importance Weighted Pruning on Ring AllReduce

USAGE:
    ringiwp <subcommand> [flags]

SUBCOMMANDS:
    train       train a real model (PJRT) on the simulated N-node ring
                  --model mlp|tfm_tiny
                  --method <spec> (compression pipeline, DESIGN.md §12:
                  dense|terngrad|iwp:fixed|iwp:layerwise|
                  iwp:vargate[:<gate>[:<boost>]]|dgc:topk|dgc:layerwise
                  plus +warmup:<e>/+mcorr/+nomcorr/+sel/+nosel/+tern
                  stages; legacy names like iwp-fixed are aliases; env
                  RINGIWP_METHOD sets the default; see `ringiwp methods`)
                  --nodes N --steps N --thr X --seed N
                  --mask-nodes R --no-random-select --config FILE --out DIR
                  --parallelism W (node-parallel executor width, default 1)
                  --topology flat|hier:<group_size>|tree|
                  pipeline:<chunks>[:<inner>] (reduce topology, DESIGN.md
                  §10-§11; default flat)
                  --tuner off|on|log-only (online protocol autotuner,
                  DESIGN.md §14: each step picks the CostModel-argmin
                  wire format + topology + chunking from the observed
                  shared mask; log-only records decisions without acting;
                  env RINGIWP_TUNER sets the default; needs iwp:* methods)
    exp         regenerate a paper experiment:
                  --id table1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|density|sweep|all
                  --out DIR (default results/) --steps N --nodes N --seed N
                  (env RINGIWP_PARALLELISM=W widens the sim executor —
                   results are bit-identical at any width; env
                   RINGIWP_TOPOLOGY=flat|hier:<g>|tree|pipeline:<k>[:<inner>]
                   switches the sim reduce topology; `density` sweeps its
                   own topology set itself)
    bench       run the in-process perf harness (exp::bench) and emit
                schema-versioned BENCH_ring.json / BENCH_step.json (ring
                rows cover the topology sweep incl. pipeline:4:flat, and
                both suites carry autotuner `tuned` rows next to the
                static strategies):
                  --out DIR (default .) --quick --no-timing --repeats N
                  --ring-sizes 4,8,32,96 --seed N
                  --baseline FILE   gate ns/op + determinism against a
                                    checked-in baseline (bench/baseline.json)
                                    and print a per-row ns/op diff summary
                  --strict-baseline fail (exit 1) when a baseline section
                                    ships null instead of skipping the gate
                  --seed-baseline FILE  fill the baseline file's null ring/
                                    step sections with this run's payloads
                                    (already-seeded sections are untouched)
                  --diff DIR_A DIR_B  compare two output dirs' payloads
                                    modulo volatile fields (exit 1 on drift)
                  --transport sim|uds|tcp  run the step suite over the
                                    real socket ring (net::wire) instead
                                    of the virtual-only transport; rows
                                    carry a `transport` column either way
                                    (env RINGIWP_TRANSPORT sets the
                                    default; DESIGN.md §13)
    serve       run one wire-transport rank until its coordinator
                connects (EXPERIMENTS.md §10):
                  --rank N --nodes N  this rank's id / ring size
                  --dir DIR           rendezvous directory (default wire)
                  --transport uds|tcp (default uds)
                  --once              serve one session then exit
                  --wire-timeout-ms N socket connect/read deadline, ms
                                      (default 30000; env
                                      RINGIWP_WIRE_TIMEOUT_MS)
                prints per-rank recovery totals (retransmits,
                reconnects, …) on exit (DESIGN.md §16)
    chaos       replay a deterministic fault schedule (net::chaos,
                DESIGN.md §15) across every compression pipeline ×
                reduce topology × recovery mode, checking residual
                conservation, bounded staleness, and mask consistency
                around every recovery event; output is byte-identical
                for the same seed:
                  --seed N            schedule seed (default 42)
                  --chaos GRAMMAR     explicit plan instead (mode=…,
                                      crash@s:n, slow@s:n:f, heal@s,
                                      join@s; env RINGIWP_CHAOS)
                  --chaos-mode handoff|rescale  sweep one mode only
                  --nodes N --steps N starting ring / schedule length
                  --transport sim|uds|tcp  engine flavor (sim checks
                                      the virtual oracle; uds/tcp
                                      re-ring real socket rings)
                  --wire-faults GRAMMAR  seeded byte-level frame faults
                                      on the socket rings (flip@f:e,
                                      trunc@f:e, drop@f:e, dup@f:e,
                                      delay@f:e:ms, reset@f:e,
                                      attempts=K, seed=S; env
                                      RINGIWP_WIRE_FAULTS; overrides
                                      wire tokens riding in --chaos;
                                      sim arms ignore it; DESIGN.md §16)
                  --wire-timeout-ms N socket deadline, ms (ARQ retry /
                                      ACK deadlines derive from it)
    methods     list the registered compression-pipeline specs with
                one-line descriptions (the --method registry)
    info        list artifacts, PJRT platform, zoo inventories
    help        print this message

Config file (--config): `key = value` lines; see configs/*.conf.
Artifacts must exist (run `make artifacts` once).

Exit codes (DESIGN.md §16): 0 success; 1 unclassified error;
2 config (bad flag / grammar / plan); 3 transport (socket, frame, or
recovery failure — including an unrecoverable wire-fault schedule
exhausting its retry budget); 4 invariant violation.
";

fn main() {
    env_logger_init();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => {
            let unknown = args.unknown();
            if !unknown.is_empty() {
                eprintln!("warning: unrecognized flags: {unknown:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            // Typed exit codes (util::exit, DESIGN.md §16): config=2,
            // transport=3, invariant=4; anything untagged stays 1.
            match ringiwp::util::exit::ExitClass::of(&e) {
                Some(class) => {
                    eprintln!("error: {class}");
                    class.code()
                }
                None => 1,
            }
        }
    };
    std::process::exit(code);
}

fn env_logger_init() {
    // Minimal logger: honor RUST_LOG=debug for verbose traces.
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    fn max_level() -> log::Level {
        match std::env::var("RUST_LOG").as_deref() {
            Ok("debug") => log::Level::Debug,
            Ok("trace") => log::Level::Trace,
            _ => log::Level::Info,
        }
    }
    let _ = log::set_logger(Box::leak(Box::new(L)));
    log::set_max_level(log::LevelFilter::Debug);
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("exp") => cmd_exp(args),
        Some("bench") => cmd_bench(args),
        Some("serve") => cmd_serve(args),
        Some("chaos") => cmd_chaos(args),
        Some("methods") => cmd_methods(),
        Some("info") => cmd_info(args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand `{other}`\n\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::default().apply_args(args)?;
    anyhow::ensure!(
        !matches!(&cfg.chaos, Some(p) if !p.is_empty()),
        "train does not execute fault schedules — run `ringiwp chaos` \
         (drop --chaos/--chaos-seed or unset RINGIWP_CHAOS)"
    );
    anyhow::ensure!(
        !matches!(&cfg.wire_faults, Some(p) if !p.is_empty()),
        "train does not execute wire-fault schedules — run `ringiwp chaos` \
         (drop --wire-faults or unset RINGIWP_WIRE_FAULTS)"
    );
    let rt = Runtime::cpu(&cfg.artifacts_dir)?;
    println!(
        "training {} with {} on a {}-node ring (PJRT platform: {})",
        cfg.model,
        cfg.method.name(),
        cfg.nodes,
        rt.platform()
    );
    let out_dir = cfg.out_dir.clone();
    let steps = cfg.steps;
    let mut trainer = Trainer::new(cfg, &rt)?;
    let t0 = std::time::Instant::now();
    let out = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nstep      train_loss");
    let stride = (steps / 20).max(1);
    for &(s, l) in out.losses.iter().filter(|(s, _)| s % stride == 0) {
        println!("{s:>6}    {l:.4}");
    }
    println!("\nfinal eval: loss {:.4}, acc {:.4}", out.final_eval_loss, out.final_eval_acc);
    println!(
        "compression ratio: {:.1}x (mean selected density {:.5})",
        out.account.ratio(),
        out.account.mean_density()
    );
    println!(
        "wire: {} total per-node (dense reference {}), {:.2} virtual net-seconds, peak {:.0} KB/s",
        human_bytes(out.account.total_wire_bytes() as f64),
        human_bytes(out.account.total_dense_bytes() as f64),
        out.net_seconds,
        out.peak_kbps
    );
    println!("wall time: {wall:.1}s ({:.2} s/step)", wall / steps as f64);

    // Autotuner decision trace (DESIGN.md §14): one line per step, the
    // format the EXPERIMENTS.md §11 walkthroughs grep for.
    if let Some(t) = trainer.tuner() {
        println!(
            "\nautotuner ({}): {} decisions, {} switches",
            t.mode().name(),
            t.trace().len(),
            t.switches()
        );
        for row in t.trace().rows() {
            println!("  {}", row.log_line());
        }
    }

    // Persist curves.
    std::fs::create_dir_all(&out_dir)?;
    use ringiwp::csv_row;
    use ringiwp::metrics::CsvWriter;
    let mut csv = CsvWriter::create(
        format!("{out_dir}/train_losses.csv"),
        &["step", "train_loss"],
    )?;
    for &(s, l) in &out.losses {
        csv_row!(csv, s, l)?;
    }
    csv.flush()?;
    println!("wrote {out_dir}/train_losses.csv");
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    // The experiment harnesses build engines from `SimCfg::default()`,
    // which honors RINGIWP_CHAOS — refuse up front rather than let a
    // forgotten env var silently fault every paper artifact.
    anyhow::ensure!(
        !ringiwp::net::ChaosPlan::from_env().is_some_and(|p| !p.is_empty()),
        "exp does not execute fault schedules — run `ringiwp chaos` (unset RINGIWP_CHAOS)"
    );
    anyhow::ensure!(
        !ringiwp::net::FaultPlan::from_env().is_some_and(|p| !p.is_empty()),
        "exp does not execute wire-fault schedules — run `ringiwp chaos` \
         (unset RINGIWP_WIRE_FAULTS)"
    );
    let id = args.str_or("id", "all");
    let out_dir = args.str_or("out", "results");
    let seed = args.u64_or("seed", 42);
    let artifacts_dir = args.str_or("artifacts", "artifacts");
    std::fs::create_dir_all(&out_dir)?;
    let rt = Runtime::cpu(&artifacts_dir).ok();
    if rt.is_none() {
        eprintln!("note: artifacts not found — accuracy halves will be skipped");
    }

    let run_one = |id: &str, rt: Option<&Runtime>| -> anyhow::Result<()> {
        match id {
            "table1" => exp::table1::run(
                rt,
                &out_dir,
                args.usize_or("nodes", 96),
                args.usize_or("steps", 8),
                args.usize_or("train-steps", 120),
                args.f64_or("thr", 0.05) as f32,
                seed,
            ),
            "fig2" | "fig3" => exp::figs::run_fig2_fig3(&out_dir, args.usize_or("steps", 12), seed),
            "fig4" => exp::figs::run_fig4(&out_dir, args.usize_or("steps", 40), seed),
            "fig5" | "fig6" => {
                let rt = rt.ok_or_else(|| anyhow::anyhow!("fig5/6 need artifacts"))?;
                exp::curves::run(rt, &out_dir, &args.str_or("model", "mlp"),
                                 args.usize_or("steps", 150), seed)
            }
            "fig7" | "fig8" => exp::io_trace::run(
                &out_dir,
                args.usize_or("nodes", 96),
                args.usize_or("steps", 6),
                seed,
            ),
            "density" => exp::density::run(&out_dir, seed),
            "sweep" => exp::sweep::run(rt, &out_dir, args.usize_or("steps", 6), seed),
            other => anyhow::bail!("unknown experiment `{other}`"),
        }
    };

    if id == "all" {
        for id in ["table1", "fig2", "fig4", "fig5", "fig7", "density", "sweep"] {
            println!("\n──────────────────────────── exp {id} ────────────────────────────");
            run_one(id, rt.as_ref())?;
        }
        Ok(())
    } else {
        run_one(&id, rt.as_ref())
    }
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use ringiwp::exp::bench::{run_ring, run_step, BenchCfg};
    use ringiwp::metrics::bench::{canonical, commit, compare, ns_op_summary};
    use ringiwp::util::json;

    anyhow::ensure!(
        !ringiwp::net::ChaosPlan::from_env().is_some_and(|p| !p.is_empty()),
        "bench does not execute fault schedules — a faulted run would poison the \
         perf baselines; run `ringiwp chaos` (unset RINGIWP_CHAOS)"
    );
    anyhow::ensure!(
        !ringiwp::net::FaultPlan::from_env().is_some_and(|p| !p.is_empty()),
        "bench does not execute wire-fault schedules — retransmits would poison the \
         perf baselines; run `ringiwp chaos` (unset RINGIWP_WIRE_FAULTS)"
    );

    // Diff mode: compare two output directories' payloads modulo the
    // volatile fields (the CI determinism check).
    if let Some(dir_a) = args.str_opt("diff") {
        let dir_b = args
            .positional
            .first()
            .ok_or_else(|| anyhow::anyhow!("bench --diff needs two directories"))?;
        let mut drift = false;
        for name in ["BENCH_ring.json", "BENCH_step.json"] {
            let a = json::parse(&std::fs::read_to_string(format!("{dir_a}/{name}"))?)
                .map_err(|e| anyhow::anyhow!("{dir_a}/{name}: {e}"))?;
            let b = json::parse(&std::fs::read_to_string(format!("{dir_b}/{name}"))?)
                .map_err(|e| anyhow::anyhow!("{dir_b}/{name}: {e}"))?;
            if canonical(&a) == canonical(&b) {
                println!("{name}: identical modulo volatile fields");
            } else {
                eprintln!("{name}: DETERMINISM DRIFT between {dir_a} and {dir_b}");
                drift = true;
            }
        }
        anyhow::ensure!(!drift, "bench payloads are not deterministic");
        return Ok(());
    }

    let mut cfg = BenchCfg {
        quick: args.switch("quick"),
        timing: !args.switch("no-timing"),
        repeats: args.usize_or("repeats", 3).max(1),
        seed: args.u64_or("seed", 42),
        ..Default::default()
    };
    if let Some(t) = args.str_opt("transport") {
        cfg.transport = ringiwp::net::TransportKind::parse(t)?;
    }
    if let Some(sizes) = args.str_opt("ring-sizes") {
        cfg.ring_sizes = sizes
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--ring-sizes expects integers, got `{s}`"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(
            cfg.ring_sizes.iter().all(|&n| n >= 2),
            "--ring-sizes entries must be >= 2"
        );
    }
    let out = args.str_or("out", ".");
    std::fs::create_dir_all(&out)?;

    println!(
        "bench: profile={} timing={} repeats={} rings={:?} commit={}",
        cfg.profile(),
        cfg.timing,
        cfg.repeats,
        cfg.ring_sizes,
        commit()
    );
    let ring = run_ring(&cfg);
    let ring_path = format!("{out}/BENCH_ring.json");
    ring.write(&ring_path)?;
    println!("wrote {ring_path} ({} rows)", ring.len());
    let step = run_step(&cfg);
    let step_path = format!("{out}/BENCH_step.json");
    step.write(&step_path)?;
    println!("wrote {step_path} ({} rows)", step.len());

    // Seed mode: fill a baseline file's null sections with this run's
    // payloads (EXPERIMENTS.md §6) — already-seeded sections stay put,
    // so a committed baseline is never silently clobbered.
    let mut seeded_this_run = Vec::new();
    if let Some(seed_path) = args.str_opt("seed-baseline") {
        let text = std::fs::read_to_string(seed_path)?;
        let parsed = json::parse(&text).map_err(|e| anyhow::anyhow!("{seed_path}: {e}"))?;
        let json::Json::Obj(mut map) = parsed else {
            anyhow::bail!("{seed_path}: baseline must be a JSON object");
        };
        anyhow::ensure!(
            cfg.timing,
            "seed runs must be timed (drop --no-timing) so the ns/op gate is not vacuous"
        );
        for (section, payload) in [("ring", ring.to_json()), ("step", step.to_json())] {
            if matches!(
                map.get(section),
                None | Some(json::Json::Null)
            ) {
                map.insert(section.to_string(), payload);
                seeded_this_run.push(section);
            }
        }
        if seeded_this_run.is_empty() {
            println!("seed-baseline: {seed_path} already fully seeded — no changes");
        } else {
            std::fs::write(seed_path, format!("{}\n", json::Json::Obj(map)))?;
            println!("seed-baseline: wrote {seeded_this_run:?} section(s) into {seed_path}");
        }
    }

    // Regression gate against a checked-in baseline.
    let strict = args.switch("strict-baseline");
    anyhow::ensure!(
        !strict || args.str_opt("baseline").is_some(),
        "--strict-baseline requires --baseline FILE — without it no gate runs at all"
    );
    if let Some(baseline_path) = args.str_opt("baseline") {
        // Gating a run against sections it just seeded from itself would
        // compare this run to this run and print a vacuous PASS — seed
        // and gate must be separate invocations (as CI does). Paths are
        // canonicalized so alternate spellings of the same file cannot
        // sneak past the guard.
        let same_file = args.str_opt("seed-baseline").is_some_and(|sp| {
            match (std::fs::canonicalize(sp), std::fs::canonicalize(baseline_path)) {
                (Ok(a), Ok(b)) => a == b,
                _ => sp == baseline_path,
            }
        });
        anyhow::ensure!(
            seeded_this_run.is_empty() || !same_file,
            "--baseline {baseline_path} was seeded by this very run (sections \
             {seeded_this_run:?}) — a self-referential gate verifies nothing. Re-run the \
             gate as a separate invocation against the now-seeded file."
        );
        let text = std::fs::read_to_string(baseline_path)?;
        let baseline = json::parse(&text).map_err(|e| anyhow::anyhow!("{baseline_path}: {e}"))?;
        let max_regression = baseline.get("max_regression").as_f64().unwrap_or(0.2);
        let mut failures = Vec::new();
        let mut unseeded = Vec::new();
        for (section, current) in [("ring", ring.to_json()), ("step", step.to_json())] {
            let base = baseline.get(section);
            if matches!(base, json::Json::Null) {
                println!(
                    "baseline `{section}` section is null — gate skipped (seed it with \
                     `ringiwp bench --seed-baseline {baseline_path}` or from a trusted CI \
                     run's BENCH_{section}.json artifact; see EXPERIMENTS.md §6)"
                );
                unseeded.push(section);
                continue;
            }
            // Human-readable ns/op diff next to the pass/fail verdict,
            // worst regression first (EXPERIMENTS.md §6).
            let summary = ns_op_summary(base, &current);
            if !summary.is_empty() {
                println!("ns/op vs baseline [{section}]:");
                for line in &summary {
                    println!("  {line}");
                }
            }
            failures.extend(
                compare(base, &current, max_regression)
                    .into_iter()
                    .map(|f| format!("[{section}] {f}")),
            );
        }
        // A gate that skipped a section must not read as protection:
        // --strict-baseline (the CI setting) turns the silent skip into
        // a failure carrying the seeding instruction — appended after
        // any real regressions so those still get reported first.
        if strict && !unseeded.is_empty() {
            failures.push(format!(
                "baseline {baseline_path} ships null section(s) {unseeded:?} — those gates \
                 verified nothing. Seed them: run `ringiwp bench --quick --seed-baseline \
                 {baseline_path}` on the reference machine (CI does this in its own \
                 workspace before gating), or download the `bench-json` artifact from a \
                 trusted CI run of this commit and paste BENCH_ring.json / BENCH_step.json \
                 verbatim into the `ring` / `step` keys (EXPERIMENTS.md §6), then re-run."
            ));
        }
        if failures.is_empty() {
            println!(
                "regression gate vs {baseline_path}: PASS (max ns/op regression {:.0}%)",
                max_regression * 100.0
            );
        } else {
            for f in &failures {
                eprintln!("REGRESSION: {f}");
            }
            anyhow::bail!("{} bench regression(s) vs {baseline_path}", failures.len());
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use ringiwp::net::wire::{serve_rank_with, wire_timeout_from_env, ServeOpts};
    use ringiwp::net::TransportKind;

    let rank = args
        .str_opt("rank")
        .ok_or_else(|| anyhow::anyhow!("serve needs --rank N"))?
        .parse::<u16>()
        .map_err(|_| anyhow::anyhow!("--rank expects a small integer"))?;
    let nodes = args
        .str_opt("nodes")
        .ok_or_else(|| anyhow::anyhow!("serve needs --nodes N"))?
        .parse::<u16>()
        .map_err(|_| anyhow::anyhow!("--nodes expects a small integer"))?;
    anyhow::ensure!(nodes >= 2, "serve needs --nodes >= 2");
    anyhow::ensure!(rank < nodes, "--rank must be < --nodes");
    let dir = args.str_or("dir", "wire");
    let transport = TransportKind::parse(&args.str_or("transport", "uds"))?;
    anyhow::ensure!(
        transport.is_wire(),
        "serve needs a socket transport (--transport uds|tcp)"
    );
    let once = args.switch("once");
    let timeout_ms = args.u64_or("wire-timeout-ms", wire_timeout_from_env());
    anyhow::ensure!(timeout_ms > 0, "--wire-timeout-ms must be > 0");
    std::fs::create_dir_all(&dir)?;
    println!(
        "serve: rank {rank}/{nodes} over {transport} in {dir} \
         (coordinator: set RINGIWP_WIRE_DIR={dir} RINGIWP_TRANSPORT={transport})"
    );
    let opts = ServeOpts {
        timeout: std::time::Duration::from_millis(timeout_ms),
        ..Default::default()
    };
    let report = serve_rank_with(std::path::Path::new(&dir), rank, nodes, transport, once, opts)?;
    println!(
        "serve: rank {rank} served {} session(s), wire-recovery: {}",
        report.sessions, report.recovery
    );
    Ok(())
}

fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use ringiwp::exp::chaosrun::{run, ChaosCfg};
    use ringiwp::net::{ChaosPlan, FaultPlan, RecoveryMode, TransportKind};
    use ringiwp::util::exit::ExitClass;

    let nodes = args.usize_or("nodes", 5);
    let steps = args.usize_or("steps", 10);
    let seed = args.u64_or("seed", 42);
    // Plan precedence: explicit grammar > RINGIWP_CHAOS > generated
    // from --seed.
    let plan = match args.str_opt("chaos") {
        Some(g) => ChaosPlan::parse(g).map_err(|e| anyhow::anyhow!(e).context(ExitClass::Config))?,
        None => {
            ChaosPlan::from_env().unwrap_or_else(|| ChaosPlan::generate(seed, nodes, steps))
        }
    };
    // Wire-fault precedence mirrors the chaos plan's: explicit grammar >
    // RINGIWP_WIRE_FAULTS > wire tokens riding in the chaos plan (the
    // engine falls back to those when this stays None).
    let wire_faults = match args.str_opt("wire-faults") {
        Some(g) => {
            Some(FaultPlan::parse(g).map_err(|e| anyhow::anyhow!(e).context(ExitClass::Config))?)
        }
        None => FaultPlan::from_env(),
    };
    let modes = match args.str_opt("chaos-mode") {
        Some(m) => vec![RecoveryMode::parse(m)
            .ok_or_else(|| anyhow::anyhow!("--chaos-mode expects handoff|rescale"))?],
        None => vec![RecoveryMode::Handoff, RecoveryMode::DropRescale],
    };
    let transport = TransportKind::parse(&args.str_or("transport", "sim"))?;
    let wire_timeout_ms =
        args.u64_or("wire-timeout-ms", ringiwp::net::wire::wire_timeout_from_env());
    let cfg = ChaosCfg {
        nodes,
        steps,
        plan: plan.clone(),
        modes,
        transport,
        seed,
        wire_timeout_ms,
        wire_faults,
        ..Default::default()
    };
    println!("chaos: plan {plan}");
    println!("chaos: nodes={nodes} steps={steps} transport={transport} seed={seed}");
    // The wire seam inside the compression pipelines panics (by §13
    // design) if a payload goes missing; with fault injection live that
    // is an unrecoverable-schedule outcome, so convert the panic into
    // the typed transport failure (exit 3) instead of an abort trace.
    let s = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(&cfg)))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "wire seam panicked".into());
            Err(anyhow::anyhow!("{msg}").context(ExitClass::Transport))
        })?;
    for line in &s.lines {
        println!("  {line}");
    }
    println!(
        "chaos: {} configs green, {} conservation checks, digest={:016x}",
        s.configs, s.recovery_events, s.digest
    );
    if transport.is_wire() {
        println!("chaos: wire-recovery: {}", s.wire_recovery);
    }
    Ok(())
}

fn cmd_methods() -> anyhow::Result<()> {
    use ringiwp::compress::spec::{REGISTRY, STAGES};
    println!(
        "registered method specs (--method <spec>, config `method = <spec>`, \
         env RINGIWP_METHOD):\n"
    );
    for e in REGISTRY {
        let legacy = e.legacy.map(|l| format!("[alias: {l}]")).unwrap_or_default();
        println!("  {:<28} {:<22} {}", e.spec, legacy, e.desc);
    }
    println!("\nstages (append to iwp/dgc heads with `+`):\n");
    for (stage, desc) in STAGES {
        println!("  {stage:<18} {desc}");
    }
    println!(
        "\nexamples:\n  \
         ringiwp train --method iwp:layerwise+warmup:4\n  \
         ringiwp train --method iwp:vargate:2:8+nosel\n  \
         ringiwp train --method iwp:fixed+tern\n  \
         RINGIWP_METHOD=dgc:layerwise ringiwp exp --id density"
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let artifacts_dir = args.str_or("artifacts", "artifacts");
    match Runtime::cpu(&artifacts_dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts ({artifacts_dir}):");
            for name in rt.available()? {
                let art = rt.load(&name)?;
                println!(
                    "  {name:<28} kind={:<12} inputs={} outputs={}",
                    art.meta.kind,
                    art.meta.inputs.len(),
                    art.meta.outputs.len()
                );
            }
        }
        Err(e) => println!("no runtime: {e}"),
    }
    println!("\nzoo inventories:");
    for layout in [zoo::alexnet(), zoo::resnet50()] {
        println!(
            "  {:<10} {:>4} layers, {:>11} params ({})",
            layout.model,
            layout.n_layers(),
            layout.total_params(),
            human_bytes(layout.dense_bytes() as f64)
        );
    }
    Ok(())
}
