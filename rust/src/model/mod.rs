//! Model metadata: parameter layouts over flat buffers + the layer
//! inventories of the paper's evaluation models.

pub mod layout;
pub mod zoo;

pub use layout::{LayerInfo, LayerKind, ParamLayout};
