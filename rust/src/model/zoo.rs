//! Layer inventories of the paper's evaluation models.
//!
//! Table I and Figs. 2–4/7–8 are functions of *layer shapes* and wire
//! bytes, not of trained weights; we therefore carry the exact parameter
//! inventories of AlexNet (61.1M) and ResNet-50 (25.56M) — torchvision
//! definitions — and drive them with realistic synthetic gradients
//! (`grad::synth`).  DESIGN.md §2 records this substitution for the
//! ImageNet-scale experiments; the *accuracy* experiments train real
//! small models end-to-end instead.

use super::layout::{LayerKind, ParamLayout};

type Spec = (String, Vec<usize>, LayerKind);

fn conv(name: &str, out_ch: usize, in_ch: usize, k: usize) -> Spec {
    (name.into(), vec![out_ch, in_ch, k, k], LayerKind::Conv)
}

fn bias(name: &str, n: usize) -> Spec {
    (name.into(), vec![n], LayerKind::Bias)
}

fn bn(name: &str, ch: usize) -> Vec<Spec> {
    vec![
        (format!("{name}.weight"), vec![ch], LayerKind::BatchNorm),
        (format!("{name}.bias"), vec![ch], LayerKind::BatchNorm),
    ]
}

fn fc(name: &str, in_f: usize, out_f: usize) -> Vec<Spec> {
    vec![
        (format!("{name}.weight"), vec![out_f, in_f], LayerKind::Fc),
        (format!("{name}.bias"), vec![out_f], LayerKind::Bias),
    ]
}

/// AlexNet (torchvision) — 61,100,840 parameters.
pub fn alexnet() -> ParamLayout {
    let mut s: Vec<Spec> = Vec::new();
    for (name, o, i, k) in [
        ("features.conv1", 64, 3, 11),
        ("features.conv2", 192, 64, 5),
        ("features.conv3", 384, 192, 3),
        ("features.conv4", 256, 384, 3),
        ("features.conv5", 256, 256, 3),
    ] {
        s.push(conv(&format!("{name}.weight"), o, i, k));
        s.push(bias(&format!("{name}.bias"), o));
    }
    s.extend(fc("classifier.fc6", 256 * 6 * 6, 4096));
    s.extend(fc("classifier.fc7", 4096, 4096));
    s.extend(fc("classifier.fc8", 4096, 1000));
    ParamLayout::new("alexnet", s)
}

/// ResNet-50 (torchvision) — 25,557,032 parameters (incl. BN affine).
pub fn resnet50() -> ParamLayout {
    resnet("resnet50", [3, 4, 6, 3], 1000)
}

/// ResNet-101 — 44,549,160 parameters; the paper also evaluates
/// ResNet101 on CIFAR10 (10-class head).
pub fn resnet101_cifar10() -> ParamLayout {
    resnet("resnet101_cifar10", [3, 4, 23, 3], 10)
}

/// Bottleneck ResNet inventory generator.
fn resnet(name: &str, blocks: [usize; 4], n_classes: usize) -> ParamLayout {
    let mut s: Vec<Spec> = Vec::new();
    // Stem.
    s.push(conv("conv1.weight", 64, 3, 7));
    s.extend(bn("bn1", 64));

    // Bottleneck stages: (blocks, mid_ch, out_ch).
    let stages = [
        (blocks[0], 64usize, 256usize),
        (blocks[1], 128, 512),
        (blocks[2], 256, 1024),
        (blocks[3], 512, 2048),
    ];

    let mut in_ch = 64;
    for (si, (blocks, mid, out)) in stages.into_iter().enumerate() {
        for b in 0..blocks {
            let p = format!("layer{}.{}", si + 1, b);
            s.push(conv(&format!("{p}.conv1.weight"), mid, in_ch, 1));
            s.extend(bn(&format!("{p}.bn1"), mid));
            s.push(conv(&format!("{p}.conv2.weight"), mid, mid, 3));
            s.extend(bn(&format!("{p}.bn2"), mid));
            s.push(conv(&format!("{p}.conv3.weight"), out, mid, 1));
            s.extend(bn(&format!("{p}.bn3"), out));
            if b == 0 {
                // Downsample projection (the layer Fig. 4 tracks).
                s.push(conv(&format!("{p}.downsample.conv.weight"), out, in_ch, 1));
                s.extend(bn(&format!("{p}.downsample.bn"), out));
            }
            in_ch = out;
        }
    }
    s.extend(fc("fc", 2048, n_classes));
    ParamLayout::new(name, s)
}

/// Registry used by the CLI / experiment harness.
pub fn by_name(name: &str) -> anyhow::Result<ParamLayout> {
    match name {
        "alexnet" => Ok(alexnet()),
        "resnet50" => Ok(resnet50()),
        "resnet101" | "resnet101_cifar10" => Ok(resnet101_cifar10()),
        other => anyhow::bail!(
            "unknown zoo model `{other}` (alexnet|resnet50|resnet101)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_exact_param_count() {
        // torchvision.models.alexnet: 61,100,840
        assert_eq!(alexnet().total_params(), 61_100_840);
    }

    #[test]
    fn resnet50_exact_param_count() {
        // torchvision.models.resnet50 trainable params: 25,557,032
        assert_eq!(resnet50().total_params(), 25_557_032);
    }

    #[test]
    fn resnet50_has_downsample_layers() {
        let r = resnet50();
        let ds: Vec<_> = r
            .layers()
            .iter()
            .filter(|l| l.name.contains("downsample.conv"))
            .collect();
        assert_eq!(ds.len(), 4); // one per stage
        assert_eq!(ds[0].name, "layer1.0.downsample.conv.weight");
    }

    #[test]
    fn kind_mix() {
        let r = resnet50();
        assert!(r.of_kind(LayerKind::Conv).count() > 50);
        assert!(r.of_kind(LayerKind::BatchNorm).count() > 100);
        assert_eq!(r.of_kind(LayerKind::Fc).count(), 1);
    }

    #[test]
    fn resnet101_cifar10_param_count() {
        // torchvision resnet101 is 44,549,160 with a 1000-class head;
        // the CIFAR10 head replaces 2048x1000+1000 with 2048x10+10.
        let expect = 44_549_160 - (2048 * 1000 + 1000) + (2048 * 10 + 10);
        assert_eq!(resnet101_cifar10().total_params(), expect);
    }

    #[test]
    fn registry() {
        assert!(by_name("alexnet").is_ok());
        assert!(by_name("resnet50").is_ok());
        assert!(by_name("resnet101").is_ok());
        assert!(by_name("vgg").is_err());
    }
}
