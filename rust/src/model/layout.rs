//! `ParamLayout` — the bridge between flat f32 buffers (what the ring and
//! the compression pipeline move around) and the model's layer structure
//! (what the paper's *layer-wise* threshold controller needs).
//!
//! Layouts come from two sources: artifact manifests (`runtime::artifact`)
//! for the real PJRT-trained models, and `model::zoo` for the paper's
//! AlexNet/ResNet50 inventories used in the bandwidth experiments.

use crate::util::json::Json;

/// Layer taxonomy. The paper distinguishes conv vs batch-norm vs fc
/// importance distributions (Figs. 2/3); the zoo and the manifests map
/// onto this shared set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Convolution weight (OIHW).
    Conv,
    /// Batch-norm affine parameter (gain or bias).
    BatchNorm,
    /// Fully-connected weight.
    Fc,
    /// Plain bias vector.
    Bias,
    /// Embedding table.
    Embed,
    /// Attention projection weight.
    Attn,
    /// Layer-norm parameter.
    Norm,
}

impl LayerKind {
    /// Parse a manifest/zoo kind tag.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "bn" | "batchnorm" => LayerKind::BatchNorm,
            "fc" => LayerKind::Fc,
            "bias" => LayerKind::Bias,
            "embed" => LayerKind::Embed,
            "attn" => LayerKind::Attn,
            "norm" => LayerKind::Norm,
            other => anyhow::bail!("unknown layer kind `{other}`"),
        })
    }

    /// Canonical tag (inverse of [`LayerKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::BatchNorm => "bn",
            LayerKind::Fc => "fc",
            LayerKind::Bias => "bias",
            LayerKind::Embed => "embed",
            LayerKind::Attn => "attn",
            LayerKind::Norm => "norm",
        }
    }
}

/// One named parameter tensor inside the flat buffer.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    /// Tensor name (torchvision-style for zoo models).
    pub name: String,
    /// Tensor shape (OIHW for convs).
    pub shape: Vec<usize>,
    /// Layer taxonomy bucket.
    pub kind: LayerKind,
    /// Element count.
    pub size: usize,
    /// Start offset in the flat buffer.
    pub offset: usize,
}

impl LayerInfo {
    /// This layer's coordinate range in the flat buffer.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.size
    }

    /// Fan-in heuristic used by the synthetic gradient generator.
    pub fn fan_in(&self) -> usize {
        match self.shape.len() {
            0 | 1 => self.shape.first().copied().unwrap_or(1),
            2 => self.shape[0],
            // conv OIHW: in_ch * kh * kw
            _ => self.shape[1..].iter().product(),
        }
    }
}

/// Ordered layers tiling a flat parameter buffer without gaps.
#[derive(Debug, Clone)]
pub struct ParamLayout {
    /// Model name (zoo key or artifact model tag).
    pub model: String,
    layers: Vec<LayerInfo>,
    total: usize,
}

impl ParamLayout {
    /// Build a layout from ordered (name, shape, kind) specs; offsets
    /// tile contiguously in spec order.
    pub fn new(model: impl Into<String>, specs: Vec<(String, Vec<usize>, LayerKind)>) -> Self {
        let mut layers = Vec::with_capacity(specs.len());
        let mut offset = 0;
        for (name, shape, kind) in specs {
            let size = shape.iter().product::<usize>().max(1);
            layers.push(LayerInfo {
                name,
                shape,
                kind,
                size,
                offset,
            });
            offset += size;
        }
        ParamLayout {
            model: model.into(),
            layers,
            total: offset,
        }
    }

    /// Parse the `layers` array of an artifact manifest.
    pub fn from_manifest(model: &str, manifest: &Json) -> anyhow::Result<Self> {
        let mut specs = Vec::new();
        for layer in manifest.req_arr("layers")? {
            let name = layer.req_str("name")?.to_string();
            let shape: Vec<usize> = layer
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let kind = LayerKind::parse(layer.req_str("kind")?)?;
            specs.push((name, shape, kind));
        }
        let out = ParamLayout::new(model, specs);
        // Cross-check offsets against the manifest (they are redundant but
        // catching drift early beats silent corruption).
        for (ours, theirs) in out.layers.iter().zip(manifest.req_arr("layers")?) {
            let off = theirs.req_usize("offset")?;
            anyhow::ensure!(
                ours.offset == off,
                "manifest offset mismatch for `{}`: {} vs {}",
                ours.name,
                ours.offset,
                off
            );
        }
        Ok(out)
    }

    /// The ordered layers.
    pub fn layers(&self) -> &[LayerInfo] {
        &self.layers
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter count (== flat buffer length).
    pub fn total_params(&self) -> usize {
        self.total
    }

    /// Layer by index.
    pub fn layer(&self, i: usize) -> &LayerInfo {
        &self.layers[i]
    }

    /// Layer by tensor name, if present.
    pub fn by_name(&self, name: &str) -> Option<&LayerInfo> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Slice a flat buffer into per-layer sub-slices.
    pub fn split<'a>(&self, flat: &'a [f32]) -> Vec<&'a [f32]> {
        assert_eq!(flat.len(), self.total);
        self.layers.iter().map(|l| &flat[l.range()]).collect()
    }

    /// Layers of a given kind.
    pub fn of_kind(&self, kind: LayerKind) -> impl Iterator<Item = &LayerInfo> {
        self.layers.iter().filter(move |l| l.kind == kind)
    }

    /// Bytes of one dense fp32 gradient exchange.
    pub fn dense_bytes(&self) -> u64 {
        (self.total * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn toy() -> ParamLayout {
        ParamLayout::new(
            "toy",
            vec![
                ("a".into(), vec![2, 3], LayerKind::Fc),
                ("b".into(), vec![3], LayerKind::Bias),
                ("c".into(), vec![4, 1, 2, 2], LayerKind::Conv),
            ],
        )
    }

    #[test]
    fn offsets_tile_contiguously() {
        let l = toy();
        assert_eq!(l.total_params(), 6 + 3 + 16);
        assert_eq!(l.layer(0).offset, 0);
        assert_eq!(l.layer(1).offset, 6);
        assert_eq!(l.layer(2).offset, 9);
        assert_eq!(l.dense_bytes(), 25 * 4);
    }

    #[test]
    fn split_returns_layer_views() {
        let l = toy();
        let flat: Vec<f32> = (0..25).map(|i| i as f32).collect();
        let parts = l.split(&flat);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], &flat[0..6]);
        assert_eq!(parts[1], &flat[6..9]);
        assert_eq!(parts[2], &flat[9..25]);
    }

    #[test]
    fn fan_in_heuristics() {
        let l = toy();
        assert_eq!(l.layer(0).fan_in(), 2); // fc (in, out)
        assert_eq!(l.layer(2).fan_in(), 1 * 2 * 2); // conv OIHW
    }

    #[test]
    fn from_manifest_roundtrip() {
        let m = json::parse(
            r#"{"layers": [
                {"name": "x", "shape": [4, 2], "kind": "fc", "size": 8, "offset": 0},
                {"name": "y", "shape": [2], "kind": "bias", "size": 2, "offset": 8}
            ]}"#,
        )
        .unwrap();
        let l = ParamLayout::from_manifest("m", &m).unwrap();
        assert_eq!(l.total_params(), 10);
        assert_eq!(l.by_name("y").unwrap().kind, LayerKind::Bias);
    }

    #[test]
    fn from_manifest_rejects_bad_offset() {
        let m = json::parse(
            r#"{"layers": [
                {"name": "x", "shape": [4], "kind": "fc", "size": 4, "offset": 1}
            ]}"#,
        )
        .unwrap();
        assert!(ParamLayout::from_manifest("m", &m).is_err());
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [
            LayerKind::Conv,
            LayerKind::BatchNorm,
            LayerKind::Fc,
            LayerKind::Bias,
            LayerKind::Embed,
            LayerKind::Attn,
            LayerKind::Norm,
        ] {
            assert_eq!(LayerKind::parse(k.name()).unwrap(), k);
        }
        assert!(LayerKind::parse("quux").is_err());
    }
}
