//! `SparseVec` — (index, value) gradient representation used by the
//! per-node sparse path (DGC baseline) and by ring rounds that carry
//! values under a shared mask.

use super::mask::BitMask;
use super::{wire_bytes, WireFormat};

/// Sparse view of a length-`len` f32 vector.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVec {
    /// Logical (dense) length.
    pub len: usize,
    /// Ascending nonzero coordinates.
    pub idx: Vec<u32>,
    /// Values aligned with `idx`.
    pub val: Vec<f32>,
}

impl SparseVec {
    /// The empty sparse vector of logical length `len`.
    pub fn empty(len: usize) -> Self {
        SparseVec {
            len,
            idx: Vec::new(),
            val: Vec::new(),
        }
    }

    /// Gather the coordinates selected by `mask`.
    pub fn from_mask(dense: &[f32], mask: &BitMask) -> Self {
        assert_eq!(dense.len(), mask.len());
        let mut idx = Vec::with_capacity(mask.count());
        let mut val = Vec::with_capacity(idx.capacity());
        for i in mask.iter_set() {
            idx.push(i as u32);
            val.push(dense[i]);
        }
        SparseVec {
            len: dense.len(),
            idx,
            val,
        }
    }

    /// All nonzero coordinates.
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                idx.push(i as u32);
                val.push(v);
            }
        }
        SparseVec {
            len: dense.len(),
            idx,
            val,
        }
    }

    /// Top-k by |value| (the DGC selection rule). Deterministic tie-break
    /// by index. k is clamped to len.
    pub fn top_k(dense: &[f32], k: usize) -> Self {
        let k = k.min(dense.len());
        if k == 0 {
            return SparseVec::empty(dense.len());
        }
        // Select the k largest |v| via partial sort of indices.
        let mut order: Vec<u32> = (0..dense.len() as u32).collect();
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            let (va, vb) = (dense[a as usize].abs(), dense[b as usize].abs());
            vb.partial_cmp(&va)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut idx: Vec<u32> = order[..k].to_vec();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| dense[i as usize]).collect();
        SparseVec {
            len: dense.len(),
            idx,
            val,
        }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Stored fraction `nnz / len` (0 for the zero-length vector).
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.len as f64
        }
    }

    /// Dense reconstruction.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.scatter_into(&mut out);
        out
    }

    /// out[idx] = val (overwrite).
    pub fn scatter_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
    }

    /// out[idx] += val — the reduce step of sparse ring all-reduce.
    pub fn scatter_add(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += v;
        }
    }

    /// Merge-add two sparse vectors (union support, summed values).
    /// Both inputs must have ascending indices; output is ascending.
    pub fn merge_add(&self, other: &SparseVec) -> SparseVec {
        let mut out = SparseVec::empty(self.len);
        self.merge_add_into(other, &mut out);
        out
    }

    /// Reset to an empty sparse vector of logical length `len`, keeping
    /// the allocated index/value capacity (arena reuse).
    pub fn clear_to(&mut self, len: usize) {
        self.len = len;
        self.idx.clear();
        self.val.clear();
    }

    /// Re-extract `src`'s slice of `range` into `self` — the per-hop
    /// segment gather of the sparse ring schedule, reusing `self`'s
    /// buffers. Indices are rebased to `range.start`. Returns `true`
    /// when an internal buffer had to reallocate (arena accounting).
    pub fn assign_window(&mut self, src: &SparseVec, range: &std::ops::Range<usize>) -> bool {
        let caps = (self.idx.capacity(), self.val.capacity());
        self.clear_to(range.len());
        for (&i, &v) in src.idx.iter().zip(&src.val) {
            let i = i as usize;
            if range.contains(&i) {
                self.idx.push((i - range.start) as u32);
                self.val.push(v);
            }
        }
        caps != (self.idx.capacity(), self.val.capacity())
    }

    /// [`SparseVec::merge_add`] writing into a caller-owned `out`
    /// (buffer reuse; `out` must be a distinct object). The summed value
    /// on overlaps adds `self`'s value first, exactly as `merge_add`.
    /// Returns `true` when `out` had to reallocate.
    pub fn merge_add_into(&self, other: &SparseVec, out: &mut SparseVec) -> bool {
        assert_eq!(self.len, other.len);
        let caps = (out.idx.capacity(), out.val.capacity());
        out.clear_to(self.len);
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.nnz() || b < other.nnz() {
            let ia = self.idx.get(a).copied().unwrap_or(u32::MAX);
            let ib = other.idx.get(b).copied().unwrap_or(u32::MAX);
            match ia.cmp(&ib) {
                std::cmp::Ordering::Less => {
                    out.idx.push(ia);
                    out.val.push(self.val[a]);
                    a += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.idx.push(ib);
                    out.val.push(other.val[b]);
                    b += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.idx.push(ia);
                    out.val.push(self.val[a] + other.val[b]);
                    a += 1;
                    b += 1;
                }
            }
        }
        caps != (out.idx.capacity(), out.val.capacity())
    }

    /// Wire bytes under the cheapest codec for this density.
    pub fn wire_bytes(&self) -> u64 {
        wire_bytes(
            WireFormat::cheapest(self.len, self.nnz()),
            self.len,
            self.nnz(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn from_dense_roundtrip() {
        let d = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseVec::from_dense(&d);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    fn from_mask_gathers_selected() {
        let d = vec![1.0, 2.0, 3.0, 4.0];
        let mut m = BitMask::zeros(4);
        m.set(1);
        m.set(3);
        let s = SparseVec::from_mask(&d, &m);
        assert_eq!(s.idx, vec![1, 3]);
        assert_eq!(s.val, vec![2.0, 4.0]);
    }

    #[test]
    fn top_k_selects_largest_magnitudes() {
        let d = vec![0.1, -5.0, 3.0, 0.2, -0.05];
        let s = SparseVec::top_k(&d, 2);
        assert_eq!(s.idx, vec![1, 2]);
        assert_eq!(s.val, vec![-5.0, 3.0]);
    }

    #[test]
    fn top_k_edge_cases() {
        let d = vec![1.0, 2.0];
        assert_eq!(SparseVec::top_k(&d, 0).nnz(), 0);
        assert_eq!(SparseVec::top_k(&d, 10).nnz(), 2);
    }

    #[test]
    fn scatter_add_accumulates() {
        let s = SparseVec {
            len: 4,
            idx: vec![0, 2],
            val: vec![1.0, 2.0],
        };
        let mut out = vec![10.0, 10.0, 10.0, 10.0];
        s.scatter_add(&mut out);
        assert_eq!(out, vec![11.0, 10.0, 12.0, 10.0]);
    }

    #[test]
    fn merge_add_property() {
        forall("merge_add == dense add", 100, |g| {
            let len = g.usize_in(1, 300);
            let a_dense = g.vec_sparse(len, 0.2);
            let b_dense = g.vec_sparse(len, 0.2);
            let a = SparseVec::from_dense(&a_dense);
            let b = SparseVec::from_dense(&b_dense);
            let merged = a.merge_add(&b).to_dense();
            let expect: Vec<f32> = a_dense
                .iter()
                .zip(&b_dense)
                .map(|(x, y)| x + y)
                .collect();
            assert_eq!(merged, expect);
        });
    }

    #[test]
    fn top_k_matches_sort_property() {
        forall("top_k == full-sort top-k", 60, |g| {
            let len = g.usize_in(1, 200);
            let d = g.vec_normal(len, 0.0, 1.0);
            let k = g.usize_in(0, len + 1);
            let s = SparseVec::top_k(&d, k);
            let mut mags: Vec<f32> = d.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let kth = if k == 0 { f32::INFINITY } else { mags[k.min(len) - 1] };
            // Every selected magnitude >= every unselected magnitude.
            let sel: std::collections::HashSet<u32> = s.idx.iter().copied().collect();
            for (i, &v) in d.iter().enumerate() {
                if !sel.contains(&(i as u32)) {
                    assert!(
                        v.abs() <= kth + 1e-6,
                        "unselected {} > kth {}",
                        v.abs(),
                        kth
                    );
                }
            }
            assert_eq!(s.nnz(), k.min(len));
        });
    }

    #[test]
    fn merge_add_into_matches_merge_add_and_reuses_buffers() {
        forall("merge_add_into == merge_add", 60, |g| {
            let len = g.usize_in(1, 200);
            let a = SparseVec::from_dense(&g.vec_sparse(len, 0.3));
            let b = SparseVec::from_dense(&g.vec_sparse(len, 0.3));
            let fresh = a.merge_add(&b);
            let mut out = SparseVec::empty(0);
            a.merge_add_into(&b, &mut out);
            assert_eq!(out, fresh);
            // Second merge into the warmed buffer must not reallocate.
            assert!(!a.merge_add_into(&b, &mut out));
            assert_eq!(out, fresh);
        });
    }

    #[test]
    fn assign_window_extracts_and_rebases() {
        let d = vec![0.0f32, 1.0, 0.0, 3.0, 4.0, 0.0, 6.0];
        let s = SparseVec::from_dense(&d);
        let mut seg = SparseVec::empty(0);
        seg.assign_window(&s, &(2..5));
        assert_eq!(seg.len, 3);
        assert_eq!(seg.idx, vec![1, 2]);
        assert_eq!(seg.val, vec![3.0, 4.0]);
        // Warm buffer: repeating the same extraction never reallocates.
        assert!(!seg.assign_window(&s, &(2..5)));
        // Empty window.
        seg.assign_window(&s, &(0..0));
        assert_eq!(seg.nnz(), 0);
        assert_eq!(seg.len, 0);
    }

    #[test]
    fn wire_bytes_picks_cheap_codec() {
        let mut d = vec![0.0f32; 10_000];
        d[5] = 1.0;
        let s = SparseVec::from_dense(&d);
        assert!(s.wire_bytes() < 100); // pairs, not bitmap/dense
    }
}
