//! `BitMask` — packed transmit-mask, the wire object of Algorithm 1's
//! `AllGather(encode_uint8(Mask))` / `Mask = OR(Mask_r)` steps.

/// Packed bitmask over `len` coordinates (u64 words).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMask {
    len: usize,
    words: Vec<u64>,
}

impl BitMask {
    /// All-clear mask over `len` coordinates.
    pub fn zeros(len: usize) -> Self {
        BitMask {
            len,
            words: vec![0; len.div_ceil(64)],
        }
    }

    /// Build from the L1 kernel's f32 0/1 mask output.
    pub fn from_f32(mask: &[f32]) -> Self {
        let mut m = BitMask::zeros(mask.len());
        for (i, &v) in mask.iter().enumerate() {
            if v != 0.0 {
                m.set(i);
            }
        }
        m
    }

    /// Build by thresholding importance scores (CPU mirror of the kernel).
    pub fn from_threshold(imp: &[f32], thr: f32) -> Self {
        let mut m = BitMask::zeros(imp.len());
        for (i, &v) in imp.iter().enumerate() {
            if v > thr {
                m.set(i);
            }
        }
        m
    }

    /// Number of coordinates this mask covers.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the mask covers zero coordinates.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Select coordinate `i`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1 << (i % 64);
    }

    /// Deselect coordinate `i`.
    #[inline]
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] &= !(1 << (i % 64));
    }

    /// Whether coordinate `i` is selected.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Word-at-a-time OR — Algorithm 1's mask union.
    pub fn or_assign(&mut self, other: &BitMask) {
        assert_eq!(self.len, other.len, "mask length mismatch");
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Word-at-a-time AND — mask intersection.
    pub fn and_assign(&mut self, other: &BitMask) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// Population count (selected coordinates).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Selected fraction.
    pub fn density(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.count() as f64 / self.len as f64
        }
    }

    /// Clear every bit in place, keeping the allocation (the fused
    /// scoring paths reuse per-broadcaster mask slots across steps —
    /// `compress::fuse`, DESIGN.md §11).
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Raw mutable word view for bulk writers that fully overwrite the
    /// mask (the fused kernel packs selection bits word-at-a-time instead
    /// of calling [`BitMask::set`] per coordinate).
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Raw word view for bulk readers that walk the support word-at-a-
    /// time (`compress::fuse::take_compact` extracts set bits with
    /// `trailing_zeros` instead of driving the per-bit iterator).
    pub(crate) fn words(&self) -> &[u64] {
        &self.words
    }

    /// Iterate set indices in ascending order.
    pub fn iter_set(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(move |(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    // ---- wire codec (Algorithm 1's encode_uint8) ----------------------

    /// Pack to bytes: little-endian u64 words truncated to ceil(len/8).
    pub fn encode_u8(&self) -> Vec<u8> {
        let n_bytes = self.len.div_ceil(8);
        let mut out = Vec::with_capacity(n_bytes);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.truncate(n_bytes);
        out
    }

    /// Inverse of [`BitMask::encode_u8`]; rejects wrong byte lengths and
    /// zeroes any padding bits past `len`.
    pub fn decode_u8(bytes: &[u8], len: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(
            bytes.len() == len.div_ceil(8),
            "mask byte length {} != expected {}",
            bytes.len(),
            len.div_ceil(8)
        );
        let mut words = vec![0u64; len.div_ceil(64)];
        for (i, &b) in bytes.iter().enumerate() {
            words[i / 8] |= (b as u64) << ((i % 8) * 8);
        }
        // Zero any bits past `len` (robustness against dirty padding).
        let tail = len % 64;
        if tail != 0 {
            if let Some(last) = words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
        Ok(BitMask { len, words })
    }

    /// Wire bytes of this mask.
    pub fn wire_bytes(&self) -> u64 {
        self.len.div_ceil(8) as u64
    }

    /// Raw word view of a word-aligned coordinate range (the support-only
    /// ring fast path uses `chunk_ranges_aligned` so chunk supports are
    /// direct word slices). `range.start` must be a multiple of 64.
    pub fn word_slice(&self, range: std::ops::Range<usize>) -> &[u64] {
        if range.is_empty() {
            // Degenerate trailing chunks of `chunk_ranges_aligned` are
            // `len..len`, whose start need not be word-aligned.
            return &[];
        }
        assert_eq!(range.start % 64, 0, "unaligned word_slice start");
        assert!(range.end <= self.len);
        &self.words[range.start / 64..range.end.div_ceil(64)]
    }

    /// Set-bit count of a slice of words.
    pub fn popcount_words(words: &[u64]) -> usize {
        words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn set_get_clear() {
        let mut m = BitMask::zeros(130);
        m.set(0);
        m.set(64);
        m.set(129);
        assert!(m.get(0) && m.get(64) && m.get(129));
        assert!(!m.get(1) && !m.get(128));
        assert_eq!(m.count(), 3);
        m.clear(64);
        assert!(!m.get(64));
        assert_eq!(m.count(), 2);
    }

    #[test]
    fn or_is_union() {
        let mut a = BitMask::zeros(100);
        let mut b = BitMask::zeros(100);
        a.set(3);
        b.set(97);
        b.set(3);
        a.or_assign(&b);
        assert_eq!(a.iter_set().collect::<Vec<_>>(), vec![3, 97]);
    }

    #[test]
    fn from_f32_and_threshold_agree() {
        let imp = [0.1f32, 0.0, 0.5, 0.04, 0.06];
        let as_f32: Vec<f32> = imp.iter().map(|&v| (v > 0.05) as u8 as f32).collect();
        assert_eq!(
            BitMask::from_f32(&as_f32),
            BitMask::from_threshold(&imp, 0.05)
        );
    }

    #[test]
    fn codec_roundtrip_property() {
        forall("bitmask u8 codec roundtrip", 100, |g| {
            let len = g.usize_in(1, 2000);
            let mut m = BitMask::zeros(len);
            let n_set = g.usize_in(0, len.max(2));
            for _ in 0..n_set {
                m.set(g.usize_in(0, len));
            }
            let bytes = m.encode_u8();
            assert_eq!(bytes.len(), len.div_ceil(8));
            let back = BitMask::decode_u8(&bytes, len).unwrap();
            assert_eq!(m, back);
        });
    }

    #[test]
    fn or_matches_elementwise_property() {
        forall("word-level OR == element OR", 50, |g| {
            let len = g.usize_in(1, 500);
            let mut a = BitMask::zeros(len);
            let mut b = BitMask::zeros(len);
            for i in 0..len {
                if g.bool() {
                    a.set(i);
                }
                if g.bool() {
                    b.set(i);
                }
            }
            let mut c = a.clone();
            c.or_assign(&b);
            for i in 0..len {
                assert_eq!(c.get(i), a.get(i) || b.get(i));
            }
        });
    }

    #[test]
    fn decode_rejects_bad_length() {
        assert!(BitMask::decode_u8(&[0u8; 3], 100).is_err());
    }

    #[test]
    fn iter_set_ascending() {
        let mut m = BitMask::zeros(200);
        for i in [5usize, 63, 64, 65, 199] {
            m.set(i);
        }
        assert_eq!(m.iter_set().collect::<Vec<_>>(), vec![5, 63, 64, 65, 199]);
    }

    #[test]
    fn density() {
        let mut m = BitMask::zeros(1000);
        for i in 0..10 {
            m.set(i * 100);
        }
        assert!((m.density() - 0.01).abs() < 1e-12);
    }
}
