//! Sparse gradient codecs + wire-size accounting.
//!
//! Everything the paper's bandwidth numbers rest on: how a pruned gradient
//! is represented on the wire. Three encodings, chosen per message by
//! actual byte cost (`WireFormat::cheapest`):
//!
//! * `Pairs` — (u32 index, f32 value) per nonzero: best when very sparse.
//! * `Bitmap` — 1 bit/coordinate + packed f32 values: best at ≥ ~3%
//!   density, and the natural mate of Algorithm 1's shared mask (the mask
//!   travels once as a bitmap, the values alone afterwards).
//! * `Dense` — raw f32s: the fallback that keeps "compressed" never worse
//!   than baseline.
//!
//! `BitMask` is the `encode_uint8(Mask)` of Algorithm 1 — masks AllGather
//! around the ring as packed bytes and are OR-combined.

pub mod mask;
pub mod vec;

pub use mask::BitMask;
pub use vec::SparseVec;

/// Wire encodings for one gradient message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFormat {
    /// (u32 index, f32 value) per nonzero — best when very sparse.
    Pairs,
    /// 1 bit/coordinate + packed f32 values — best at ≥ ~3% density.
    Bitmap,
    /// Raw f32s — the never-worse-than-baseline fallback.
    Dense,
}

/// Fixed per-message header: format tag + element count (u8 + u32 + u32 nnz).
pub const HEADER_BYTES: u64 = 9;

/// Wire size of `nnz` nonzeros out of `len` coordinates, per format.
pub fn wire_bytes(format: WireFormat, len: usize, nnz: usize) -> u64 {
    HEADER_BYTES
        + match format {
            WireFormat::Pairs => (nnz as u64) * 8,
            WireFormat::Bitmap => (len as u64).div_ceil(8) + (nnz as u64) * 4,
            WireFormat::Dense => (len as u64) * 4,
        }
}

impl WireFormat {
    /// Cheapest format for the given density.
    pub fn cheapest(len: usize, nnz: usize) -> WireFormat {
        let mut best = WireFormat::Dense;
        let mut best_bytes = wire_bytes(WireFormat::Dense, len, nnz);
        for f in [WireFormat::Pairs, WireFormat::Bitmap] {
            let b = wire_bytes(f, len, nnz);
            if b < best_bytes {
                best = f;
                best_bytes = b;
            }
        }
        best
    }
}

/// Bytes for transmitting only the values under an *already shared* mask
/// (Algorithm 1: after the mask AllGather, ring rounds carry values only).
pub fn values_only_bytes(nnz: usize) -> u64 {
    HEADER_BYTES + (nnz as u64) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_win_when_very_sparse() {
        assert_eq!(WireFormat::cheapest(1_000_000, 100), WireFormat::Pairs);
    }

    #[test]
    fn bitmap_wins_at_moderate_density() {
        // 5% density: pairs = 8*50k = 400k; bitmap = 125k + 200k = 325k.
        assert_eq!(WireFormat::cheapest(1_000_000, 50_000), WireFormat::Bitmap);
    }

    #[test]
    fn dense_wins_when_dense() {
        assert_eq!(WireFormat::cheapest(1000, 999), WireFormat::Dense);
    }

    #[test]
    fn wire_bytes_formulas() {
        assert_eq!(wire_bytes(WireFormat::Dense, 100, 0), HEADER_BYTES + 400);
        assert_eq!(wire_bytes(WireFormat::Pairs, 100, 10), HEADER_BYTES + 80);
        assert_eq!(
            wire_bytes(WireFormat::Bitmap, 100, 10),
            HEADER_BYTES + 13 + 40
        );
    }
}
