//! `ringiwp` — Importance-Weighted Pruning on Ring AllReduce.
//!
//! A full reproduction of Cheng & Xu (2019), *Bandwidth Reduction using
//! Importance Weighted Pruning on Ring AllReduce*, as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: ring
//!   all-reduce schedules (dense / sparse / shared-mask), the compression
//!   policies (IWP fixed & layerwise, DGC top-k, TernGrad), a virtual-time
//!   network simulator with per-link I/O traces, the multi-node trainer,
//!   and one experiment harness per paper table/figure.
//! * **L2** — JAX train-step graphs (MLP classifier, char-LM transformer),
//!   AOT-lowered to HLO text under `artifacts/`.
//! * **L1** — the Pallas importance kernel (fused score + mask + layer
//!   stats), called from L2 so it lowers into the same HLO.
//!
//! Python runs only at `make artifacts`; the request path (training steps,
//! ring rounds) is pure Rust + PJRT.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

#![warn(missing_docs)]

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod grad;
pub mod metrics;
pub mod model;
pub mod net;
pub mod optim;
pub mod ring;
pub mod runtime;
pub mod sparse;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
