//! Run configuration: CLI flags + key=value config files + presets.
//!
//! serde/toml are unreachable offline, so the file format is a strict
//! `key = value` subset (one pair per line, `#` comments) — enough for
//! reproducible experiment configs checked into `configs/`.

use std::collections::BTreeMap;

use crate::compress::{Method, MethodSpec};
use crate::net::{ChaosPlan, FaultPlan, RecoveryMode, TopoKind, TransportKind, TunerMode};
use crate::util::cli::Args;

/// Everything a training / experiment run needs.
#[derive(Debug, Clone)]
pub struct Config {
    /// Simulated ring size.
    pub nodes: usize,
    /// `mlp` | `tfm_tiny` | zoo names for synthetic runs.
    pub model: String,
    /// Compression pipeline (`compress::spec` grammar, DESIGN.md §12:
    /// heads `dense | terngrad | iwp:* | dgc:*` plus `+stage` suffixes;
    /// legacy Table-I names are accepted as aliases). The CLI flag, the
    /// config-file key, and the `RINGIWP_METHOD` environment default
    /// all parse through [`MethodSpec::parse`] — one validated entry
    /// point. Precedence: flag > config file > env > built-in default.
    pub method: MethodSpec,
    /// Importance threshold (α for layerwise).
    pub threshold: f32,
    /// Eq. 4 dispersion gain β.
    pub beta: f32,
    /// Eq. 4 crossover C.
    pub c: f32,
    /// Number of random mask-broadcast nodes r (Alg. 1).
    pub mask_nodes: usize,
    /// Random gradient selection on/off (Sec. III-C).
    pub random_select: bool,
    /// SGD / residual-store momentum m.
    pub momentum: f32,
    /// Base learning rate η.
    pub lr: f32,
    /// Total training steps.
    pub steps: usize,
    /// Per-node batch size.
    pub batch_size: usize,
    /// Steps per "epoch" for epoch-indexed schedules (small-scale stand-in).
    pub steps_per_epoch: usize,
    /// Warm-up epochs for thresholds / DGC density ramps.
    pub warmup_epochs: usize,
    /// Per-step local gradient clip (global L2; 0 disables).
    pub clip_norm: f32,
    /// DGC baseline density.
    pub dgc_density: f64,
    /// Root seed for every stochastic stream.
    pub seed: u64,
    /// Link bandwidth in MB/s (gigabit usable by default).
    pub bandwidth_mbps: f64,
    /// Link latency in microseconds.
    pub latency_us: f64,
    /// Worker threads for the node-parallel execution engine
    /// (`ring::exec`, DESIGN.md §4). 1 = sequential oracle; results are
    /// bit-identical at any setting.
    pub parallelism: usize,
    /// Communication topology of the reduce (`net::topo`, DESIGN.md
    /// §10): `flat` | `hier:<group_size>` | `tree`. Flat is the paper's
    /// testbed and the pre-topology behaviour, bit for bit.
    pub topology: TopoKind,
    /// Payload transport (`net::wire`, DESIGN.md §13): `sim` keeps
    /// everything virtual; `uds` | `tcp` route every traveling payload
    /// through a real socket ring whose decoded frames must reproduce
    /// the simulator bit for bit. Defaults from `RINGIWP_TRANSPORT`.
    pub transport: TransportKind,
    /// Online protocol autotuner (`net::tuner`, DESIGN.md §14):
    /// `off` | `on` | `log-only`. `on` replaces the static wire-format
    /// / topology / chunking choice with the per-step `CostModel`
    /// argmin; `log-only` records the decisions while the static
    /// strategy keeps executing. Defaults from `RINGIWP_TUNER`.
    pub tuner: TunerMode,
    /// Deterministic fault-injection schedule (`net::chaos`, DESIGN.md
    /// §15): `--chaos <grammar>` | `--chaos-seed N` | `RINGIWP_CHAOS`.
    /// Only `ringiwp chaos` executes plans — `train`/`exp`/`bench`
    /// refuse them rather than silently reporting faulted results.
    pub chaos: Option<ChaosPlan>,
    /// Socket read/connect deadline in milliseconds for the real wire
    /// ring (`net::wire`, DESIGN.md §16): `--wire-timeout-ms N` |
    /// `RINGIWP_WIRE_TIMEOUT_MS`. The ARQ retransmit and ACK deadlines
    /// derive from it, so shrinking it speeds up drop-fault recovery in
    /// tests. Must be > 0; default 30 000 (the pre-§16 constant).
    pub wire_timeout_ms: u64,
    /// Seeded byte-level wire-fault schedule (`net::wire::fault`,
    /// DESIGN.md §16): `--wire-faults <grammar>` | `RINGIWP_WIRE_FAULTS`.
    /// Overrides any wire tokens riding in `--chaos`. Like chaos plans,
    /// only `ringiwp chaos` executes one — `train`/`exp`/`bench` refuse.
    pub wire_faults: Option<FaultPlan>,
    /// Artifact directory (`make artifacts` output).
    pub artifacts_dir: String,
    /// Output directory for CSVs and logs.
    pub out_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nodes: 4,
            model: "mlp".into(),
            method: MethodSpec::from_env_or(Method::IwpLayerwise.spec()),
            threshold: 0.01,
            beta: 0.002,
            c: 1.0,
            mask_nodes: 2,
            random_select: true,
            momentum: 0.9,
            lr: 0.05,
            steps: 200,
            batch_size: 32,
            steps_per_epoch: 50,
            warmup_epochs: 1,
            clip_norm: 5.0,
            dgc_density: 0.01,
            seed: 42,
            bandwidth_mbps: 117.0 * 1.048576, // gigabit usable, in MB/s
            latency_us: 100.0,
            parallelism: 1,
            topology: TopoKind::Flat,
            transport: TransportKind::from_env(),
            tuner: TunerMode::from_env(),
            chaos: ChaosPlan::from_env(),
            wire_timeout_ms: crate::net::wire::wire_timeout_from_env(),
            wire_faults: FaultPlan::from_env(),
            artifacts_dir: "artifacts".into(),
            out_dir: "results".into(),
        }
    }
}

impl Config {
    /// Apply CLI flag overrides on top of `self`.
    pub fn apply_args(mut self, a: &Args) -> anyhow::Result<Self> {
        if let Some(path) = a.str_opt("config") {
            let text = std::fs::read_to_string(path)?;
            self = self.apply_kv(&parse_kv(&text)?)?;
        }
        self.nodes = a.usize_or("nodes", self.nodes);
        self.model = a.str_or("model", &self.model);
        if let Some(m) = a.str_opt("method") {
            self.method = MethodSpec::parse(m)?;
        }
        self.threshold = a.f64_or("thr", self.threshold as f64) as f32;
        self.beta = a.f64_or("beta", self.beta as f64) as f32;
        self.c = a.f64_or("c", self.c as f64) as f32;
        self.mask_nodes = a.usize_or("mask-nodes", self.mask_nodes);
        if a.switch("no-random-select") {
            self.random_select = false;
        }
        self.momentum = a.f64_or("momentum", self.momentum as f64) as f32;
        self.lr = a.f64_or("lr", self.lr as f64) as f32;
        self.steps = a.usize_or("steps", self.steps);
        self.batch_size = a.usize_or("batch", self.batch_size);
        self.steps_per_epoch = a.usize_or("steps-per-epoch", self.steps_per_epoch);
        self.warmup_epochs = a.usize_or("warmup-epochs", self.warmup_epochs);
        self.clip_norm = a.f64_or("clip", self.clip_norm as f64) as f32;
        self.dgc_density = a.f64_or("dgc-density", self.dgc_density);
        self.seed = a.u64_or("seed", self.seed);
        self.bandwidth_mbps = a.f64_or("bandwidth-mbps", self.bandwidth_mbps);
        self.latency_us = a.f64_or("latency-us", self.latency_us);
        self.parallelism = a.usize_or("parallelism", self.parallelism);
        if let Some(t) = a.str_opt("topology") {
            self.topology = TopoKind::parse(t)?;
        }
        if let Some(t) = a.str_opt("transport") {
            self.transport = TransportKind::parse(t)?;
        }
        if let Some(t) = a.str_opt("tuner") {
            self.tuner = TunerMode::parse(t)?;
        }
        if let Some(g) = a.str_opt("chaos") {
            self.chaos = Some(ChaosPlan::parse(g).map_err(|e| anyhow::anyhow!(e))?);
        }
        // Seeded generation runs after --nodes/--steps so the schedule
        // covers the ring and step range actually being run.
        if let Some(s) = a.str_opt("chaos-seed") {
            let seed: u64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("--chaos-seed expects an integer"))?;
            self.chaos = Some(ChaosPlan::generate(seed, self.nodes, self.steps));
        }
        if let Some(m) = a.str_opt("chaos-mode") {
            let mode = RecoveryMode::parse(m)
                .ok_or_else(|| anyhow::anyhow!("--chaos-mode expects handoff|rescale"))?;
            self.chaos.get_or_insert_with(ChaosPlan::none).mode = mode;
        }
        self.wire_timeout_ms = a.u64_or("wire-timeout-ms", self.wire_timeout_ms);
        if let Some(g) = a.str_opt("wire-faults") {
            self.wire_faults = Some(FaultPlan::parse(g).map_err(|e| anyhow::anyhow!(e))?);
        }
        self.artifacts_dir = a.str_or("artifacts", &self.artifacts_dir);
        self.out_dir = a.str_or("out", &self.out_dir);
        self.validate()?;
        Ok(self)
    }

    fn apply_kv(mut self, kv: &BTreeMap<String, String>) -> anyhow::Result<Self> {
        for (k, v) in kv {
            match k.as_str() {
                "nodes" => self.nodes = v.parse()?,
                "model" => self.model = v.clone(),
                "method" => self.method = MethodSpec::parse(v)?,
                "threshold" | "thr" => self.threshold = v.parse()?,
                "beta" => self.beta = v.parse()?,
                "c" => self.c = v.parse()?,
                "mask_nodes" => self.mask_nodes = v.parse()?,
                "random_select" => self.random_select = v.parse()?,
                "momentum" => self.momentum = v.parse()?,
                "lr" => self.lr = v.parse()?,
                "steps" => self.steps = v.parse()?,
                "batch_size" => self.batch_size = v.parse()?,
                "steps_per_epoch" => self.steps_per_epoch = v.parse()?,
                "warmup_epochs" => self.warmup_epochs = v.parse()?,
                "clip_norm" => self.clip_norm = v.parse()?,
                "dgc_density" => self.dgc_density = v.parse()?,
                "seed" => self.seed = v.parse()?,
                "bandwidth_mbps" => self.bandwidth_mbps = v.parse()?,
                "latency_us" => self.latency_us = v.parse()?,
                "parallelism" => self.parallelism = v.parse()?,
                "topology" => self.topology = TopoKind::parse(v)?,
                "transport" => self.transport = TransportKind::parse(v)?,
                "tuner" => self.tuner = TunerMode::parse(v)?,
                "chaos" => {
                    self.chaos = Some(ChaosPlan::parse(v).map_err(|e| anyhow::anyhow!(e))?)
                }
                "wire_timeout_ms" => self.wire_timeout_ms = v.parse()?,
                "wire_faults" => {
                    self.wire_faults = Some(FaultPlan::parse(v).map_err(|e| anyhow::anyhow!(e))?)
                }
                "artifacts_dir" => self.artifacts_dir = v.clone(),
                "out_dir" => self.out_dir = v.clone(),
                other => anyhow::bail!("unknown config key `{other}`"),
            }
        }
        Ok(self)
    }

    /// Reject out-of-range values with actionable messages.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.nodes >= 2, "nodes must be >= 2");
        anyhow::ensure!(self.threshold >= 0.0, "threshold must be >= 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.momentum),
            "momentum must be in [0,1)"
        );
        anyhow::ensure!(self.lr > 0.0, "lr must be > 0");
        anyhow::ensure!(
            self.mask_nodes >= 1 && self.mask_nodes <= self.nodes,
            "mask_nodes must be in [1, nodes]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.dgc_density),
            "dgc_density must be in [0,1]"
        );
        anyhow::ensure!(self.steps_per_epoch > 0, "steps_per_epoch must be > 0");
        anyhow::ensure!(self.parallelism >= 1, "parallelism must be >= 1");
        if let Some(p) = &self.chaos {
            p.validate(self.nodes).map_err(|e| anyhow::anyhow!(e))?;
        }
        anyhow::ensure!(self.wire_timeout_ms > 0, "wire_timeout_ms must be > 0");
        if let Some(p) = &self.wire_faults {
            p.validate().map_err(|e| anyhow::anyhow!(e))?;
        }
        self.method.validate()?;
        self.topology.validate()?;
        if self.tuner != TunerMode::Off {
            anyhow::ensure!(
                matches!(self.method.head, crate::compress::SpecHead::Iwp(_)),
                "--tuner {} needs a shared-mask method (iwp:*); `{}` has no \
                 mask observation to tune on",
                self.tuner.name(),
                self.method.name()
            );
        }
        Ok(())
    }

    /// Executor for the node-parallel engine at this config's width.
    pub fn executor(&self) -> crate::ring::Executor {
        crate::ring::Executor::new(self.parallelism)
    }

    /// The link model in SI units.
    pub fn link_spec(&self) -> crate::net::LinkSpec {
        crate::net::LinkSpec::new(self.bandwidth_mbps * 1e6, self.latency_us * 1e-6)
    }

    /// Epoch index of a step under `steps_per_epoch`.
    pub fn epoch_of(&self, step: usize) -> usize {
        step / self.steps_per_epoch
    }
}

/// Parse `key = value` lines (# comments, blank lines ok).
pub fn parse_kv(text: &str) -> anyhow::Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("config line {}: missing `=`", ln + 1))?;
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn kv_parsing() {
        let kv = parse_kv("# comment\nnodes = 8\n\nmethod = dgc\n").unwrap();
        assert_eq!(kv["nodes"], "8");
        let cfg = Config::default().apply_kv(&kv).unwrap();
        assert_eq!(cfg.nodes, 8);
        assert_eq!(cfg.method, Method::Dgc.spec());
    }

    #[test]
    fn method_spec_grammar_flows_from_flag_and_file() {
        // Flag, config-file key, and env default all route through the
        // one validated entry point (`MethodSpec::parse`), so the new
        // spec grammar works everywhere the legacy names did.
        let a = Args::parse(
            ["train", "--method", "iwp:vargate+nosel"]
                .into_iter()
                .map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.method, MethodSpec::parse("iwp:vargate+nosel").unwrap());
        let kv = parse_kv("method = dgc:layerwise+warmup:2").unwrap();
        let cfg = Config::default().apply_kv(&kv).unwrap();
        assert_eq!(cfg.method.name(), "dgc:layerwise+warmup:2");
        // Malformed specs are rejected at the same entry point.
        let a = Args::parse(
            ["train", "--method", "iwp:fixed+bogus"]
                .into_iter()
                .map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err());
        assert!(Config::default().apply_kv(&parse_kv("method = mesh").unwrap()).is_err());
    }

    #[test]
    fn kv_rejects_unknown_key() {
        let kv = parse_kv("bogus = 1").unwrap();
        assert!(Config::default().apply_kv(&kv).is_err());
    }

    #[test]
    fn kv_rejects_missing_equals() {
        assert!(parse_kv("nodes 8").is_err());
    }

    #[test]
    fn args_override() {
        let a = Args::parse(
            ["train", "--nodes", "16", "--method", "iwp-fixed", "--thr", "0.05"]
                .into_iter()
                .map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.nodes, 16);
        assert_eq!(cfg.method, Method::IwpFixed.spec());
        assert!((cfg.threshold - 0.05).abs() < 1e-7);
    }

    #[test]
    fn validation_catches_bad_values() {
        let c = Config {
            nodes: 1,
            ..Config::default()
        };
        assert!(c.validate().is_err());
        let c = Config {
            mask_nodes: 10,
            nodes: 4,
            ..Config::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn parallelism_knob_flows_and_validates() {
        let a = Args::parse(
            ["train", "--parallelism", "4"].into_iter().map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.parallelism, 4);
        assert_eq!(cfg.executor().workers(), 4);
        let kv = parse_kv("parallelism = 8").unwrap();
        assert_eq!(Config::default().apply_kv(&kv).unwrap().parallelism, 8);
        let c = Config {
            parallelism: 0,
            ..Config::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn topology_knob_flows_from_flag_and_file() {
        let a = Args::parse(
            ["train", "--topology", "hier:4"].into_iter().map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.topology, TopoKind::Hier { group: 4 });
        let kv = parse_kv("topology = tree").unwrap();
        assert_eq!(Config::default().apply_kv(&kv).unwrap().topology, TopoKind::Tree);
        assert_eq!(Config::default().topology, TopoKind::Flat);
        let a = Args::parse(
            ["train", "--topology", "mesh"].into_iter().map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err());
    }

    #[test]
    fn transport_knob_flows_from_flag_and_file() {
        let a = Args::parse(
            ["train", "--transport", "uds"].into_iter().map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.transport, TransportKind::Uds);
        let kv = parse_kv("transport = tcp").unwrap();
        assert_eq!(
            Config::default().apply_kv(&kv).unwrap().transport,
            TransportKind::Tcp
        );
        let a = Args::parse(
            ["train", "--transport", "carrier-pigeon"]
                .into_iter()
                .map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err());
    }

    #[test]
    fn tuner_knob_flows_from_flag_and_file() {
        let a = Args::parse(
            ["train", "--tuner", "on"].into_iter().map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.tuner, TunerMode::On);
        let kv = parse_kv("tuner = log-only").unwrap();
        assert_eq!(
            Config::default().apply_kv(&kv).unwrap().tuner,
            TunerMode::LogOnly
        );
        // Malformed mode is rejected at the shared parse entry point.
        let a = Args::parse(
            ["train", "--tuner", "sometimes"].into_iter().map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err());
        // The tuner observes shared masks — non-IWP methods can't run it.
        let c = Config {
            tuner: TunerMode::On,
            method: Method::Baseline.spec(),
            ..Config::default()
        };
        assert!(c.validate().is_err());
        let c = Config {
            tuner: TunerMode::On,
            method: Method::IwpFixed.spec(),
            ..Config::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn chaos_knobs_flow_and_validate() {
        let a = Args::parse(
            ["train", "--chaos", "mode=rescale,crash@2:1"]
                .into_iter()
                .map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        let plan = cfg.chaos.unwrap();
        assert_eq!(plan.mode, RecoveryMode::DropRescale);
        assert_eq!(plan.events.len(), 1);
        // Seeded generation covers the configured ring and step range.
        let a = Args::parse(
            ["chaos", "--nodes", "6", "--chaos-seed", "9"]
                .into_iter()
                .map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.chaos, Some(ChaosPlan::generate(9, 6, cfg.steps)));
        // --chaos-mode overrides whatever the plan said.
        let a = Args::parse(
            ["chaos", "--chaos", "crash@1:0", "--chaos-mode", "rescale"]
                .into_iter()
                .map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.chaos.unwrap().mode, RecoveryMode::DropRescale);
        // Plans referencing absent nodes are rejected at validate.
        let a = Args::parse(
            ["train", "--nodes", "4", "--chaos", "crash@1:7"]
                .into_iter()
                .map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err());
        // The config-file key flows through the same parser.
        let kv = parse_kv("chaos = crash@3:0").unwrap();
        assert!(Config::default().apply_kv(&kv).unwrap().chaos.is_some());
    }

    #[test]
    fn wire_knobs_flow_and_validate() {
        let a = Args::parse(
            ["chaos", "--wire-timeout-ms", "5000", "--wire-faults", "seed=7,flip@0:1,dup@2:0"]
                .into_iter()
                .map(String::from),
        );
        let cfg = Config::default().apply_args(&a).unwrap();
        assert_eq!(cfg.wire_timeout_ms, 5_000);
        let plan = cfg.wire_faults.unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.seed, 7);
        // Config-file keys flow through the same parsers.
        let kv = parse_kv("wire_timeout_ms = 750\nwire_faults = reset@1:0").unwrap();
        let cfg = Config::default().apply_kv(&kv).unwrap();
        assert_eq!(cfg.wire_timeout_ms, 750);
        assert!(cfg.wire_faults.is_some());
        // A zero deadline and an out-of-range retry budget are rejected.
        let c = Config {
            wire_timeout_ms: 0,
            ..Config::default()
        };
        assert!(c.validate().is_err());
        let a = Args::parse(
            ["chaos", "--wire-faults", "attempts=9,flip@0:0"]
                .into_iter()
                .map(String::from),
        );
        assert!(Config::default().apply_args(&a).is_err());
    }

    #[test]
    fn epoch_indexing() {
        let c = Config {
            steps_per_epoch: 50,
            ..Config::default()
        };
        assert_eq!(c.epoch_of(0), 0);
        assert_eq!(c.epoch_of(49), 0);
        assert_eq!(c.epoch_of(50), 1);
    }
}
