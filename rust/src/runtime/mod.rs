//! PJRT runtime — loads AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and executes them from the Rust hot path.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  Interchange is HLO **text**: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Python never runs here — `make artifacts` is the only compile path.

pub mod artifact;
pub mod kernels;

/// The real PJRT bindings (feature `pjrt`) or an in-repo stub with the
/// same surface that errors at execution time — so the whole crate,
/// including the simulation stack and its tests, builds and runs on
/// machines without the XLA extension.
#[cfg(feature = "pjrt")]
pub(crate) use ::xla;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod xla;

pub use artifact::{Artifact, ArtifactMeta};
pub use kernels::ImportanceKernel;

use std::path::{Path, PathBuf};

/// A PJRT CPU runtime owning the client and the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        anyhow::ensure!(
            dir.is_dir(),
            "artifacts directory `{}` not found — run `make artifacts` first",
            dir.display()
        );
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir,
        })
    }

    /// The PJRT platform name (e.g. `cpu`; a stub marker without the
    /// `pjrt` feature).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name (`train_step_mlp_b32`, …).
    pub fn load(&self, name: &str) -> anyhow::Result<Artifact> {
        Artifact::load(&self.client, &self.dir, name)
    }

    /// Names listed in the artifact index (artifacts/index.json).
    pub fn available(&self) -> anyhow::Result<Vec<String>> {
        let text = std::fs::read_to_string(self.dir.join("index.json"))?;
        let idx = crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad index.json: {e}"))?;
        Ok(idx
            .req_arr("artifacts")?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect())
    }
}
