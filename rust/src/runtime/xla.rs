//! Build-time stand-in for the `xla` crate (PJRT bindings), used when
//! the `pjrt` cargo feature is off — e.g. CI machines without the XLA
//! extension. Mirrors exactly the API surface `runtime::{artifact,
//! kernels}` consume. The client constructs fine (so artifact-directory
//! validation and manifest errors keep their real behaviour and tests),
//! but anything that would actually compile or execute HLO returns an
//! actionable error, which the runtime-dependent tests and harnesses
//! already treat as "skip".

use anyhow::{anyhow, Result};

fn unavailable() -> anyhow::Error {
    anyhow!(
        "PJRT is unavailable: ringiwp was built without the `pjrt` feature \
         (rebuild with `cargo build --features pjrt` on a machine with the \
         XLA extension, after `make artifacts`)"
    )
}

/// Stub PJRT client: constructible, cannot compile.
pub struct PjRtClient(());

impl PjRtClient {
    /// Always succeeds — directory validation and manifest parsing stay
    /// exercisable without PJRT.
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient(()))
    }

    /// Reports the stub platform.
    pub fn platform_name(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    /// Always errors: no XLA backend is linked.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Always errors: HLO text parsing needs the XLA extension.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub computation handle.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wraps a (never-constructible-in-practice) proto.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Always errors: nothing can be compiled, so nothing executes.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Always errors.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub host literal.
pub struct Literal(());

impl Literal {
    /// Accepts any f32 slice (marshalling is shape-checked upstream).
    pub fn vec1(_v: &[f32]) -> Self {
        Literal(())
    }

    /// Always errors.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Always errors.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

impl From<f32> for Literal {
    fn from(_: f32) -> Self {
        Literal(())
    }
}
