//! Kernel-backed importance scoring: the L1 Pallas kernel on the actual
//! request path.
//!
//! The artifact processes fixed-size flat buffers (`importance_m65536` /
//! `importance_m8192`); this wrapper tiles arbitrary layer lengths across
//! those sizes, padding the tail with (g=0, w=1, u=1) — importance 0,
//! never selected — and corrects the padded count out of the stats.

use super::Artifact;
use crate::compress::importance::LayerStats;
use crate::sparse::BitMask;

/// The bulk artifact granularity (large tiles).
pub const M_LARGE: usize = 65_536;
/// The tail artifact granularity (small tiles).
pub const M_SMALL: usize = 8_192;

/// Importance kernel executor over arbitrary-length buffers.
pub struct ImportanceKernel {
    large: Artifact,
    small: Artifact,
    // Reusable padded staging buffers (hot path: no per-call allocation).
    g_pad: Vec<f32>,
    w_pad: Vec<f32>,
    u_pad: Vec<f32>,
}

impl ImportanceKernel {
    /// Load + compile both kernel granularities from the runtime.
    pub fn load(rt: &super::Runtime) -> anyhow::Result<Self> {
        Ok(ImportanceKernel {
            large: rt.load(&format!("importance_m{M_LARGE}"))?,
            small: rt.load(&format!("importance_m{M_SMALL}"))?,
            g_pad: vec![0.0; M_LARGE],
            w_pad: vec![1.0; M_LARGE],
            u_pad: vec![1.0; M_LARGE],
        })
    }

    /// Score one flat buffer: returns (mask, importance, stats).
    /// `u` follows the kernel semantics (1.0 = hard threshold).
    pub fn score(
        &mut self,
        g: &[f32],
        w: &[f32],
        u: &[f32],
        thr: f32,
        eps: f32,
    ) -> anyhow::Result<(BitMask, Vec<f32>, LayerStats)> {
        let len = g.len();
        let mut mask = BitMask::zeros(len);
        let mut imp = vec![0.0f32; len];
        let stats = self.score_tiles(g, w, u, thr, eps, &mut |off, take, mask_f32, imp_f32| {
            for (k, (&m, &v)) in mask_f32[..take].iter().zip(&imp_f32[..take]).enumerate() {
                if m != 0.0 {
                    mask.set(off + k);
                }
                imp[off + k] = v;
            }
        })?;
        Ok((mask, imp, stats))
    }

    /// [`ImportanceKernel::score`] for a layer window at global offset
    /// `base`: sets selection bits directly into the caller's model-wide
    /// mask and skips the importance materialization (the trainer only
    /// consumes the stats rows) — no per-call allocation (DESIGN.md
    /// §11). Bits in `[base, base + g.len())` must be clear on entry;
    /// callers reuse a `clear_all`-ed per-broadcaster slot.
    #[allow(clippy::too_many_arguments)]
    pub fn score_into(
        &mut self,
        g: &[f32],
        w: &[f32],
        u: &[f32],
        thr: f32,
        eps: f32,
        base: usize,
        mask_out: &mut BitMask,
    ) -> anyhow::Result<LayerStats> {
        self.score_tiles(g, w, u, thr, eps, &mut |off, take, mask_f32, _imp_f32| {
            for (k, &m) in mask_f32[..take].iter().enumerate() {
                if m != 0.0 {
                    mask_out.set(base + off + k);
                }
            }
        })
    }

    /// Shared tiling loop: runs the kernel artifacts over `g/w/u` and
    /// hands each tile's `(offset, take, mask_f32, imp_f32)` to `sink`,
    /// accumulating the padding-corrected stats.
    #[allow(clippy::too_many_arguments)]
    fn score_tiles(
        &mut self,
        g: &[f32],
        w: &[f32],
        u: &[f32],
        thr: f32,
        eps: f32,
        sink: &mut dyn FnMut(usize, usize, &[f32], &[f32]),
    ) -> anyhow::Result<LayerStats> {
        assert!(g.len() == w.len() && g.len() == u.len());
        let len = g.len();
        let mut stats = LayerStats::default();

        let thr_buf = [thr];
        let eps_buf = [eps];
        let mut off = 0usize;
        while off < len {
            let remaining = len - off;
            let (m, art) = if remaining >= M_LARGE {
                (M_LARGE, &self.large)
            } else {
                (M_SMALL, &self.small)
            };
            let take = remaining.min(m);
            let (gs, ws, us): (&[f32], &[f32], &[f32]) = if take == m {
                (&g[off..off + m], &w[off..off + m], &u[off..off + m])
            } else {
                // Tail: stage into padded buffers (g=0, w=1, u=1).
                self.g_pad[..take].copy_from_slice(&g[off..off + take]);
                self.g_pad[take..m].fill(0.0);
                self.w_pad[..take].copy_from_slice(&w[off..off + take]);
                self.w_pad[take..m].fill(1.0);
                self.u_pad[..take].copy_from_slice(&u[off..off + take]);
                self.u_pad[take..m].fill(1.0);
                (&self.g_pad[..m], &self.w_pad[..m], &self.u_pad[..m])
            };
            let out = art.run_f32(&[gs, ws, us, &thr_buf, &eps_buf])?;
            let (mask_f32, imp_f32, st) = (&out[0], &out[1], &out[2]);
            sink(off, take, mask_f32, imp_f32);
            // Kernel stats include the padded coordinates (importance 0,
            // unselected) — only `n` needs correcting.
            stats.sum += st[0] as f64;
            stats.sumsq += st[1] as f64;
            stats.n_selected += st[2] as f64;
            stats.n += take as f64;
            off += take;
        }
        Ok(stats)
    }
}
