//! Kernel-backed importance scoring: the L1 Pallas kernel on the actual
//! request path.
//!
//! The artifact processes fixed-size flat buffers (`importance_m65536` /
//! `importance_m8192`); this wrapper tiles arbitrary layer lengths across
//! those sizes, padding the tail with (g=0, w=1, u=1) — importance 0,
//! never selected — and corrects the padded count out of the stats.

use super::Artifact;
use crate::compress::importance::LayerStats;
use crate::sparse::BitMask;

/// The bulk artifact granularity (large tiles).
pub const M_LARGE: usize = 65_536;
/// The tail artifact granularity (small tiles).
pub const M_SMALL: usize = 8_192;

/// Importance kernel executor over arbitrary-length buffers.
pub struct ImportanceKernel {
    large: Artifact,
    small: Artifact,
    // Reusable padded staging buffers (hot path: no per-call allocation).
    g_pad: Vec<f32>,
    w_pad: Vec<f32>,
    u_pad: Vec<f32>,
}

impl ImportanceKernel {
    /// Load + compile both kernel granularities from the runtime.
    pub fn load(rt: &super::Runtime) -> anyhow::Result<Self> {
        Ok(ImportanceKernel {
            large: rt.load(&format!("importance_m{M_LARGE}"))?,
            small: rt.load(&format!("importance_m{M_SMALL}"))?,
            g_pad: vec![0.0; M_LARGE],
            w_pad: vec![1.0; M_LARGE],
            u_pad: vec![1.0; M_LARGE],
        })
    }

    /// Score one flat buffer: returns (mask, importance, stats).
    /// `u` follows the kernel semantics (1.0 = hard threshold).
    pub fn score(
        &mut self,
        g: &[f32],
        w: &[f32],
        u: &[f32],
        thr: f32,
        eps: f32,
    ) -> anyhow::Result<(BitMask, Vec<f32>, LayerStats)> {
        assert!(g.len() == w.len() && g.len() == u.len());
        let len = g.len();
        let mut mask = BitMask::zeros(len);
        let mut imp = vec![0.0f32; len];
        let mut stats = LayerStats::default();

        let thr_buf = [thr];
        let eps_buf = [eps];
        let mut off = 0usize;
        while off < len {
            let remaining = len - off;
            let (m, art) = if remaining >= M_LARGE {
                (M_LARGE, &self.large)
            } else {
                (M_SMALL, &self.small)
            };
            let take = remaining.min(m);
            let (gs, ws, us): (&[f32], &[f32], &[f32]) = if take == m {
                (&g[off..off + m], &w[off..off + m], &u[off..off + m])
            } else {
                // Tail: stage into padded buffers (g=0, w=1, u=1).
                self.g_pad[..take].copy_from_slice(&g[off..off + take]);
                self.g_pad[take..m].fill(0.0);
                self.w_pad[..take].copy_from_slice(&w[off..off + take]);
                self.w_pad[take..m].fill(1.0);
                self.u_pad[..take].copy_from_slice(&u[off..off + take]);
                self.u_pad[take..m].fill(1.0);
                (&self.g_pad[..m], &self.w_pad[..m], &self.u_pad[..m])
            };
            let out = art.run_f32(&[gs, ws, us, &thr_buf, &eps_buf])?;
            let (mask_f32, imp_f32, st) = (&out[0], &out[1], &out[2]);
            for k in 0..take {
                if mask_f32[k] != 0.0 {
                    mask.set(off + k);
                }
                imp[off + k] = imp_f32[k];
            }
            // Kernel stats include the padded coordinates (importance 0,
            // unselected) — only `n` needs correcting.
            stats.sum += st[0] as f64;
            stats.sumsq += st[1] as f64;
            stats.n_selected += st[2] as f64;
            stats.n += take as f64;
            off += take;
        }
        Ok((mask, imp, stats))
    }
}
