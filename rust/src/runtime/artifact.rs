//! One loaded artifact: compiled executable + manifest metadata +
//! f32 marshalling.

use std::path::Path;

use super::xla;
use crate::model::ParamLayout;
use crate::util::json::{parse, Json};

/// Parsed manifest metadata (shapes the marshalling layer relies on).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (also the file stem on disk).
    pub name: String,
    /// Artifact kind tag from the manifest (`train_step`, `kernel`, …).
    pub kind: String,
    /// (name, shape) per input, in call order.
    pub inputs: Vec<(String, Vec<usize>)>,
    /// (name, shape) per output, in tuple order.
    pub outputs: Vec<(String, Vec<usize>)>,
    /// The full manifest document (layout, offsets, extras).
    pub raw: Json,
}

impl ArtifactMeta {
    /// Parse a manifest document into typed metadata.
    pub fn from_json(raw: Json) -> anyhow::Result<Self> {
        let shapes = |key: &str| -> anyhow::Result<Vec<(String, Vec<usize>)>> {
            raw.req_arr(key)?
                .iter()
                .map(|o| {
                    let name = o.req_str("name")?.to_string();
                    let shape = o
                        .req_arr("shape")?
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect();
                    Ok((name, shape))
                })
                .collect()
        };
        Ok(ArtifactMeta {
            name: raw.req_str("name")?.to_string(),
            kind: raw.req_str("kind")?.to_string(),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
            raw,
        })
    }

    /// Parameter layout for `train_step` artifacts.
    pub fn layout(&self) -> anyhow::Result<ParamLayout> {
        let model = self.raw.get("model").as_str().unwrap_or(&self.name);
        ParamLayout::from_manifest(model, &self.raw)
    }

    /// Number of leading inputs that are model parameters (train_step
    /// artifacts list params first, then data inputs).
    pub fn n_param_inputs(&self) -> anyhow::Result<usize> {
        Ok(self.raw.req_arr("layers")?.len())
    }
}

/// Compiled executable + metadata.
pub struct Artifact {
    /// Manifest metadata driving the f32 marshalling.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Read `<dir>/<name>.manifest.json` + `<name>.hlo.txt` and compile
    /// the HLO through the client.
    pub fn load(client: &xla::PjRtClient, dir: &Path, name: &str) -> anyhow::Result<Self> {
        let manifest_path = dir.join(format!("{name}.manifest.json"));
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", manifest_path.display())
        })?;
        let meta = ArtifactMeta::from_json(
            parse(&text).map_err(|e| anyhow::anyhow!("bad manifest: {e}"))?,
        )?;
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Artifact { meta, exe })
    }

    /// Execute with flat f32 buffers (one per manifest input, lengths must
    /// match the manifest shapes). Returns one flat f32 buffer per output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            inputs.len() == self.meta.inputs.len(),
            "artifact `{}` expects {} inputs, got {}",
            self.meta.name,
            self.meta.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, (iname, shape)) in inputs.iter().zip(&self.meta.inputs) {
            let numel: usize = shape.iter().product::<usize>().max(1);
            anyhow::ensure!(
                buf.len() == numel,
                "input `{iname}` of `{}`: {} elements given, shape {:?} needs {numel}",
                self.meta.name,
                buf.len(),
                shape
            );
            let lit = if shape.is_empty() {
                xla::Literal::from(buf[0])
            } else if shape.len() == 1 {
                xla::Literal::vec1(buf)
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf).reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.meta.outputs.len(),
            "artifact `{}` returned {} outputs, manifest says {}",
            self.meta.name,
            parts.len(),
            self.meta.outputs.len()
        );
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}
