//! The §II density-growth claim, swept across topologies AND selection
//! pipelines: per-node selection (DGC transport — magnitude top-k or
//! the `dgc:layerwise` thresholded composition, DESIGN.md §12)
//! densifies as the reduce progresses ("top 1% … the worst case is 2%"
//! per hop, compounding), while Algorithm 1's shared mask (plain
//! `iwp:fixed` or the variance-gated `iwp:vargate` composition) keeps
//! density flat — and the *communication pattern* decides how much that
//! densification costs on the wire (DESIGN.md §10, EXPERIMENTS.md §7,
//! §9).
//!
//! Output: density after a full reduce vs ring size, for all four
//! pipelines under the flat ring, a group-8 hierarchy, the binomial
//! tree, and the layer-pipelined flat ring at chunk depths 1 (serial
//! anchor) and 8 (overlapped — DESIGN.md §11; only the pipeline rows
//! price selection prep, so compare them to each other), plus per-step
//! wire bytes/time and the analytic `1-(1-d)^N` model. A final `tuned`
//! row per ring size runs the shared-mask stream under `--tuner on`
//! (DESIGN.md §14), recording what the autotuner picks at that scale.

use crate::compress::MethodSpec;
use crate::csv_row;
use crate::exp::simrun::{SimCfg, SimEngine};
use crate::metrics::CsvWriter;
use crate::model::zoo;
use crate::net::{PipeInner, TopoKind, TunerMode};
use crate::ring::sparse::expected_final_density;

/// Topologies the density sweep compares (group 8 keeps at least two
/// groups from 16 nodes up). The two pipeline rows expose the
/// prep-overlap wire-time trade of DESIGN.md §11 on the same workload:
/// compare `pipeline:8:flat` against the `pipeline:1:flat` serial
/// anchor, which prices the same selection prep without overlap — the
/// base-topology rows do not price prep at all, so their `virtual_s`
/// is not directly comparable to the pipeline rows'.
pub const DENSITY_TOPOLOGIES: [TopoKind; 5] = [
    TopoKind::Flat,
    TopoKind::Hier { group: 8 },
    TopoKind::Tree,
    TopoKind::Pipeline {
        chunks: 1,
        inner: PipeInner::Flat,
    },
    TopoKind::Pipeline {
        chunks: 8,
        inner: PipeInner::Flat,
    },
];

/// Selection pipelines the sweep compares: both DGC-transport variants
/// (densifying per-node masks) against the shared-mask variants
/// (ring-size-invariant density), including one low-precision payload
/// row (`+q:8`, DESIGN.md §17) — same mask stream as `iwp:fixed`, a
/// quarter of the payload bytes.
pub const DENSITY_SPECS: [&str; 5] = [
    "dgc:topk",
    "dgc:layerwise",
    "iwp:fixed",
    "iwp:vargate",
    "iwp:fixed+q:8",
];

/// Sweep ring sizes × topologies × pipelines and write
/// `density_growth.csv` against the analytic `1-(1-d)^N` model.
pub fn run(out_dir: &str, seed: u64) -> anyhow::Result<()> {
    let layout = zoo::resnet50();
    let ring_sizes = [4usize, 8, 16, 32, 64, 96];
    let mut csv = CsvWriter::create(
        format!("{out_dir}/density_growth.csv"),
        &[
            "nodes",
            "topology",
            "method",
            "final_density",
            "analytic_model",
            "wire_bytes_per_node",
            "virtual_s",
        ],
    )?;
    println!("== per-node vs shared-mask density growth across topologies (ResNet50, d0=1%) ==");
    println!(
        "{:>6} {:>15} {:>11} {:>11} {:>11} {:>11} {:>11} {:>16} {:>12}",
        "nodes",
        "topology",
        "dgc:topk",
        "dgc:lw",
        "iwp:fixed",
        "iwp:vargate",
        "iwp:fix+q8",
        "model(1-(1-d)^N)",
        "topk_MB/node"
    );
    for &n in &ring_sizes {
        for topology in DENSITY_TOPOLOGIES {
            let mut densities = Vec::new();
            let mut dgc_bytes = 0u64;
            for (mi, spec) in DENSITY_SPECS.iter().copied().enumerate() {
                let cfg = SimCfg {
                    nodes: n,
                    method: MethodSpec::parse(spec).expect("registry spec"),
                    dgc_density: 0.01,
                    // Calibrated to ~1% per-broadcaster density on this
                    // model (hard threshold, single mask node) so every
                    // pipeline starts from the paper's "top 1%" regime.
                    threshold: 0.04,
                    mask_nodes: 1,
                    random_select: false,
                    seed,
                    topology,
                    ..Default::default()
                };
                let mut engine = SimEngine::new(layout.clone(), cfg);
                let (mut last_density, mut wire, mut secs) = (0.0, 0u64, 0.0);
                for s in 0..2 {
                    let r = engine.step(s);
                    last_density = r.density;
                    wire = r.wire_bytes_per_node;
                    secs = r.seconds;
                }
                densities.push(last_density);
                if mi == 0 {
                    dgc_bytes = wire;
                }
                csv_row!(
                    csv,
                    n,
                    topology.name(),
                    spec,
                    last_density,
                    expected_final_density(0.01, n),
                    wire,
                    secs
                )?;
            }
            println!(
                "{n:>6} {:>15} {:>10.4}% {:>10.4}% {:>10.4}% {:>10.4}% {:>10.4}% {:>15.4}% {:>12.2}",
                topology.name(),
                densities[0] * 100.0,
                densities[1] * 100.0,
                densities[2] * 100.0,
                densities[3] * 100.0,
                densities[4] * 100.0,
                expected_final_density(0.01, n) * 100.0,
                dgc_bytes as f64 / 1e6
            );
        }

        // Autotuned arm (DESIGN.md §14): the same shared-mask stream
        // with each step's CostModel-argmin strategy executing. The
        // `topology` column carries the literal `tuned`; the pick the
        // tuner settled on at this ring size is printed alongside.
        let cfg = SimCfg {
            nodes: n,
            method: MethodSpec::parse("iwp:fixed").expect("registry spec"),
            threshold: 0.04,
            mask_nodes: 1,
            random_select: false,
            seed,
            tuner: TunerMode::On,
            ..Default::default()
        };
        let mut engine = SimEngine::new(layout.clone(), cfg);
        let (mut last_density, mut wire, mut secs) = (0.0, 0u64, 0.0);
        for s in 0..2 {
            let r = engine.step(s);
            last_density = r.density;
            wire = r.wire_bytes_per_node;
            secs = r.seconds;
        }
        let pick = engine
            .tuner()
            .and_then(|t| t.trace().last())
            .map(|r| r.pick.clone())
            .unwrap_or_default();
        csv_row!(
            csv,
            n,
            "tuned",
            "iwp:fixed",
            last_density,
            expected_final_density(0.01, n),
            wire,
            secs
        )?;
        println!(
            "{n:>6} {:>15} {:>10.4}% (autotuned iwp:fixed — pick {pick})",
            "tuned",
            last_density * 100.0
        );
    }
    csv.flush()?;
    println!(
        "paper (Sec. II): per-node selection (both dgc:* pipelines) densifies towards\n       \
         dense as N grows; the shared mask (both iwp:* pipelines) is invariant in N —\n       \
         on every topology, but the wire cost of the densified payload depends on the\n       \
         pattern (EXPERIMENTS.md §7, §9)"
    );
    Ok(())
}
