//! The §II density-growth claim: DGC's per-node top-k densifies as the
//! ring grows ("top 1% … the worst case is 2%" per hop, compounding),
//! while Algorithm 1's shared mask keeps density flat in N.
//!
//! Output: density after a full scatter-reduce vs ring size, for DGC
//! and IWP, plus the analytic 1-(1-d)^N model.

use crate::compress::Method;
use crate::csv_row;
use crate::exp::simrun::{SimCfg, SimEngine};
use crate::metrics::CsvWriter;
use crate::model::zoo;
use crate::ring::sparse::expected_final_density;

/// Sweep ring sizes under DGC and IWP and write
/// `density_growth.csv` against the analytic `1-(1-d)^N` model.
pub fn run(out_dir: &str, seed: u64) -> anyhow::Result<()> {
    let layout = zoo::resnet50();
    let ring_sizes = [4usize, 8, 16, 32, 64, 96];
    let mut csv = CsvWriter::create(
        format!("{out_dir}/density_growth.csv"),
        &["nodes", "method", "final_density", "analytic_model"],
    )?;
    println!("== DGC-vs-IWP density growth on the ring (ResNet50, d0=1%) ==");
    println!(
        "{:>6} {:>16} {:>16} {:>16}",
        "nodes", "dgc_density", "iwp_density", "model_1-(1-d)^N"
    );
    for &n in &ring_sizes {
        let mut densities = Vec::new();
        for method in [Method::Dgc, Method::IwpFixed] {
            let cfg = SimCfg {
                nodes: n,
                method,
                dgc_density: 0.01,
                // Calibrated to ~1% per-broadcaster density on this
                // model (hard threshold, single mask node) so both
                // methods start from the paper's "top 1%" regime.
                threshold: 0.04,
                mask_nodes: 1,
                random_select: false,
                seed,
                ..Default::default()
            };
            let mut engine = SimEngine::new(layout.clone(), cfg);
            let mut last = 0.0;
            for s in 0..2 {
                last = engine.step(s).density;
            }
            densities.push(last);
            csv_row!(
                csv,
                n,
                method.name(),
                last,
                expected_final_density(0.01, n)
            )?;
        }
        println!(
            "{n:>6} {:>15.4}% {:>15.4}% {:>15.4}%",
            densities[0] * 100.0,
            densities[1] * 100.0,
            expected_final_density(0.01, n) * 100.0
        );
    }
    csv.flush()?;
    println!("paper (Sec. II): DGC density grows towards dense as N grows;\n       IWP's shared mask is invariant in N");
    Ok(())
}
