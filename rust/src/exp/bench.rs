//! `exp::bench` — the in-process, deterministic perf harness behind
//! `BENCH_ring.json` / `BENCH_step.json` (DESIGN.md §9, EXPERIMENTS.md
//! §6).
//!
//! Two sweeps, both seeded and counter-based so every *deterministic*
//! row field (wire bytes, virtual wire time from the `net::cost` model's
//! link parameters, densities, ratios) replays bit-for-bit across runs
//! and machines:
//!
//! * **ring** — the three transport schedules (dense / sparse / masked)
//!   in isolation, per ring size, over a fixed synthetic payload. Rows
//!   carry the simulated virtual seconds *and* the closed-form
//!   `net::cost` prediction (`model_s`), which must agree.
//! * **step** — the full `SimEngine` step (gradient synthesis →
//!   compression → ring transport → accounting) for all 9 pipelines
//!   ([`step_specs`]: the 5 legacy methods plus `iwp:vargate`,
//!   `dgc:layerwise`, and the two registry `+q` compositions,
//!   DESIGN.md §12, §17) × ring sizes × AlexNet/ResNet50
//!   inventories (scaled-down stand-ins under the `quick` profile so
//!   the CI smoke run stays fast).
//!
//! Measured wall time (`ns_op`, the CI regression gate's input) is the
//! only non-replayable field; `metrics::bench::canonical` strips it
//! (plus provenance) for the determinism checks, and `timing: false`
//! omits it entirely.
//!
//! `--transport uds|tcp` routes the step sweep through the real socket
//! ring (`net::wire`, DESIGN.md §13). Every deterministic row field is
//! bit-identical to the `sim` transport by the transport-equivalence
//! oracle; only `ns_op` (and the rows' `transport` label) moves. The
//! ring sweep drives schedules below the engine seam and stays virtual.

use crate::compress::{Method, MethodSpec};
use crate::exp::simrun::{SimCfg, SimEngine, WireEngine};
use crate::metrics::bench::BenchReport;
use crate::model::{zoo, LayerKind, ParamLayout};
use crate::net::topo::pipeline;
use crate::net::{
    CostModel, LinkSpec, Observation, PipeInner, RingNet, TopoKind, Topology, TransportKind,
    Tuner, TunerMode,
};
use crate::ring::{Arena, Executor, ReduceReport};
use crate::sparse::{BitMask, SparseVec};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer;

/// Harness configuration (CLI: `ringiwp bench`).
#[derive(Debug, Clone)]
pub struct BenchCfg {
    /// Reduced payloads/inventories for the CI smoke run (`--quick`).
    pub quick: bool,
    /// Measure wall time (`ns_op`). `false` omits the field, making the
    /// whole payload replay bit-for-bit (`--no-timing`).
    pub timing: bool,
    /// Timed iterations per arm (median is reported).
    pub repeats: usize,
    /// Ring sizes swept (the paper's 4..96 range by default).
    pub ring_sizes: Vec<usize>,
    /// Root seed for every synthetic stream.
    pub seed: u64,
    /// Link bandwidth/latency parameterizing the virtual wire time.
    pub link: LinkSpec,
    /// Step-sweep transport (`--transport`): `sim` stays virtual; `uds`
    /// / `tcp` route payloads through a real in-process socket ring.
    /// Pinned to `sim` by default (not `RINGIWP_TRANSPORT`) so baseline
    /// payloads are environment-independent, like the topology pin.
    pub transport: TransportKind,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            quick: false,
            timing: true,
            repeats: 3,
            ring_sizes: vec![4, 8, 32, 96],
            seed: 42,
            link: LinkSpec::gigabit_ethernet(),
            transport: TransportKind::Sim,
        }
    }
}

impl BenchCfg {
    /// Profile label recorded in the payload config; baselines only
    /// compare against payloads of the same profile.
    pub fn profile(&self) -> &'static str {
        if self.quick {
            "quick"
        } else {
            "full"
        }
    }

    /// Ring-sweep payload size in coordinates.
    fn ring_coords(&self) -> usize {
        if self.quick {
            1 << 13
        } else {
            1 << 17
        }
    }

    /// Deterministic metric steps per step-sweep arm.
    fn metric_steps(&self) -> usize {
        if self.quick {
            2
        } else {
            3
        }
    }

    fn config_json(&self) -> Json {
        Json::obj(vec![
            ("profile", Json::from(self.profile())),
            ("repeats", Json::from(self.repeats)),
            (
                "ring_sizes",
                Json::Arr(self.ring_sizes.iter().map(|&n| Json::from(n)).collect()),
            ),
            // String, not number: JSON numbers are f64 and would corrupt
            // seeds >= 2^53, breaking replay-from-config.
            ("seed", Json::from(self.seed.to_string().as_str())),
            ("bandwidth_bps", Json::from(self.link.bandwidth_bps)),
            ("latency_s", Json::from(self.link.latency_s)),
            ("transport", Json::from(self.transport.name())),
        ])
    }
}

/// 1% of `len`, at least 1 — the sweeps' sparse payload density.
fn one_percent(len: usize) -> usize {
    (len / 100).max(1)
}

fn deterministic_sparse(rng: &mut Rng, len: usize) -> SparseVec {
    let mut dense = vec![0.0f32; len];
    for _ in 0..one_percent(len) {
        dense[rng.below(len)] = rng.normal();
    }
    SparseVec::from_dense(&dense)
}

/// Topologies the ring sweep covers (DESIGN.md §10, §11): the flat
/// ring, a group-of-4 hierarchy (4 divides every default ring size),
/// the binomial tree, and the 4-chunk layer-pipelined flat ring.
pub const BENCH_TOPOLOGIES: [TopoKind; 4] = [
    TopoKind::Flat,
    TopoKind::Hier { group: 4 },
    TopoKind::Tree,
    TopoKind::Pipeline {
        chunks: 4,
        inner: PipeInner::Flat,
    },
];

/// The ring transport sweep: dense / sparse / masked × topologies ×
/// ring sizes. Dense and masked rows carry the closed-form
/// `CostModel::topo_*` predictions (`model_s`, `model_bytes`), which
/// must equal the simulated `virtual_s` / `total_bytes` bit for bit.
/// One `tuned` row per ring size records the `net::tuner` argmin pick
/// over the candidate grid on the bench mask (DESIGN.md §14).
pub fn run_ring(cfg: &BenchCfg) -> BenchReport {
    let coords = cfg.ring_coords();
    let mut report = BenchReport::new("ring", cfg.config_json());
    let exec = Executor::sequential();
    for &n in &cfg.ring_sizes {
        let model = CostModel::new(n, cfg.link);
        let mut rng = Rng::new(cfg.seed ^ ((n as u64) << 20));
        // Payloads are drawn once per ring size — in the pre-topology
        // stream order (dense base, then sparse inputs, then the mask) —
        // and shared by every topology, so rows differ only in the
        // communication pattern.
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; coords];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let inputs: Vec<SparseVec> =
            (0..n).map(|_| deterministic_sparse(&mut rng, coords)).collect();
        let mut mask = BitMask::zeros(coords);
        for _ in 0..one_percent(coords) {
            mask.set(rng.below(coords));
        }
        let refs: Vec<&[f32]> = base.iter().map(|v| v.as_slice()).collect();
        let support = mask.count();

        for kind in BENCH_TOPOLOGIES {
            let topo = kind.build(n);
            let tname = kind.name();

            // -- dense --------------------------------------------------
            // The schedule reduces in place, so each sample restores
            // `work` from `base` first (a memcpy, no allocation). ns_op
            // therefore includes the restore + a fresh RingNet; both are
            // identical on both sides of a baseline comparison, so the
            // gate still tracks the schedule.
            let mut arena = Arena::for_nodes(n);
            let mut work = base.clone();
            let run = |work: &mut [Vec<f32>], arena: &mut Arena| -> ReduceReport {
                for (w, b) in work.iter_mut().zip(&base) {
                    w.copy_from_slice(b);
                }
                let mut net = RingNet::new(n, cfg.link, 1.0);
                topo.dense(&mut net, work, &exec, arena)
            };
            let rep = run(&mut work, &mut arena);
            let ns = cfg.timing.then(|| {
                timer::bench(0, cfg.repeats.max(1), || {
                    std::hint::black_box(run(&mut work, &mut arena));
                })
            });
            report.push(ring_row(
                &format!("ring/dense/{tname}/n{n}/c{coords}"),
                "dense",
                &tname,
                n,
                coords,
                &rep,
                Some(model.topo_dense_seconds(kind, coords)),
                Some(model.topo_dense_total_bytes(kind, coords)),
                ns.map(|s| s.median_ns),
            ));

            // -- sparse (DGC-style per-node supports) -------------------
            let mut arena = Arena::for_nodes(n);
            let run = |arena: &mut Arena| -> ReduceReport {
                let mut net = RingNet::new(n, cfg.link, 1.0);
                topo.sparse(&mut net, &inputs, &exec, arena).1
            };
            let rep = run(&mut arena);
            let ns = cfg.timing.then(|| {
                timer::bench(0, cfg.repeats.max(1), || {
                    std::hint::black_box(run(&mut arena));
                })
            });
            report.push(ring_row(
                &format!("ring/sparse/{tname}/n{n}/c{coords}"),
                "sparse",
                &tname,
                n,
                coords,
                &rep,
                None,
                None,
                ns.map(|s| s.median_ns),
            ));

            // -- masked (Algorithm 1's shared-mask transport) -----------
            let mut arena = Arena::for_nodes(n);
            let run = |arena: &mut Arena| -> ReduceReport {
                let mut net = RingNet::new(n, cfg.link, 1.0);
                topo.masked(&mut net, &[&mask], &refs, &exec, arena).2
            };
            let rep = run(&mut arena);
            let ns = cfg.timing.then(|| {
                timer::bench(0, cfg.repeats.max(1), || {
                    std::hint::black_box(run(&mut arena));
                })
            });
            // Masked predictions: the pipelined wrapper's makespan is
            // per-chunk-support-dependent (DESIGN.md §11), so its rows
            // price through `pipelined_masked_*`.
            let (masked_model_s, masked_model_bytes) = match kind {
                TopoKind::Pipeline { chunks, inner } => {
                    let sups = pipeline::chunk_supports(&mask, chunks);
                    (
                        model.pipelined_masked_seconds(inner.kind(), chunks, coords, 1, &sups),
                        model.pipelined_masked_total_bytes(
                            inner.kind(),
                            chunks,
                            coords,
                            1,
                            &sups,
                        ),
                    )
                }
                _ => (
                    model.topo_masked_seconds(kind, coords, 1, support),
                    model.topo_masked_total_bytes(kind, coords, 1, support),
                ),
            };
            report.push(ring_row(
                &format!("ring/masked/{tname}/n{n}/c{coords}"),
                "masked",
                &tname,
                n,
                coords,
                &rep,
                Some(masked_model_s),
                Some(masked_model_bytes),
                ns.map(|s| s.median_ns),
            ));
        }

        // -- tuned (net::tuner argmin over the candidate grid) ----------
        // One decision on the bench mask per ring size: the row records
        // which strategy the autotuner would run here and its predicted
        // prep-inclusive wire-seconds (DESIGN.md §14). The decision is
        // pure arithmetic over the CostModel closed forms, so every
        // field but ns_op replays bit-for-bit.
        let mut tuner = Tuner::new(TunerMode::On, n, cfg.link);
        let obs = Observation {
            coords,
            k: 1,
            shared: &mask,
        };
        let d = tuner.decide(&obs);
        let strat = *tuner.strategy(d.index);
        let ns = cfg.timing.then(|| {
            timer::bench(0, cfg.repeats.max(1), || {
                std::hint::black_box(tuner.decide(&obs));
            })
            .median_ns
        });
        let id = format!("ring/tuned/n{n}/c{coords}");
        let pick = strat.name();
        let mut fields = vec![
            ("id", Json::from(id.as_str())),
            ("schedule", Json::from("tuned")),
            ("topology", Json::from(strat.topo.name().as_str())),
            ("nodes", Json::from(n)),
            ("coords", Json::from(coords)),
            ("pick", Json::from(pick.as_str())),
            ("predicted_s", Json::from(d.predicted_s)),
        ];
        if let Some(ns) = ns {
            fields.push(("ns_op", Json::from(ns)));
        }
        report.push(Json::obj(fields));
    }
    report
}

#[allow(clippy::too_many_arguments)]
fn ring_row(
    id: &str,
    schedule: &str,
    topology: &str,
    nodes: usize,
    coords: usize,
    rep: &ReduceReport,
    model_s: Option<f64>,
    model_bytes: Option<u64>,
    ns_op: Option<f64>,
) -> Json {
    let mut fields = vec![
        ("id", Json::from(id)),
        ("schedule", Json::from(schedule)),
        ("topology", Json::from(topology)),
        ("nodes", Json::from(nodes)),
        ("coords", Json::from(coords)),
        ("bytes_per_node", Json::from(rep.mean_bytes_per_node())),
        ("total_bytes", Json::from(rep.total_bytes() as f64)),
        ("virtual_s", Json::from(rep.seconds)),
    ];
    if let Some(m) = model_s {
        fields.push(("model_s", Json::from(m)));
    }
    if let Some(b) = model_bytes {
        fields.push(("model_bytes", Json::from(b as f64)));
    }
    if let Some(ns) = ns_op {
        fields.push(("ns_op", Json::from(ns)));
    }
    Json::obj(fields)
}

/// AlexNet stand-in for the `quick` profile: the real 61M-parameter
/// inventory's layer-kind mix at ~1/2800 scale.
fn micro_alexnet() -> ParamLayout {
    ParamLayout::new(
        "alexnet_micro",
        vec![
            ("conv1".into(), vec![16, 3, 3, 3], LayerKind::Conv),
            ("conv2".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("fc1".into(), vec![256, 64], LayerKind::Fc),
            ("fc2".into(), vec![64, 10], LayerKind::Fc),
            ("bias".into(), vec![10], LayerKind::Bias),
        ],
    )
}

/// ResNet50 stand-in for the `quick` profile (conv/BN alternation).
fn micro_resnet50() -> ParamLayout {
    ParamLayout::new(
        "resnet50_micro",
        vec![
            ("conv1".into(), vec![16, 3, 7, 7], LayerKind::Conv),
            ("bn1".into(), vec![32], LayerKind::BatchNorm),
            ("block1".into(), vec![32, 16, 3, 3], LayerKind::Conv),
            ("bn2".into(), vec![64], LayerKind::BatchNorm),
            ("block2".into(), vec![64, 32, 3, 3], LayerKind::Conv),
            ("fc".into(), vec![128, 10], LayerKind::Fc),
        ],
    )
}

/// Step-sweep pipelines: the five legacy Table-I methods (canonical
/// specs) plus the four shipped stage compositions — variance-gated
/// IWP, DGC transport under Eq. 4 layerwise thresholds (DESIGN.md
/// §12), and the two registry `+q:<bits>` rows pricing precision
/// against bandwidth on the masked payload (DESIGN.md §17).
pub fn step_specs() -> [MethodSpec; 9] {
    [
        Method::Baseline.spec(),
        Method::TernGrad.spec(),
        Method::Dgc.spec(),
        Method::IwpFixed.spec(),
        Method::IwpLayerwise.spec(),
        MethodSpec::parse("iwp:vargate").expect("registry spec"),
        MethodSpec::parse("dgc:layerwise").expect("registry spec"),
        MethodSpec::parse("iwp:layerwise+q:8").expect("registry spec"),
        MethodSpec::parse("iwp:fixed+q:16b").expect("registry spec"),
    ]
}

/// The engine step sweep: 9 pipelines plus the autotuned arm (`tuned`,
/// `--tuner on` over `iwp:fixed`) × ring sizes × AlexNet/ResNet50.
pub fn run_step(cfg: &BenchCfg) -> BenchReport {
    let mut report = BenchReport::new("step", cfg.config_json());
    let models: Vec<(&str, ParamLayout)> = if cfg.quick {
        vec![("alexnet", micro_alexnet()), ("resnet50", micro_resnet50())]
    } else {
        vec![("alexnet", zoo::alexnet()), ("resnet50", zoo::resnet50())]
    };
    for (model_name, layout) in &models {
        // The static pipelines, plus one autotuned arm: the canonical
        // IWP observation stream with each step's CostModel-argmin
        // strategy executing (`--tuner on`, DESIGN.md §14). Its row id
        // reads `step/<model>/tuned/n<N>`.
        let mut arms: Vec<(MethodSpec, TunerMode, String)> = step_specs()
            .into_iter()
            .map(|m| {
                let label = m.name();
                (m, TunerMode::Off, label)
            })
            .collect();
        arms.push((Method::IwpFixed.spec(), TunerMode::On, "tuned".into()));
        for (method, tuner_mode, label) in &arms {
            let (method, tuner_mode) = (*method, *tuner_mode);
            for &n in &cfg.ring_sizes {
                let sim = SimCfg {
                    nodes: n,
                    method,
                    tuner: tuner_mode,
                    seed: cfg.seed,
                    link: cfg.link,
                    // Pinned: the step sweep measures the pipelines on
                    // the paper's flat ring (the ring sweep carries the
                    // topology axis). Inheriting RINGIWP_TOPOLOGY here
                    // would make BENCH_step.json — and the baseline
                    // gate's deterministic fields — environment-
                    // dependent.
                    topology: TopoKind::Flat,
                    // Pinned for the same reason: the wire is the
                    // harness's own in-process ring, never an external
                    // RINGIWP_WIRE_DIR rendezvous.
                    transport: cfg.transport,
                    wire_dir: None,
                    ..Default::default()
                };
                // Deterministic metrics pass — over the real socket
                // ring when a wire transport is selected (bit-identical
                // fields by the transport-equivalence oracle).
                let steps = cfg.metric_steps();
                let (mut wire_sum, mut secs, mut density) = (0u64, 0.0f64, 0.0f64);
                let tuned_summary = |t: Option<&Tuner>| {
                    t.map(|t| {
                        let last = t.trace().last().expect("stepped tuner has decisions");
                        (last.pick.clone(), t.switches())
                    })
                };
                let (wire_ratio, payload_ratio, topology, tuned) = if cfg.transport.is_wire() {
                    let mut engine =
                        WireEngine::new(layout.clone(), sim.clone()).expect("wire ring");
                    for s in 0..steps {
                        let r = engine.step(s).report;
                        wire_sum += r.wire_bytes_per_node;
                        secs += r.seconds;
                        density = r.density;
                    }
                    let acct = &engine.sim().account;
                    (
                        acct.ratio(),
                        acct.payload_ratio(),
                        engine.sim().topology().name(),
                        tuned_summary(engine.sim().tuner()),
                    )
                } else {
                    let mut engine = SimEngine::new(layout.clone(), sim.clone());
                    for s in 0..steps {
                        let r = engine.step(s);
                        wire_sum += r.wire_bytes_per_node;
                        secs += r.seconds;
                        density = r.density;
                    }
                    (
                        engine.account.ratio(),
                        engine.account.payload_ratio(),
                        engine.topology().name(),
                        tuned_summary(engine.tuner()),
                    )
                };
                // Timing pass on a fresh engine (the metrics pass above
                // doubles as its cache/branch warm-up).
                let ns = cfg.timing.then(|| {
                    let mut s = 0usize;
                    if cfg.transport.is_wire() {
                        let mut e =
                            WireEngine::new(layout.clone(), sim.clone()).expect("wire ring");
                        timer::bench(1, cfg.repeats.max(1), || {
                            std::hint::black_box(e.step(s));
                            s += 1;
                        })
                        .median_ns
                    } else {
                        let mut e = SimEngine::new(layout.clone(), sim.clone());
                        timer::bench(1, cfg.repeats.max(1), || {
                            std::hint::black_box(e.step(s));
                            s += 1;
                        })
                        .median_ns
                    }
                });
                let id = format!("step/{model_name}/{label}/n{n}");
                let mut fields = vec![
                    ("id", Json::from(id.as_str())),
                    ("model", Json::from(*model_name)),
                    ("method", Json::from(label.as_str())),
                    ("topology", Json::from(topology.as_str())),
                    ("transport", Json::from(cfg.transport.name())),
                    ("nodes", Json::from(n)),
                    ("params", Json::from(layout.total_params())),
                    ("bytes_per_node", Json::from(wire_sum as f64 / steps as f64)),
                    ("virtual_s", Json::from(secs)),
                    ("density", Json::from(density)),
                    ("wire_ratio", Json::from(wire_ratio)),
                    ("payload_ratio", Json::from(payload_ratio)),
                ];
                if let Some((last_pick, switches)) = tuned {
                    fields.push(("tuned_last_pick", Json::from(last_pick.as_str())));
                    fields.push(("tuned_switches", Json::from(switches)));
                }
                if let Some(ns) = ns {
                    fields.push(("ns_op", Json::from(ns)));
                }
                report.push(Json::obj(fields));
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::bench::canonical;

    fn tiny_cfg() -> BenchCfg {
        BenchCfg {
            quick: true,
            timing: false,
            repeats: 1,
            ring_sizes: vec![4, 8],
            ..Default::default()
        }
    }

    #[test]
    fn ring_payload_is_deterministic_across_runs() {
        let cfg = tiny_cfg();
        let a = run_ring(&cfg).to_json();
        let b = run_ring(&cfg).to_json();
        assert_eq!(canonical(&a), canonical(&b));
        // 3 schedules x 4 topologies x 2 ring sizes, plus one tuned
        // decision row per ring size.
        assert_eq!(a.get("rows").as_arr().unwrap().len(), 3 * 4 * 2 + 2);
    }

    #[test]
    fn step_payload_is_deterministic_across_runs() {
        let cfg = BenchCfg {
            ring_sizes: vec![4],
            ..tiny_cfg()
        };
        let a = run_step(&cfg).to_json();
        let b = run_step(&cfg).to_json();
        assert_eq!(canonical(&a), canonical(&b));
        // 2 models x (9 pipelines + the tuned arm) x 1 ring size.
        assert_eq!(a.get("rows").as_arr().unwrap().len(), 20);
    }

    #[test]
    fn step_sweep_covers_the_new_compositions() {
        let cfg = BenchCfg {
            ring_sizes: vec![4],
            ..tiny_cfg()
        };
        let j = run_step(&cfg).to_json();
        let methods: Vec<String> = j
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(|r| r.get("method").as_str().map(String::from))
            .collect();
        for want in [
            "iwp:vargate",
            "dgc:layerwise",
            "iwp:layerwise+q:8",
            "iwp:fixed+q:16b",
        ] {
            assert!(
                methods.iter().any(|m| m == want),
                "step sweep must carry `{want}` rows (got {methods:?})"
            );
        }
    }

    #[test]
    fn step_rows_over_uds_match_sim_bit_for_bit() {
        // Bench-level statement of the transport oracle (the full
        // matrix lives in rust/tests/transport_equivalence.rs): same
        // cfg, transport flipped — every deterministic row field is
        // bit-identical, only the `transport` label moves.
        let sim_cfg = BenchCfg {
            ring_sizes: vec![4],
            ..tiny_cfg()
        };
        let uds_cfg = BenchCfg {
            transport: TransportKind::Uds,
            ..sim_cfg.clone()
        };
        let a = run_step(&sim_cfg).to_json();
        let b = run_step(&uds_cfg).to_json();
        let (ra, rb) = (a.get("rows").as_arr().unwrap(), b.get("rows").as_arr().unwrap());
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(rb) {
            let id = x.get("id").as_str().unwrap().to_string();
            assert_eq!(id, y.get("id").as_str().unwrap());
            assert_eq!(x.get("transport").as_str(), Some("sim"));
            assert_eq!(y.get("transport").as_str(), Some("uds"));
            for field in ["bytes_per_node", "virtual_s", "density", "wire_ratio", "payload_ratio"]
            {
                assert_eq!(
                    x.get(field).as_f64().unwrap().to_bits(),
                    y.get(field).as_f64().unwrap().to_bits(),
                    "{id}: `{field}` drifts across transports"
                );
            }
        }
    }

    #[test]
    fn both_sweeps_carry_tuned_rows() {
        let cfg = BenchCfg {
            ring_sizes: vec![4],
            ..tiny_cfg()
        };
        let r = run_ring(&cfg).to_json();
        let tuned: Vec<_> = r
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|x| x.get("schedule").as_str() == Some("tuned"))
            .collect();
        assert_eq!(tuned.len(), 1, "one ring tuned row per ring size");
        assert!(tuned[0].get("pick").as_str().unwrap().contains('/'));
        assert!(tuned[0].get("predicted_s").as_f64().unwrap() > 0.0);

        let s = run_step(&cfg).to_json();
        let tuned: Vec<_> = s
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|x| x.get("method").as_str() == Some("tuned"))
            .collect();
        assert_eq!(tuned.len(), 2, "one step tuned row per model");
        for row in tuned {
            assert!(row.get("tuned_last_pick").as_str().unwrap().contains('/'));
            assert!(row.get("tuned_switches").as_f64().unwrap() >= 0.0);
            assert!(row.get("virtual_s").as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn timing_mode_adds_only_volatile_fields() {
        let quiet = tiny_cfg();
        let timed = BenchCfg {
            timing: true,
            ring_sizes: vec![4],
            ..tiny_cfg()
        };
        let a = run_ring(&BenchCfg {
            ring_sizes: vec![4],
            ..quiet
        })
        .to_json();
        let b = run_ring(&timed).to_json();
        assert_eq!(canonical(&a), canonical(&b));
        let row = &b.get("rows").as_arr().unwrap()[0];
        assert!(row.get("ns_op").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn ring_rows_carry_matching_cost_model_predictions() {
        let cfg = tiny_cfg();
        let j = run_ring(&cfg).to_json();
        let mut predicted_rows = 0;
        for row in j.get("rows").as_arr().unwrap() {
            let id = row.get("id").as_str().unwrap_or("?").to_string();
            if let Some(model_s) = row.get("model_s").as_f64() {
                predicted_rows += 1;
                let virtual_s = row.get("virtual_s").as_f64().unwrap();
                assert_eq!(
                    model_s.to_bits(),
                    virtual_s.to_bits(),
                    "cost model time disagrees with simulation on {id}"
                );
                let model_bytes = row.get("model_bytes").as_f64().unwrap();
                let total_bytes = row.get("total_bytes").as_f64().unwrap();
                assert_eq!(
                    model_bytes.to_bits(),
                    total_bytes.to_bits(),
                    "cost model bytes disagree with simulation on {id}"
                );
            }
        }
        // dense + masked rows for every topology x ring size.
        assert_eq!(predicted_rows, 2 * 4 * 2);
    }
}
