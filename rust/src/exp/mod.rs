//! Experiment harnesses — one per paper table/figure (DESIGN.md §5).
//!
//! Each harness regenerates its artifact as CSV rows under `--out`
//! (default `results/`) and prints the same rows the paper reports.
//! `ringiwp exp all` runs the whole battery.

pub mod bench;
pub mod chaosrun;
pub mod curves;
pub mod density;
pub mod figs;
pub mod io_trace;
pub mod simrun;
pub mod sweep;
pub mod table1;

/// Shared output-directory helper.
pub fn out_path(out_dir: &str, name: &str) -> String {
    format!("{out_dir}/{name}")
}
