//! Figs. 7/8 — Networks I/O traces (KB/s over time, node 0).
//!
//! Fig. 7: baseline dense ring on ResNet50 gradients — the link sits
//! near the gigabit full-load line during every exchange.
//! Fig. 8: the same workload under importance-weighted pruning — a
//! sparse trickle with idle valleys.

use crate::compress::Method;
use crate::csv_row;
use crate::exp::simrun::{SimCfg, SimEngine};
use crate::metrics::CsvWriter;
use crate::model::zoo;

/// Run baseline and IWP over ResNet50 gradients and write the node-0
/// KB/s trace CSV (`fig7_fig8_io_traces.csv`).
pub fn run(out_dir: &str, nodes: usize, steps: usize, seed: u64) -> anyhow::Result<()> {
    let mut csv = CsvWriter::create(
        format!("{out_dir}/fig7_fig8_io_traces.csv"),
        &["figure", "method", "t_seconds", "kb_per_s"],
    )?;
    println!("== Fig 7/8: node-0 I/O traces, ResNet50 grads, {nodes}-node gigabit ring ==");
    for (fig, method) in [("fig7", Method::Baseline), ("fig8", Method::IwpFixed)] {
        let cfg = SimCfg {
            nodes,
            method: method.spec(),
            seed,
            ..Default::default()
        };
        let mut engine = SimEngine::new(zoo::resnet50(), cfg);
        for s in 0..steps {
            engine.step(s);
        }
        let series = engine.net().trace().kbps_series(0);
        for &(t, kbps) in &series {
            csv_row!(csv, fig, method.name(), t, kbps)?;
        }
        let peak = engine.net().trace().peak_kbps(0);
        let mean = engine.net().trace().mean_kbps(0);
        println!(
            "  {fig} ({:<12}): peak {:>12.0} KB/s, mean {:>12.0} KB/s over {:.1}s virtual",
            method.name(),
            peak,
            mean,
            engine.net().clock()
        );
    }
    csv.flush()?;
    println!(
        "paper: baseline ~full gigabit load (~120000 KB/s peak); IWP a sparse trickle\n       (orders of magnitude lower mean I/O)"
    );
    Ok(())
}
