//! Figs. 2–4 — importance-distribution measurements.
//!
//! * Fig. 2: histogram of gradient importance, convolutional layers.
//! * Fig. 3: histogram of gradient importance, batch-norm layers.
//! * Fig. 4: var/mean of the first downsample layer over training steps
//!   (the signal driving the Eq. 4 layerwise controller).

use crate::compress::Method;
use crate::csv_row;
use crate::exp::simrun::{SimCfg, SimEngine};
use crate::metrics::CsvWriter;
use crate::model::zoo;
use crate::model::LayerKind;
use crate::util::stats::Histogram;

/// Figs. 2/3: log10-importance histograms per layer kind at a few steps.
pub fn run_fig2_fig3(out_dir: &str, steps: usize, seed: u64) -> anyhow::Result<()> {
    let layout = zoo::resnet50();
    let cfg = SimCfg {
        nodes: 8,
        method: Method::IwpFixed.spec(),
        seed,
        ..Default::default()
    };
    let mut engine = SimEngine::new(layout, cfg);

    let snapshots = [0usize, steps / 2, steps.saturating_sub(1)];
    let mut csv = CsvWriter::create(
        format!("{out_dir}/fig2_fig3_importance_hist.csv"),
        &["figure", "kind", "step", "log10_importance_bin", "count"],
    )?;
    println!("== Fig 2/3: importance distributions (ResNet50, synthetic grads) ==");
    for s in 0..steps {
        engine.step(s);
        if !snapshots.contains(&s) {
            continue;
        }
        let layout = engine.layout().clone();
        let (imp, _) = engine.importance_snapshot();
        for (fig, kind) in [("fig2", LayerKind::Conv), ("fig3", LayerKind::BatchNorm)] {
            let mut hist = Histogram::log10(-8, 2, 5);
            for layer in layout.of_kind(kind) {
                for &v in &imp[layer.range()] {
                    hist.push_log10(v as f64);
                }
            }
            let total = hist.total().max(1);
            let mut mode = (0.0, 0u64);
            for (center, count) in hist.rows() {
                csv_row!(csv, fig, kind.name(), s, center, count)?;
                if count > mode.1 {
                    mode = (center, count);
                }
            }
            println!(
                "  {fig} step {s:>4} {}: n={total}, mode at log10(I)≈{:.1}, under={} over={}",
                kind.name(),
                mode.0,
                hist.under,
                hist.over
            );
        }
    }
    csv.flush()?;
    println!("paper: conv and bn importance distributions differ in location/shape;\n       both shift as training progresses");
    Ok(())
}

/// Fig. 4: var/mean of the first downsample layer over steps.
pub fn run_fig4(out_dir: &str, steps: usize, seed: u64) -> anyhow::Result<()> {
    let layout = zoo::resnet50();
    let target = "layer1.0.downsample.conv.weight";
    let target_idx = layout
        .layers()
        .iter()
        .position(|l| l.name == target)
        .expect("resnet50 has a first downsample layer");
    let cfg = SimCfg {
        nodes: 8,
        method: Method::IwpLayerwise.spec(),
        seed,
        ..Default::default()
    };
    let mut engine = SimEngine::new(layout, cfg);
    let mut csv = CsvWriter::create(
        format!("{out_dir}/fig4_var_over_mean.csv"),
        &["step", "layer", "var_over_mean", "mean", "var"],
    )?;
    println!("== Fig 4: var/mean of `{target}` over steps ==");
    let mut series = Vec::new();
    for s in 0..steps {
        engine.step(s);
        let (_, stats) = engine.importance_snapshot();
        let st = &stats[target_idx];
        series.push(st.var_over_mean());
        csv_row!(csv, s, target, st.var_over_mean(), st.mean(), st.var())?;
    }
    csv.flush()?;
    let (min, max) = series
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
    println!(
        "  {} steps: var/mean in [{min:.3}, {max:.3}] — fluctuating layer dispersion",
        series.len()
    );
    println!("paper: var/mean of the downsample layer fluctuates strongly over steps,\n       motivating the adaptive Eq. 4 threshold");
    Ok(())
}
