//! Sec. IV-A threshold sweep — "We have set our threshold to 0.005,
//! 0.01, 0.05, 0.1": compression ratio + wire density per threshold on
//! both inventories, plus (with artifacts) final accuracy on the real
//! MLP.  Also the mask-node ablation r ∈ {1,2,4,8} (Alg. 1 line 6).

use crate::compress::Method;
use crate::config::Config;
use crate::coordinator::Trainer;
use crate::csv_row;
use crate::exp::simrun::{SimCfg, SimEngine};
use crate::metrics::CsvWriter;
use crate::model::zoo;
use crate::runtime::Runtime;

/// The thresholds the paper sweeps in Sec. IV-A.
pub const PAPER_THRESHOLDS: [f32; 4] = [0.005, 0.01, 0.05, 0.1];

/// Threshold sweep + mask-node and random-selection ablations; writes
/// one CSV per sweep.
pub fn run(rt: Option<&Runtime>, out_dir: &str, steps: usize, seed: u64) -> anyhow::Result<()> {
    let mut csv = CsvWriter::create(
        format!("{out_dir}/threshold_sweep.csv"),
        &["model", "threshold", "compress_ratio", "mean_density"],
    )?;
    println!("== Threshold sweep (Sec. IV-A): 96-node ring, synthetic grads ==");
    println!(
        "{:<10} {:>10} {:>14} {:>12}",
        "Model", "thr", "ratio", "density"
    );
    for (name, layout) in [
        ("AlexNet", zoo::alexnet()),
        ("ResNet50", zoo::resnet50()),
        ("ResNet101", zoo::resnet101_cifar10()),
    ] {
        for &thr in &PAPER_THRESHOLDS {
            let cfg = SimCfg {
                nodes: 96,
                method: Method::IwpFixed.spec(),
                threshold: thr,
                seed,
                ..Default::default()
            };
            let mut engine = SimEngine::new(layout.clone(), cfg);
            for s in 0..steps {
                engine.step(s);
            }
            let ratio = engine.account.ratio();
            let density = engine.account.mean_density();
            println!("{name:<10} {thr:>10} {ratio:>13.1}x {density:>12.5}");
            csv_row!(csv, name, thr as f64, ratio, density)?;
        }
    }
    csv.flush()?;

    // Mask-node count ablation.
    let mut csv = CsvWriter::create(
        format!("{out_dir}/mask_nodes_ablation.csv"),
        &["mask_nodes", "compress_ratio", "mean_density"],
    )?;
    println!("\n== Mask-broadcaster ablation (r random nodes, Alg. 1) ==");
    for r in [1usize, 2, 4, 8] {
        let cfg = SimCfg {
            nodes: 32,
            method: Method::IwpFixed.spec(),
            mask_nodes: r,
            seed,
            ..Default::default()
        };
        let mut engine = SimEngine::new(zoo::resnet50(), cfg);
        for s in 0..steps {
            engine.step(s);
        }
        println!(
            "  r={r}: ratio {:>8.1}x, density {:.5}",
            engine.account.ratio(),
            engine.account.mean_density()
        );
        csv_row!(csv, r, engine.account.ratio(), engine.account.mean_density())?;
    }
    csv.flush()?;

    // Random-selection ablation on the real model.
    if let Some(rt) = rt {
        println!("\n== Random-gradient-selection ablation (real MLP) ==");
        let mut csv = CsvWriter::create(
            format!("{out_dir}/random_select_ablation.csv"),
            &["random_select", "eval_acc", "eval_loss", "compress_ratio"],
        )?;
        for random_select in [true, false] {
            let cfg = Config {
                method: Method::IwpFixed.spec(),
                steps: 80,
                seed,
                threshold: 200.0, // see table1::accuracy_rows on scaling
                random_select,
                ..Config::default()
            };
            let mut t = Trainer::new(cfg, rt)?;
            let out = t.run()?;
            println!(
                "  random_select={random_select:<5} acc {:.4}, loss {:.4}, ratio {:.1}x",
                out.final_eval_acc,
                out.final_eval_loss,
                out.account.ratio()
            );
            csv_row!(
                csv,
                if random_select { "on" } else { "off" },
                out.final_eval_acc,
                out.final_eval_loss,
                out.account.ratio()
            )?;
        }
        csv.flush()?;
    }
    println!("\npaper: higher thresholds -> higher ratio; random selection preserves accuracy\n       by resisting gradient staleness");
    Ok(())
}
