//! Chaos sweep harness behind `ringiwp chaos` (DESIGN.md §15,
//! EXPERIMENTS.md §12).
//!
//! One run replays a deterministic [`ChaosPlan`] against every
//! configured compression pipeline × reduce topology × recovery mode,
//! checking the recovery invariants *around* every membership event:
//!
//! * **residual conservation** — a single-crash step preserves the
//!   total pending gradient mass under `handoff` (merge into the ring
//!   successor), and rescales it by exactly N/(N−1) under `rescale`
//!   (modulo f32 arithmetic; exchangeable crashes beyond the
//!   materialized-state cap leave handoff state untouched);
//! * **bounded staleness** — every pending residual stays finite after
//!   every step, faulty or not;
//! * **mask/support consistency** — reported support sizes and
//!   densities stay within the model's coordinate budget.
//!
//! Everything observable is folded into an FNV-1a digest of the
//! [`StepReport`] stream, so `ringiwp chaos --seed N` run twice prints
//! byte-identical output — the goldenable contract the CI smoke pins
//! with `cmp`.

use crate::compress::MethodSpec;
use crate::exp::bench::step_specs;
use crate::exp::simrun::{SimCfg, SimEngine, StepReport, WireEngine};
use crate::model::{LayerKind, ParamLayout};
use crate::net::{
    ChaosEvent, ChaosPlan, FaultPlan, LinkSpec, RecoveryMode, RecoveryStats, TopoKind,
    TransportKind, TunerMode,
};
use crate::util::exit::ExitClass;

/// Sweep configuration (the `ringiwp chaos` flag surface).
#[derive(Debug, Clone)]
pub struct ChaosCfg {
    /// Starting ring size.
    pub nodes: usize,
    /// Engine steps per configuration (extended to cover the plan).
    pub steps: usize,
    /// The fault schedule (its `mode` field is overridden per sweep arm).
    pub plan: ChaosPlan,
    /// Recovery modes to sweep.
    pub modes: Vec<RecoveryMode>,
    /// Compression pipelines to sweep.
    pub specs: Vec<MethodSpec>,
    /// Reduce topologies to sweep.
    pub topologies: Vec<TopoKind>,
    /// `sim` checks the virtual engine; `uds`/`tcp` run the same sweep
    /// through real socket rings (re-ringing on every membership event).
    pub transport: TransportKind,
    /// Engine seed (gradient + selection streams).
    pub seed: u64,
    /// Wire connect/read deadline in milliseconds (`--wire-timeout-ms`);
    /// sim arms ignore it.
    pub wire_timeout_ms: u64,
    /// Explicit wire-fault schedule (`--wire-faults`, default
    /// `RINGIWP_WIRE_FAULTS`). When set it overrides any wire tokens
    /// riding in the chaos plan; sim arms ignore it.
    pub wire_faults: Option<FaultPlan>,
}

impl Default for ChaosCfg {
    fn default() -> Self {
        ChaosCfg {
            nodes: 5,
            steps: 10,
            plan: ChaosPlan::none(),
            modes: vec![RecoveryMode::Handoff, RecoveryMode::DropRescale],
            specs: step_specs().to_vec(),
            topologies: sweep_topologies().to_vec(),
            transport: TransportKind::Sim,
            seed: 17,
            wire_timeout_ms: crate::net::wire::wire_timeout_from_env(),
            wire_faults: FaultPlan::from_env(),
        }
    }
}

/// The topology sweep: one representative of every family
/// (DESIGN.md §10–§11).
pub fn sweep_topologies() -> [TopoKind; 4] {
    [
        TopoKind::Flat,
        TopoKind::Hier { group: 2 },
        TopoKind::Tree,
        TopoKind::parse("pipeline:2:flat").expect("static topo spec"),
    ]
}

/// Small 3-layer inventory the sweep runs over — big enough for every
/// pipeline's selection paths, small enough for 56 engine builds.
pub fn harness_layout() -> ParamLayout {
    ParamLayout::new(
        "chaos_harness",
        vec![
            ("conv".into(), vec![16, 8, 3, 3], LayerKind::Conv),
            ("bn".into(), vec![32], LayerKind::BatchNorm),
            ("fc".into(), vec![64, 10], LayerKind::Fc),
        ],
    )
}

/// Deterministic sweep result.
#[derive(Debug)]
pub struct ChaosSummary {
    /// One report line per swept configuration (stable order).
    pub lines: Vec<String>,
    /// FNV-1a digest over every configuration's `StepReport` stream.
    pub digest: u64,
    /// Configurations swept.
    pub configs: usize,
    /// Single-crash recovery events whose conservation invariant was
    /// checked (pipelines without pending state contribute none).
    pub recovery_events: usize,
    /// Wire-level recovery totals summed over every swept configuration
    /// (DESIGN.md §16). All-zero on sim transports and on fault-free
    /// wire sweeps; deterministic for a given plan, so it is part of
    /// the goldenable output. Kept *out* of [`ChaosSummary::digest`] —
    /// the digest compares payload results across transports, and the
    /// sim oracle does no wire recovery by construction.
    pub wire_recovery: RecoveryStats,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

fn fnv_report(h: &mut u64, r: &StepReport) {
    fnv(h, &r.wire_bytes_per_node.to_le_bytes());
    fnv(h, &r.density.to_bits().to_le_bytes());
    fnv(h, &r.seconds.to_bits().to_le_bytes());
    fnv(h, &r.wire_seconds.to_bits().to_le_bytes());
    fnv(h, &r.support_nnz.to_le_bytes());
}

/// Either engine flavor behind one `apply_chaos`/`step` surface.
enum Engine {
    Sim(SimEngine),
    Wire(WireEngine),
}

impl Engine {
    fn build(layout: ParamLayout, cfg: SimCfg) -> anyhow::Result<Engine> {
        if cfg.transport.is_wire() {
            Ok(Engine::Wire(WireEngine::new(layout, cfg)?))
        } else {
            Ok(Engine::Sim(SimEngine::new(layout, cfg)))
        }
    }

    fn sim(&self) -> &SimEngine {
        match self {
            Engine::Sim(e) => e,
            Engine::Wire(w) => w.sim(),
        }
    }

    fn apply_chaos(&mut self, step: usize) -> bool {
        match self {
            Engine::Sim(e) => e.apply_chaos(step),
            Engine::Wire(w) => w.apply_chaos(step),
        }
    }

    fn step(&mut self, step: usize) -> StepReport {
        match self {
            Engine::Sim(e) => e.step(step),
            Engine::Wire(w) => w.step(step).report,
        }
    }

    /// Tear a wire engine's ring down and return the final (exact)
    /// recovery totals; sim engines have nothing to reap.
    fn finish(&mut self) -> anyhow::Result<RecoveryStats> {
        match self {
            Engine::Sim(_) => Ok(RecoveryStats::default()),
            Engine::Wire(w) => {
                w.shutdown()?;
                Ok(w.recovery_stats())
            }
        }
    }
}

fn add_stats(total: &mut RecoveryStats, r: RecoveryStats) {
    total.retransmits += r.retransmits;
    total.reconnects += r.reconnects;
    total.dup_drops += r.dup_drops;
    total.nacks += r.nacks;
    total.backoff_us += r.backoff_us;
}

/// Per-store pending-mass sums (f64, index order); `None` for
/// residual-free pipelines (dense, terngrad).
fn pending_sums(e: &SimEngine) -> Option<Vec<f64>> {
    let states = e.cfg.nodes.min(SimEngine::SIM_NODE_CAP);
    let mut sums = Vec::with_capacity(states);
    for i in 0..states {
        sums.push(e.pending(i)?.iter().map(|&v| v as f64).sum());
    }
    Some(sums)
}

/// Absolute pending mass — the scale conservation tolerances hang off.
fn pending_scale(e: &SimEngine) -> f64 {
    let states = e.cfg.nodes.min(SimEngine::SIM_NODE_CAP);
    (0..states)
        .filter_map(|i| e.pending(i))
        .flat_map(|p| p.iter())
        .map(|&v| v.abs() as f64)
        .sum()
}

/// Run the sweep; every invariant violation is a typed error naming the
/// configuration and step it fired at.
pub fn run(cfg: &ChaosCfg) -> anyhow::Result<ChaosSummary> {
    cfg.plan
        .validate(cfg.nodes)
        .map_err(|e| anyhow::anyhow!(e).context(ExitClass::Config))?;
    let steps = cfg.steps.max(cfg.plan.max_step() + 2);
    let layout = harness_layout();
    let mut summary = ChaosSummary {
        lines: Vec::new(),
        digest: FNV_OFFSET,
        configs: 0,
        recovery_events: 0,
        wire_recovery: RecoveryStats::default(),
    };
    for &mode in &cfg.modes {
        let mut plan = cfg.plan.clone();
        plan.mode = mode;
        for &spec in &cfg.specs {
            for &topo in &cfg.topologies {
                let (digest, events, recovery) =
                    run_one(cfg, plan.clone(), spec, topo, steps, layout.clone())
                        .map_err(|e| {
                            // A WireError anywhere in the chain is a
                            // transport failure (exit 3); everything
                            // else run_one raises is a broken recovery
                            // invariant (exit 4).
                            let class = if e
                                .chain()
                                .any(|c| c.downcast_ref::<crate::net::WireError>().is_some())
                            {
                                ExitClass::Transport
                            } else {
                                ExitClass::Invariant
                            };
                            e.context(format!(
                                "chaos config mode={mode} spec={} topo={}",
                                spec.name(),
                                topo.name()
                            ))
                            .context(class)
                        })?;
                summary.lines.push(format!(
                    "mode={:<8} spec={:<16} topo={:<16} steps={steps} checked={events} \
                     digest={digest:016x}",
                    mode.name(),
                    spec.name(),
                    topo.name(),
                ));
                fnv(&mut summary.digest, &digest.to_le_bytes());
                summary.configs += 1;
                summary.recovery_events += events;
                add_stats(&mut summary.wire_recovery, recovery);
            }
        }
    }
    Ok(summary)
}

fn run_one(
    cfg: &ChaosCfg,
    plan: ChaosPlan,
    spec: MethodSpec,
    topo: TopoKind,
    steps: usize,
    layout: ParamLayout,
) -> anyhow::Result<(u64, usize, RecoveryStats)> {
    let mode = plan.mode;
    let sim_cfg = SimCfg {
        nodes: cfg.nodes,
        method: spec,
        mask_nodes: cfg.nodes.min(3),
        steps_per_epoch: 3,
        warmup_epochs: 1,
        seed: cfg.seed,
        link: LinkSpec::new(1e9, 0.0),
        parallelism: 1,
        topology: topo,
        transport: cfg.transport,
        wire_dir: None,
        tuner: TunerMode::Off,
        chaos: Some(plan.clone()),
        // Fault precedence: --wire-faults / RINGIWP_WIRE_FAULTS (both
        // land in `cfg.wire_faults`) beat the plan's own wire tokens
        // (WireEngine falls back to `chaos.wire` when this is unset).
        wire_faults: cfg.wire_faults.clone(),
        wire_timeout_ms: cfg.wire_timeout_ms,
        ..Default::default()
    };
    let total = layout.total_params() as u64;
    let mut engine = Engine::build(layout, sim_cfg)?;
    let mut digest = FNV_OFFSET;
    let mut events = 0usize;
    let mut expected_n = cfg.nodes;
    for step in 0..steps {
        let firing: Vec<ChaosEvent> = plan.events_at(step).copied().collect();
        // Conservation is checked on single-crash steps (seeded plans
        // schedule at most one event per step); compound steps still get
        // the membership + staleness + consistency checks below.
        let crash = match firing[..] {
            [ChaosEvent::Crash { node, .. }] => Some(node),
            _ => None,
        };
        let before = crash.and_then(|_| pending_sums(engine.sim()));
        let scale = pending_scale(engine.sim());
        engine.apply_chaos(step);
        for ev in &firing {
            match ev {
                ChaosEvent::Crash { .. } => expected_n -= 1,
                ChaosEvent::Join { .. } => expected_n += 1,
                _ => {}
            }
        }
        anyhow::ensure!(
            engine.sim().cfg.nodes == expected_n,
            "step {step}: membership {} after events, expected {expected_n}",
            engine.sim().cfg.nodes
        );
        if let (Some(node), Some(before)) = (crash, before) {
            let after = pending_sums(engine.sim())
                .ok_or_else(|| anyhow::anyhow!("pending state vanished across recovery"))?;
            let sum_before: f64 = before.iter().sum();
            let sum_after: f64 = after.iter().sum();
            let nodes_after = engine.sim().cfg.nodes;
            let tol = 1e-4 * (1.0 + scale);
            let expected = match mode {
                // Handoff merges the departing store into its ring
                // successor: total mass is conserved. An exchangeable
                // crash (node beyond the materialized-state cap) owns no
                // store, so handoff leaves every survivor untouched.
                RecoveryMode::Handoff => sum_before,
                // Rescale drops the departing store and scales every
                // survivor by N/(N−1); exchangeable crashes have no
                // store to drop but still rescale.
                RecoveryMode::DropRescale => {
                    let factor = (nodes_after + 1) as f64 / nodes_after as f64;
                    let departed = before.get(node).copied().unwrap_or(0.0);
                    (sum_before - departed) * factor
                }
            };
            anyhow::ensure!(
                (sum_after - expected).abs() <= tol,
                "step {step}: crash@{node} mode={mode} pending mass {sum_after} \
                 (expected {expected}, tol {tol})"
            );
            events += 1;
        }
        let r = engine.step(step);
        anyhow::ensure!(
            r.density.is_finite() && (0.0..=1.0 + 1e-9).contains(&r.density),
            "step {step}: density {} out of range",
            r.density
        );
        anyhow::ensure!(
            r.support_nnz <= total,
            "step {step}: support {} exceeds {total} coordinates",
            r.support_nnz
        );
        anyhow::ensure!(
            r.seconds > 0.0 && r.wire_seconds.is_finite() && r.wire_seconds >= 0.0,
            "step {step}: degenerate timing {}/{}",
            r.seconds,
            r.wire_seconds
        );
        // Bounded staleness: no recovery path may inject NaN/inf into a
        // surviving residual store.
        let states = engine.sim().cfg.nodes.min(SimEngine::SIM_NODE_CAP);
        for i in 0..states {
            if let Some(p) = engine.sim().pending(i) {
                anyhow::ensure!(
                    p.iter().all(|v| v.is_finite()),
                    "step {step}: node {i} pending state went non-finite"
                );
            }
        }
        fnv_report(&mut digest, &r);
    }
    // Join session threads before reading totals: counters are only
    // exact post-shutdown, and an unrecoverable fault that slipped past
    // the step loop surfaces here as its typed error.
    let recovery = engine.finish()?;
    Ok((digest, events, recovery))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Method;

    fn tiny(transport: TransportKind) -> ChaosCfg {
        ChaosCfg {
            nodes: 5,
            steps: 8,
            plan: ChaosPlan::parse("crash@2:1,slow@3:0:4,join@5,heal@6,crash@7:2").unwrap(),
            specs: vec![Method::IwpFixed.spec(), Method::Dgc.spec()],
            topologies: vec![TopoKind::Flat],
            transport,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let cfg = tiny(TransportKind::Sim);
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.lines, b.lines);
        assert_eq!(a.configs, 4, "2 specs x 1 topo x 2 modes");
    }

    #[test]
    fn conservation_is_checked_on_every_crash() {
        let s = run(&tiny(TransportKind::Sim)).unwrap();
        // Both pipelines keep pending state: 4 runs x 2 single-crash
        // steps each.
        assert_eq!(s.recovery_events, 8);
    }

    #[test]
    fn wire_sweep_reproduces_the_sim_digest() {
        let mut cfg = tiny(TransportKind::Sim);
        cfg.specs = vec![Method::IwpFixed.spec()];
        cfg.modes = vec![RecoveryMode::Handoff];
        let sim = run(&cfg).unwrap();
        cfg.transport = TransportKind::Uds;
        let uds = run(&cfg).unwrap();
        assert_eq!(sim.digest, uds.digest, "sim is the oracle across re-rings");
        // No wire faults scheduled → no recovery activity.
        assert_eq!(uds.wire_recovery, RecoveryStats::default());
    }

    #[test]
    fn wire_faults_recover_bit_identically_to_the_sim_oracle() {
        // One grammar string schedules membership churn AND byte-level
        // frame faults; the recovered uds sweep must still reproduce
        // the fault-free sim digest (DESIGN.md §16), with the recovery
        // totals proving the faults actually fired.
        let mut cfg = tiny(TransportKind::Sim);
        cfg.specs = vec![Method::IwpFixed.spec()];
        cfg.modes = vec![RecoveryMode::Handoff];
        let sim = run(&cfg).unwrap();
        cfg.transport = TransportKind::Uds;
        cfg.wire_timeout_ms = 5_000;
        cfg.plan = ChaosPlan::parse(
            "crash@2:1,slow@3:0:4,join@5,heal@6,crash@7:2,seed=9,flip@0:0,dup@1:1,reset@2:2",
        )
        .unwrap();
        let uds = run(&cfg).unwrap();
        assert_eq!(
            sim.digest, uds.digest,
            "recovered wire sweep must match the fault-free sim oracle"
        );
        let rec = uds.wire_recovery;
        assert!(rec.retransmits >= 1, "{rec}");
        assert!(rec.reconnects >= 1, "{rec}");
        assert!(rec.dup_drops >= 1, "{rec}");
        assert_eq!(sim.wire_recovery, RecoveryStats::default());
    }

    #[test]
    fn generated_plans_survive_the_residual_pipelines() {
        for seed in [1u64, 2, 3] {
            let cfg = ChaosCfg {
                plan: ChaosPlan::generate(seed, 5, 8),
                specs: vec![Method::IwpLayerwise.spec(), Method::Dgc.spec()],
                topologies: vec![TopoKind::Flat, TopoKind::Tree],
                ..Default::default()
            };
            run(&cfg).unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        }
    }

    #[test]
    fn invalid_plans_are_rejected_up_front() {
        let cfg = ChaosCfg {
            plan: ChaosPlan::parse("crash@1:9").unwrap(),
            ..Default::default()
        };
        assert!(run(&cfg).is_err());
    }
}
