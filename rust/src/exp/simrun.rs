//! Synthetic-gradient simulation engine — runs the full compression +
//! ring transport stack over the paper's real AlexNet/ResNet50 layer
//! inventories at any ring size, without PJRT (the models are far too
//! large to *train* on this testbed; their wire behaviour is exact —
//! DESIGN.md §2).
//!
//! The engine mirrors `coordinator::Trainer`'s reduce paths 1:1 but
//! sources gradients from `grad::SynthGrads` and scores importance with
//! the CPU mirror of the L1 kernel (bit-identical semantics, cross-
//! validated in `tests/runtime_smoke.rs`). Since the compressor
//! subsystem (DESIGN.md §12) both engines reduce through the
//! [`Compressor`] trait: the engine owns the gradient streams, the
//! virtual net, the topology, and the accounting; the configured
//! compression pipeline owns every method-specific piece of state.

use crate::compress::importance::{LayerStats, EPS};
use crate::compress::pipeline::{self, SimCtx, StageCfg};
use crate::compress::{Compressor, Method, MethodSpec};
use crate::grad::SynthGrads;
use crate::metrics::CompressionAccount;
use crate::model::ParamLayout;
use crate::net::{
    ChaosEvent, ChaosPlan, LinkSpec, RecoveryMode, RingNet, TopoKind, Topology, TransportKind,
    Tuner, TunerMode, WireError, WireRing,
};
use crate::ring::{Arena, Executor};
use crate::util::rng::Rng;

/// Engine configuration (subset of `config::Config` relevant here).
#[derive(Debug, Clone)]
pub struct SimCfg {
    /// Simulated ring size N.
    pub nodes: usize,
    /// Compression pipeline under test (`compress::spec` grammar;
    /// legacy `Method` values convert via [`Method::spec`]).
    pub method: MethodSpec,
    /// Importance threshold (α for the layer-adaptive controllers).
    pub threshold: f32,
    /// Eq. 4 dispersion gain β.
    pub beta: f32,
    /// Eq. 4 crossover C.
    pub c: f32,
    /// Number of random mask-broadcast nodes r (Alg. 1).
    pub mask_nodes: usize,
    /// Random gradient selection on/off (Sec. III-C).
    pub random_select: bool,
    /// Residual-store momentum (momentum correction).
    pub momentum: f32,
    /// DGC baseline per-node density.
    pub dgc_density: f64,
    /// Steps per "epoch" for epoch-indexed schedules.
    pub steps_per_epoch: usize,
    /// DGC/IWP warm-up epochs.
    pub warmup_epochs: usize,
    /// Root seed for every stochastic stream.
    pub seed: u64,
    /// Link model of the simulated ring.
    pub link: LinkSpec,
    /// Worker threads for the node-parallel engine (`ring::exec`,
    /// DESIGN.md §4). 1 = sequential oracle, bit-identical results at
    /// any width.
    pub parallelism: usize,
    /// Communication topology the reduce runs over (`net::topo`,
    /// DESIGN.md §10). Defaults to `RINGIWP_TOPOLOGY`, else the flat
    /// ring — which is bit-identical to the pre-topology engine.
    pub topology: TopoKind,
    /// Transport the engine runs on (`net::wire`, DESIGN.md §13):
    /// `sim` stays in-process; `uds`/`tcp` route every traveling
    /// payload through real sockets via [`WireEngine`]. Defaults to
    /// `RINGIWP_TRANSPORT`, else `sim`.
    pub transport: TransportKind,
    /// Rendezvous directory of an external `ringiwp serve` ring; when
    /// set (flag or `RINGIWP_WIRE_DIR`), [`WireEngine`] attaches to
    /// the serve ranks instead of spawning in-process ones.
    pub wire_dir: Option<std::path::PathBuf>,
    /// Online protocol autotuner (`net::tuner`, DESIGN.md §14):
    /// `off` keeps the static strategy, `log-only` prices the grid and
    /// records decisions while still running the static path, `on`
    /// executes each step's argmin pick. Defaults to `RINGIWP_TUNER`,
    /// else `off`.
    pub tuner: TunerMode,
    /// Deterministic fault-injection schedule (`net::chaos`, DESIGN.md
    /// §15): crashes, stragglers, heals, and joins replayed at fixed
    /// step indices. `None` — and an empty plan — leave every report
    /// bit-identical to the pre-chaos engine. Defaults to
    /// `RINGIWP_CHAOS`, else `None`.
    pub chaos: Option<ChaosPlan>,
    /// Seeded byte-level wire faults (`net::wire::fault`, DESIGN.md
    /// §16), applied to ring-edge writes of an in-process socket ring.
    /// Overrides the wire half of `chaos` when both are set. `None` —
    /// and an empty plan — are bit-identical to a fault-free ring.
    /// Defaults to `RINGIWP_WIRE_FAULTS`, else `None`.
    pub wire_faults: Option<crate::net::FaultPlan>,
    /// Wire connect/read deadline in milliseconds and the base the v2
    /// ARQ timeouts derive from (`--wire-timeout-ms`). Defaults to
    /// `RINGIWP_WIRE_TIMEOUT_MS`, else 30 000 (the historical
    /// `READ_TIMEOUT`/`CONNECT_TIMEOUT` constants).
    pub wire_timeout_ms: u64,
}

impl Default for SimCfg {
    fn default() -> Self {
        SimCfg {
            nodes: 96, // the paper's cluster size
            method: MethodSpec::from_env_or(Method::IwpFixed.spec()),
            // Paper sweeps 0.005–0.1; the headline 64x/58.8x ratios live
            // at the aggressive end once random selection (P = I/thr)
            // adds its expected sub-threshold traffic.
            threshold: 0.05,
            beta: 0.002,
            c: 1.0,
            mask_nodes: 3,
            random_select: true,
            momentum: 0.9,
            dgc_density: 0.01,
            steps_per_epoch: 100,
            warmup_epochs: 0,
            seed: 17,
            link: LinkSpec::gigabit_ethernet(),
            parallelism: default_parallelism(),
            topology: TopoKind::from_env(),
            transport: TransportKind::from_env(),
            wire_dir: std::env::var_os("RINGIWP_WIRE_DIR").map(std::path::PathBuf::from),
            tuner: TunerMode::from_env(),
            chaos: ChaosPlan::from_env(),
            wire_faults: crate::net::FaultPlan::from_env(),
            wire_timeout_ms: crate::net::wire::wire_timeout_from_env(),
        }
    }
}

/// Environment knob: `RINGIWP_PARALLELISM` sets the default executor
/// width for every experiment harness (results are bit-identical at any
/// width, so this only changes wall-clock).
fn default_parallelism() -> usize {
    std::env::var("RINGIWP_PARALLELISM")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&p| p >= 1)
        .unwrap_or(1)
}

/// Per-step report.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Mean wire bytes transmitted per node this step.
    pub wire_bytes_per_node: u64,
    /// Transmitted gradient density this step.
    pub density: f64,
    /// Virtual seconds this step occupied on the net (wire + the fixed
    /// compute gap).
    pub seconds: f64,
    /// Virtual seconds of the wire phase alone — equal to the matching
    /// `CostModel` prediction bit-for-bit on a fresh clock
    /// (DESIGN.md §12).
    pub wire_seconds: f64,
    /// Selected support size this step (see
    /// `compress::WireOutcome::support_nnz`).
    pub support_nnz: u64,
}

/// The simulation engine.
pub struct SimEngine {
    /// The configuration this engine was built with.
    pub cfg: SimCfg,
    layout: ParamLayout,
    synth: SynthGrads,
    net: RingNet,
    rngs: Vec<Rng>,
    ctl_rng: Rng,
    /// Compression accounting over the whole run.
    pub account: CompressionAccount,
    exec: Executor,
    topo: Box<dyn Topology>,
    arena: Arena,
    /// The configured compression pipeline — owns every method-specific
    /// piece of per-node state (DESIGN.md §12).
    comp: Box<dyn Compressor>,
    /// Online autotuner (DESIGN.md §14); `None` when `cfg.tuner` is
    /// `off`. Owns the candidate grid and the decision trace.
    tuner: Option<Tuner>,
    imp_scratch: Vec<f32>,
    /// Cached per-layer stats buffer behind `importance_snapshot`
    /// (refilled in place — no per-call allocation).
    snap_stats: Vec<LayerStats>,
    grads: Vec<Vec<f32>>,
    /// Current per-hop link table (entry `i` = node `i`'s outgoing
    /// edge) — the elastic-membership source of truth the virtual net,
    /// the tuner, and wire re-rings all read (DESIGN.md §15).
    links: Vec<LinkSpec>,
    /// Seed stream for mid-epoch joiners' gradient jitter (split after
    /// every build-time stream, so pre-chaos runs stay bit-identical).
    join_rng: Rng,
    /// First step whose chaos events have not fired yet — the cursor
    /// that makes [`SimEngine::apply_chaos`] idempotent.
    next_chaos_step: usize,
}

impl SimEngine {
    /// Cap on *materialized* node states. Nodes are exchangeable
    /// (identical gradient distribution, disjoint shards), so wire
    /// accounting at ring size N only needs: the r mask broadcasters'
    /// residual states (IWP), one representative TernGrad encoder, and
    /// per-node *supports* (DGC — synthesized as exchangeable draws
    /// beyond the cap). Keeps 96-node x 61M-param sims in memory.
    /// Public so the chaos harnesses know how many [`SimEngine::pending`]
    /// stores exist at a given membership (DESIGN.md §15).
    pub const SIM_NODE_CAP: usize = 4;

    /// Build an engine over `layout` with configuration `cfg`.
    pub fn new(layout: ParamLayout, cfg: SimCfg) -> Self {
        let total = layout.total_params();
        let mut root = Rng::new(cfg.seed);
        let state_nodes = cfg.nodes.min(Self::SIM_NODE_CAP);
        let comp = pipeline::build(
            cfg.method,
            &StageCfg {
                nodes: cfg.nodes,
                state_nodes,
                threshold: cfg.threshold,
                beta: cfg.beta,
                c: cfg.c,
                mask_nodes: cfg.mask_nodes,
                random_select: cfg.random_select,
                momentum: cfg.momentum,
                dgc_density: cfg.dgc_density,
                warmup_epochs: cfg.warmup_epochs,
            },
            &layout,
        );
        SimEngine {
            synth: SynthGrads::new(layout.clone(), cfg.seed ^ 0x5EED),
            net: RingNet::new(cfg.nodes, cfg.link, 0.05),
            rngs: (0..cfg.nodes).map(|i| root.split(i as u64)).collect(),
            ctl_rng: root.split(0xC011),
            links: vec![cfg.link; cfg.nodes],
            // Split LAST: root's state advances past the per-node and
            // control streams, so adding this stream changes nothing
            // about them — pre-chaos runs stay bit-identical.
            join_rng: root.split(0x1014),
            next_chaos_step: 0,
            account: CompressionAccount::new(),
            exec: Executor::new(cfg.parallelism),
            topo: cfg.topology.build(cfg.nodes),
            arena: Arena::for_nodes(cfg.nodes),
            comp,
            tuner: (cfg.tuner != TunerMode::Off)
                .then(|| Tuner::new(cfg.tuner, cfg.nodes, cfg.link)),
            imp_scratch: vec![0.0; total],
            snap_stats: Vec::with_capacity(layout.n_layers()),
            grads: vec![vec![0.0; total]; state_nodes],
            layout,
            cfg,
        }
    }

    /// The model layout under simulation.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// The virtual ring network (byte counters, clock, traces).
    pub fn net(&self) -> &RingNet {
        &self.net
    }

    /// The staging arena behind the reduce paths (DESIGN.md §9); exposes
    /// the (re)allocation counter the zero-alloc steady-state tests pin.
    pub fn arena(&self) -> &Arena {
        &self.arena
    }

    /// The communication topology this engine reduces over
    /// (DESIGN.md §10).
    pub fn topology(&self) -> TopoKind {
        self.topo.kind()
    }

    /// The online autotuner — `None` when `--tuner off`; otherwise the
    /// decision trace and switch counter live here (DESIGN.md §14).
    pub fn tuner(&self) -> Option<&Tuner> {
        self.tuner.as_ref()
    }

    /// The synthetic weight buffer importance is scored against.
    pub fn weights(&self) -> &[f32] {
        &self.synth.weights
    }

    /// Trailing per-layer stats of the configured pipeline (the
    /// layerwise controller input, Fig. 4 data); empty for
    /// non-scoring pipelines.
    pub fn prev_stats(&self) -> &[LayerStats] {
        self.comp.prev_stats()
    }

    fn dense_ref_bytes(&self) -> u64 {
        let n = self.cfg.nodes as u64;
        2 * (n - 1) * self.layout.dense_bytes() / n
    }

    /// Importance scores of node 0's current pending gradient, per layer
    /// (Figs. 2–4 measurement hook). Call after at least one `step`.
    /// Both returned slices are engine-owned scratch refilled in place —
    /// the per-call `Vec<LayerStats>` allocation is gone.
    pub fn importance_snapshot(&mut self) -> (&[f32], &[LayerStats]) {
        let w = &self.synth.weights;
        match self.comp.pending(0) {
            Some(pending) => {
                for i in 0..pending.len() {
                    self.imp_scratch[i] = pending[i].abs() / (w[i].abs() + EPS);
                }
            }
            // Residual-free pipelines (dense, terngrad) have no pending
            // update — all-zero importance, as before the refactor.
            None => self.imp_scratch.iter_mut().for_each(|v| *v = 0.0),
        }
        crate::compress::importance::layer_stats_into(
            &self.layout,
            &self.imp_scratch,
            &mut self.snap_stats,
        );
        (&self.imp_scratch, &self.snap_stats)
    }

    /// Install a per-hop link table (e.g. the wire handshake's,
    /// DESIGN.md §13). A uniform table equal to `cfg.link` leaves
    /// every report bit-identical.
    pub fn set_links(&mut self, links: Vec<LinkSpec>) {
        self.links.clone_from(&links);
        self.net.set_links(links);
        if let Some(t) = self.tuner.as_mut() {
            t.set_links(&self.links);
        }
    }

    /// The current per-hop link table (entry `i` = node `i`'s outgoing
    /// edge). Uniform `cfg.link` until a chaos event or an installed
    /// table changes it.
    pub fn links(&self) -> &[LinkSpec] {
        &self.links
    }

    /// Node `node`'s accumulated pending update (the configured
    /// pipeline's residual store) — the chaos harness reads it to
    /// check residual-conservation invariants around recovery events
    /// (DESIGN.md §15). `None` for residual-free pipelines.
    pub fn pending(&self, node: usize) -> Option<&[f32]> {
        self.comp.pending(node)
    }

    /// Replay every chaos event scheduled at steps the engine has not
    /// yet reached, up to and including `step` (DESIGN.md §15).
    /// Idempotent: an internal cursor remembers what already fired, so
    /// harnesses that check invariants *around* recovery events call
    /// this manually before [`SimEngine::step`] — whose own call then
    /// becomes a no-op. Returns true when membership or links changed
    /// (a wire engine must rebuild its socket ring before stepping).
    pub fn apply_chaos(&mut self, step: usize) -> bool {
        let plan = match &self.cfg.chaos {
            Some(p) if !p.is_empty() => p.clone(),
            _ => return false,
        };
        let from = self.next_chaos_step;
        self.next_chaos_step = self.next_chaos_step.max(step + 1);
        if from > step {
            return false;
        }
        let mut changed = false;
        for ev in plan.events.iter().filter(|e| (from..=step).contains(&e.step())) {
            match *ev {
                ChaosEvent::Crash { node, .. } => self.remove_node(node, plan.mode),
                ChaosEvent::Slow { node, factor, .. } => {
                    // Degradation is base-relative (not compounding):
                    // the hop runs at cfg.link / factor until healed.
                    self.links[node] = crate::net::chaos::degrade(self.cfg.link, factor);
                    self.net.set_links(self.links.clone());
                    if let Some(t) = self.tuner.as_mut() {
                        t.set_links(&self.links);
                    }
                }
                ChaosEvent::Heal { .. } => {
                    self.links = vec![self.cfg.link; self.cfg.nodes];
                    self.net.set_links(self.links.clone());
                    if let Some(t) = self.tuner.as_mut() {
                        t.set_links(&self.links);
                    }
                }
                ChaosEvent::Join { .. } => self.add_node(step),
            }
            changed = true;
        }
        changed
    }

    /// Ring position `node` crashed mid-run: migrate its pipeline
    /// state per `mode` (handoff to its ring successor, or
    /// drop-and-rescale by N/(N−1) — DESIGN.md §15), then re-ring the
    /// survivors. The virtual clock carries over (recovery does not
    /// rewind time); cumulative byte counters and traces restart with
    /// the new ring, and per-step reports — clock deltas — stay
    /// comparable across the event.
    pub fn remove_node(&mut self, node: usize, mode: RecoveryMode) {
        let n = self.cfg.nodes;
        assert!(n > 2, "cannot re-ring below 2 survivors (have {n})");
        assert!(node < n, "crash of node {node} out of range (membership {n})");
        let nodes_after = n - 1;
        let states_after = nodes_after.min(Self::SIM_NODE_CAP);
        self.comp.remove_node(node, mode, nodes_after, states_after);
        // Survivors keep their own RNG streams and links (both shift
        // down with their ring position, like the state stores).
        self.rngs.remove(node);
        self.links.remove(node);
        self.cfg.nodes = nodes_after;
        self.cfg.mask_nodes = self.cfg.mask_nodes.min(nodes_after).max(1);
        self.rebuild_ring();
        self.resize_grads(states_after);
    }

    /// One fresh node joins at the end of the ring before `step` runs:
    /// zeroed pipeline state (no stale residuals), a fresh RNG stream
    /// off the reserved join stream, the base link, and warm-up
    /// re-entry in the pipeline (DESIGN.md §15).
    pub fn add_node(&mut self, step: usize) {
        let nodes_after = self.cfg.nodes + 1;
        let states_after = nodes_after.min(Self::SIM_NODE_CAP);
        let epoch = step / self.cfg.steps_per_epoch.max(1);
        self.comp.add_node(epoch, nodes_after, states_after);
        self.rngs.push(self.join_rng.split(nodes_after as u64));
        self.links.push(self.cfg.link);
        self.cfg.nodes = nodes_after;
        self.rebuild_ring();
        self.resize_grads(states_after);
    }

    /// Rebuild the net/topology/arena (and tuner pricing) for the
    /// current membership + link table. The clock carries over; the
    /// tuner restarts its hysteresis incumbent (a membership change
    /// invalidates every prior prediction anyway).
    fn rebuild_ring(&mut self) {
        let clock = self.net.clock();
        let mut net = RingNet::new(self.cfg.nodes, self.cfg.link, 0.05);
        net.advance(clock);
        net.set_links(self.links.clone());
        self.net = net;
        self.topo = self.cfg.topology.build(self.cfg.nodes);
        self.arena = Arena::for_nodes(self.cfg.nodes);
        if self.cfg.tuner != TunerMode::Off {
            let mut t = Tuner::new(self.cfg.tuner, self.cfg.nodes, self.cfg.link);
            t.set_links(&self.links);
            self.tuner = Some(t);
        }
    }

    fn resize_grads(&mut self, states: usize) {
        let total = self.layout.total_params();
        while self.grads.len() < states {
            self.grads.push(vec![0.0; total]);
        }
        self.grads.truncate(states);
    }

    /// One synchronous step: generate per-node gradients, compress,
    /// ring-reduce, account. Per-node work fans out over the configured
    /// executor; reports are bit-identical at any `parallelism`. Fires
    /// any pending chaos events first ([`SimEngine::apply_chaos`]).
    pub fn step(&mut self, step: usize) -> StepReport {
        self.apply_chaos(step);
        self.step_wired(step, None)
    }

    /// [`SimEngine::step`] with an optional real socket ring: when
    /// `wire` is set, the configured pipeline routes every traveling
    /// payload through it and consumes only the decoded frames
    /// (`compress::pipeline::SimCtx::wire`), so the report stays
    /// bit-identical to the pure simulation iff the transport is
    /// faithful — the `transport_equivalence` oracle contract.
    pub fn step_wired(&mut self, step: usize, wire: Option<&mut WireRing>) -> StepReport {
        let epoch = step / self.cfg.steps_per_epoch.max(1);
        let sim_nodes = self.grads.len();
        // Only materialize the gradient streams this pipeline consumes
        // (25M+-param fills dominate wall time otherwise).
        let needed = self.comp.grads_needed(sim_nodes);
        {
            // Counter-based synthesis + per-node jitter streams: each
            // node touches only its own buffer and RNG, so the fan-out
            // is deterministic.
            let synth = &self.synth;
            self.exec.map_mut2(
                &mut self.grads[..needed],
                &mut self.rngs[..needed],
                |node, grad, rng| {
                    synth.gen_step_node(step, node, grad);
                    // Decorrelate nodes with cheap multiplicative jitter.
                    for v in grad.iter_mut() {
                        *v *= 0.85 + 0.3 * rng.uniform();
                    }
                },
            );
        }

        let t0 = self.net.clock();
        let out = {
            let mut ctx = SimCtx {
                epoch,
                nodes: self.cfg.nodes,
                layout: &self.layout,
                weights: &self.synth.weights,
                grads: &self.grads,
                net: &mut self.net,
                topo: &*self.topo,
                exec: &self.exec,
                arena: &mut self.arena,
                rngs: &mut self.rngs,
                ctl_rng: &mut self.ctl_rng,
                wire,
                tuner: self.tuner.as_mut(),
            };
            self.comp.sim_step(&mut ctx)
        };
        // Compute-phase gap (ResNet50 on a 1080ti: ~0.35 s/step at the
        // paper's batch size — gives Fig. 7/8 their burst/idle shape).
        self.net.advance(0.35);

        self.account.record_full(
            self.dense_ref_bytes(),
            out.wire_bytes_per_node,
            self.layout.dense_bytes(),
            out.payload_bytes,
            out.density,
        );
        StepReport {
            wire_bytes_per_node: out.wire_bytes_per_node,
            density: out.density,
            seconds: self.net.clock() - t0,
            wire_seconds: out.wire_seconds,
            support_nnz: out.support_nnz,
        }
    }
}

/// One [`WireEngine`] step: the oracle-comparable virtual report plus
/// the real-transport measurements next to it.
#[derive(Debug, Clone)]
pub struct WireStepReport {
    /// The step report — bit-identical to [`SimEngine::step`] on the
    /// same seeds when the transport is faithful.
    pub report: StepReport,
    /// Real wall-clock seconds this step spent (compare against
    /// `report.wire_seconds`, the `CostModel` virtual prediction).
    pub wall_seconds: f64,
    /// Real bytes that traversed ring edges this step (frame length ×
    /// hops — includes frame headers, so it sits above the virtual
    /// payload accounting).
    pub real_bytes: u64,
    /// Cumulative recovery totals over the ring's lifetime (DESIGN.md
    /// §16): retransmits, reconnects, duplicate drops, NACKs, backoff
    /// time. Advisory mid-run (session threads may still be counting);
    /// exact after [`WireEngine::shutdown`]. All-zero on a fault-free
    /// ring, and never part of [`StepReport`] — the oracle contract
    /// compares payload results, not recovery effort.
    pub recovery: crate::net::RecoveryStats,
}

/// The socket-transport engine (DESIGN.md §13): a [`SimEngine`]
/// compute core with every traveling payload routed through a
/// [`WireRing`]. The simulator stays the bit-exact oracle — this
/// engine must reproduce its `StepReport`s exactly
/// (`rust/tests/transport_equivalence.rs`) while recording real
/// wall-clock and real wire bytes next to the virtual accounting.
pub struct WireEngine {
    sim: SimEngine,
    ring: WireRing,
    /// Ring options reused on every elastic re-ring: the fault plan,
    /// the timeout knob, and the shared counter block (so
    /// [`crate::net::RecoveryStats`] stays cumulative across rebuilds).
    ring_opts: crate::net::RingOpts,
}

impl WireEngine {
    /// Build the engine for `cfg.transport` (`uds` or `tcp`): spawn an
    /// in-process socket ring, or attach to external `ringiwp serve`
    /// ranks when `cfg.wire_dir` is set. The handshake's per-hop link
    /// table (uniform `cfg.link` today) is installed into the virtual
    /// net — bit-for-bit equal to the global-link default.
    pub fn new(layout: ParamLayout, cfg: SimCfg) -> anyhow::Result<Self> {
        anyhow::ensure!(
            cfg.transport.is_wire(),
            "WireEngine needs --transport uds|tcp (got `{}`)",
            cfg.transport
        );
        let chaos_active = matches!(&cfg.chaos, Some(p) if !p.is_empty());
        anyhow::ensure!(
            cfg.wire_dir.is_none() || !chaos_active,
            "chaos plans cannot drive an external `ringiwp serve` ring \
             (re-ring would abandon live ranks); drop --wire-dir"
        );
        // Explicit --wire-faults wins; otherwise a chaos plan's inline
        // wire tokens ride along. Empty plans count as absent (the
        // zero-overhead contract).
        let faults = cfg
            .wire_faults
            .clone()
            .filter(|p| !p.is_empty())
            .or_else(|| {
                cfg.chaos
                    .as_ref()
                    .map(|c| c.wire.clone())
                    .filter(|p| !p.is_empty())
            });
        anyhow::ensure!(
            cfg.wire_dir.is_none() || faults.is_none(),
            "wire faults are an in-process harness; they cannot corrupt \
             an external `ringiwp serve` ring — drop --wire-dir"
        );
        let ring_opts = crate::net::RingOpts {
            faults,
            timeout: std::time::Duration::from_millis(cfg.wire_timeout_ms.max(1)),
            counters: Some(std::sync::Arc::new(crate::net::RecoveryCounters::new())),
            force_version: None,
        };
        let links = vec![cfg.link; cfg.nodes];
        let ring = match &cfg.wire_dir {
            Some(dir) => {
                WireRing::connect_external_with(dir, cfg.transport, links, ring_opts.clone())?
            }
            None => WireRing::new_in_process_with(cfg.transport, links, ring_opts.clone())?,
        };
        let mut sim = SimEngine::new(layout, cfg);
        sim.set_links(ring.links().to_vec());
        Ok(WireEngine { sim, ring, ring_opts })
    }

    /// The underlying simulation core (accounting, layout, snapshots).
    pub fn sim(&self) -> &SimEngine {
        &self.sim
    }

    /// Mutable access to the core (e.g. `importance_snapshot`).
    pub fn sim_mut(&mut self) -> &mut SimEngine {
        &mut self.sim
    }

    /// The socket ring under this engine.
    pub fn ring(&self) -> &WireRing {
        &self.ring
    }

    /// One step over real sockets. Panics (via the pipeline's
    /// `expect`) if the wire corrupts a payload mid-step; transport
    ///-level failures before that surface as typed [`WireError`]s in
    /// [`WireEngine::shutdown`].
    ///
    /// Fires any pending chaos events first ([`WireEngine::apply_chaos`]).
    pub fn step(&mut self, step: usize) -> WireStepReport {
        self.apply_chaos(step);
        let t0 = std::time::Instant::now();
        let b0 = self.ring.real_bytes();
        self.ring.begin_step(step as u32);
        let report = self.sim.step_wired(step, Some(&mut self.ring));
        WireStepReport {
            report,
            wall_seconds: t0.elapsed().as_secs_f64(),
            real_bytes: self.ring.real_bytes() - b0,
            recovery: self.ring.recovery_stats(),
        }
    }

    /// Fire any chaos events pending at `step` and, when membership or
    /// links changed, tear the old socket ring down and spawn a fresh
    /// in-process ring over the survivors' link table (the wire half of
    /// re-ring recovery, DESIGN.md §15). Idempotent through the sim
    /// core's cursor, so harnesses checking invariants *around* recovery
    /// events call this manually before [`WireEngine::step`] — whose own
    /// call then becomes a no-op. Returns true when the ring was rebuilt.
    pub fn apply_chaos(&mut self, step: usize) -> bool {
        if !self.sim.apply_chaos(step) {
            return false;
        }
        let transport = self.ring.transport();
        self.ring.shutdown().expect("re-ring: old ring shutdown failed");
        // Same options (and the same counter block) as the first ring,
        // so fault schedules — edge indices taken modulo the live ring
        // size — and recovery totals survive the rebuild.
        self.ring = WireRing::new_in_process_with(
            transport,
            self.sim.links().to_vec(),
            self.ring_opts.clone(),
        )
        .expect("re-ring: survivor ring spawn failed");
        self.sim.set_links(self.ring.links().to_vec());
        true
    }

    /// Recovery totals so far (cumulative across re-rings); exact once
    /// [`WireEngine::shutdown`] has joined the session threads.
    pub fn recovery_stats(&self) -> crate::net::RecoveryStats {
        self.ring.recovery_stats()
    }

    /// Tear the socket ring down (also runs on drop).
    pub fn shutdown(&mut self) -> Result<(), WireError> {
        self.ring.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::model::{LayerKind, ParamLayout};

    fn small_layout() -> ParamLayout {
        ParamLayout::new(
            "small",
            vec![
                ("conv".into(), vec![32, 16, 3, 3], LayerKind::Conv),
                ("bn".into(), vec![64], LayerKind::BatchNorm),
                ("fc".into(), vec![128, 10], LayerKind::Fc),
            ],
        )
    }

    fn cfg(method: Method, nodes: usize) -> SimCfg {
        SimCfg {
            nodes,
            method: method.spec(),
            link: LinkSpec::new(1e9, 0.0),
            ..Default::default()
        }
    }

    fn spec_cfg(spec: &str, nodes: usize) -> SimCfg {
        SimCfg {
            nodes,
            method: MethodSpec::parse(spec).unwrap(),
            link: LinkSpec::new(1e9, 0.0),
            ..Default::default()
        }
    }

    #[test]
    fn iwp_compresses_hard() {
        let mut c = cfg(Method::IwpFixed, 8);
        c.threshold = 0.05;
        let mut e = SimEngine::new(small_layout(), c);
        for s in 0..5 {
            e.step(s);
        }
        assert!(e.account.ratio() > 4.0, "ratio {}", e.account.ratio());
        assert!(e.account.mean_density() < 0.25);
    }

    #[test]
    fn baseline_ratio_is_one() {
        let mut e = SimEngine::new(small_layout(), cfg(Method::Baseline, 8));
        e.step(0);
        assert!((e.account.ratio() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dgc_density_grows_with_ring_but_iwp_does_not() {
        let layout = small_layout();
        let density_of = |method: Method, nodes: usize| -> f64 {
            let mut c = cfg(method, nodes);
            c.dgc_density = 0.01;
            c.threshold = 0.05;
            let mut e = SimEngine::new(layout.clone(), c);
            let mut last = 0.0;
            for s in 0..3 {
                last = e.step(s).density;
            }
            last
        };
        let dgc_small = density_of(Method::Dgc, 4);
        let dgc_big = density_of(Method::Dgc, 32);
        assert!(
            dgc_big > dgc_small * 2.0,
            "DGC should densify: {dgc_small} -> {dgc_big}"
        );
        let iwp_small = density_of(Method::IwpFixed, 4);
        let iwp_big = density_of(Method::IwpFixed, 32);
        assert!(
            (iwp_big / iwp_small.max(1e-9)) < 2.0,
            "IWP should stay sparse: {iwp_small} -> {iwp_big}"
        );
    }

    #[test]
    fn topologies_trade_time_for_bytes() {
        // Same Baseline workload on all three topologies: the tree moves
        // the same 2(N-1)·V total as the ring but in full-payload rounds,
        // so its virtual wire time is far worse — the constant-cost
        // property the paper builds on is a *ring* property. The flat
        // per-node mean stays at the 2(N-1)/N reference.
        let layout = small_layout();
        let run = |topology: TopoKind| -> (u64, f64) {
            let mut c = cfg(Method::Baseline, 8);
            c.topology = topology;
            let mut e = SimEngine::new(layout.clone(), c);
            let r = e.step(0);
            assert_eq!(e.topology(), topology);
            (r.wire_bytes_per_node, r.seconds)
        };
        let (flat_b, flat_s) = run(TopoKind::Flat);
        let (tree_b, tree_s) = run(TopoKind::Tree);
        let (hier_b, hier_s) = run(TopoKind::Hier { group: 4 });
        let v = layout.dense_bytes();
        assert_eq!(flat_b, 2 * 7 * v / 8, "flat stays at the 2(N-1)/N reference");
        assert_eq!(tree_b, 2 * 7 * v / 8, "tree total is also 2(N-1)V");
        // Flat: 2(N-1) rounds of V/N; tree: 2·log2(N) rounds of V. Both
        // step times share the same fixed compute gap, so strict
        // inequality isolates the wire-time difference.
        assert!(
            tree_s > flat_s,
            "tree wire time {tree_s} should exceed flat {flat_s}"
        );
        // The hierarchy's chain broadcast also ships full payloads.
        assert!(hier_b > 0 && hier_s > flat_s);
    }

    #[test]
    fn resnet50_inventory_runs() {
        let mut e = SimEngine::new(zoo::resnet50(), cfg(Method::IwpFixed, 4));
        let rep = e.step(0);
        assert!(rep.wire_bytes_per_node > 0);
        assert!(rep.density < 1.0);
        let n_layers = e.layout().n_layers();
        let (_imp, stats) = e.importance_snapshot();
        assert_eq!(stats.len(), n_layers);
    }

    #[test]
    fn new_compositions_run_end_to_end() {
        // The two shipped stage compositions (DESIGN.md §12) and the
        // low-precision payload stages — ternary plus one k-bit and one
        // float `+q` width (DESIGN.md §17) — through the full engine.
        let layout = small_layout();
        for spec in [
            "iwp:vargate",
            "dgc:layerwise",
            "iwp:fixed+tern",
            "iwp:fixed+q:8",
            "iwp:fixed+q:16b",
        ] {
            let mut e = SimEngine::new(layout.clone(), spec_cfg(spec, 8));
            for s in 0..3 {
                let r = e.step(s);
                assert!(r.wire_bytes_per_node > 0, "{spec}");
                assert!(r.density < 1.0, "{spec}: density {}", r.density);
                assert!(r.wire_seconds > 0.0 && r.wire_seconds < r.seconds, "{spec}");
            }
            assert!(e.account.ratio() > 1.0, "{spec}: {}", e.account.ratio());
        }
    }

    #[test]
    fn dgc_layerwise_densifies_like_topk_but_scores_like_iwp() {
        // The composition point: per-node masks densify with ring size
        // (DGC transport) even though selection is Eq.-4 thresholded
        // importance (IWP scoring).
        let layout = small_layout();
        let density_at = |nodes: usize| -> f64 {
            let mut c = spec_cfg("dgc:layerwise", nodes);
            c.threshold = 0.05;
            let mut e = SimEngine::new(layout.clone(), c);
            let mut last = 0.0;
            for s in 0..3 {
                last = e.step(s).density;
            }
            last
        };
        let small = density_at(4);
        let big = density_at(32);
        assert!(
            big > small * 1.5,
            "per-node thresholded masks should densify: {small} -> {big}"
        );
        // And the pipeline exposes trailing stats (it scores).
        let mut e = SimEngine::new(layout, spec_cfg("dgc:layerwise", 4));
        e.step(0);
        assert_eq!(e.prev_stats().len(), e.layout().n_layers());
        assert!(e.prev_stats()[0].n > 0.0);
    }

    #[test]
    fn wire_engine_matches_sim_engine_bit_for_bit() {
        // The in-module smoke version of the transport-equivalence
        // oracle (the full matrix lives in
        // rust/tests/transport_equivalence.rs): a UDS WireEngine must
        // reproduce SimEngine's StepReports exactly.
        let layout = small_layout();
        for spec in ["baseline", "iwp:fixed", "terngrad"] {
            let mut c = spec_cfg(spec, 4);
            c.transport = TransportKind::Uds;
            c.wire_dir = None;
            let mut sim = SimEngine::new(layout.clone(), c.clone());
            let mut wire = WireEngine::new(layout.clone(), c).unwrap();
            for s in 0..3 {
                let a = sim.step(s);
                let b = wire.step(s);
                assert_eq!(
                    a.wire_bytes_per_node, b.report.wire_bytes_per_node,
                    "{spec} step {s}"
                );
                assert_eq!(a.support_nnz, b.report.support_nnz, "{spec} step {s}");
                assert_eq!(a.density.to_bits(), b.report.density.to_bits(), "{spec}");
                assert_eq!(a.seconds.to_bits(), b.report.seconds.to_bits(), "{spec}");
                assert_eq!(
                    a.wire_seconds.to_bits(),
                    b.report.wire_seconds.to_bits(),
                    "{spec}"
                );
                assert!(b.wall_seconds >= 0.0);
                assert!(b.real_bytes > 0, "{spec}: frames must traverse the ring");
            }
            wire.shutdown().unwrap();
        }
    }

    #[test]
    fn wire_engine_rejects_sim_transport() {
        let c = SimCfg {
            transport: TransportKind::Sim,
            ..cfg(Method::Baseline, 4)
        };
        assert!(WireEngine::new(small_layout(), c).is_err());
    }

    #[test]
    fn tuner_log_only_is_bit_identical_to_off() {
        // LogOnly decides + records but still executes the static path,
        // so every report must match `--tuner off` bit for bit.
        let layout = small_layout();
        let base = cfg(Method::IwpFixed, 8);
        let mut off = SimEngine::new(layout.clone(), base.clone());
        let mut log = SimEngine::new(
            layout,
            SimCfg {
                tuner: TunerMode::LogOnly,
                ..base
            },
        );
        for s in 0..4 {
            let a = off.step(s);
            let b = log.step(s);
            assert_eq!(a.wire_bytes_per_node, b.wire_bytes_per_node, "step {s}");
            assert_eq!(a.density.to_bits(), b.density.to_bits(), "step {s}");
            assert_eq!(a.wire_seconds.to_bits(), b.wire_seconds.to_bits(), "step {s}");
            assert_eq!(a.support_nnz, b.support_nnz, "step {s}");
        }
        assert!(off.tuner().is_none());
        let t = log.tuner().unwrap();
        assert_eq!(t.trace().len(), 4, "one decision per step");
    }

    #[test]
    fn tuner_on_runs_and_records_decisions() {
        let mut c = cfg(Method::IwpFixed, 8);
        c.tuner = TunerMode::On;
        let mut e = SimEngine::new(small_layout(), c);
        for s in 0..4 {
            let r = e.step(s);
            assert!(r.wire_bytes_per_node > 0, "step {s}");
            assert!(r.wire_seconds > 0.0, "step {s}");
        }
        let t = e.tuner().unwrap();
        assert_eq!(t.trace().len(), 4);
        for row in t.trace().rows() {
            assert_eq!(row.considered.len(), t.candidates().len());
            assert!(row.predicted_s.is_finite());
        }
    }

    #[test]
    fn no_fault_chaos_plan_is_bit_identical() {
        // Wiring the chaos machinery in must cost nothing when no event
        // fires: `chaos: None` and an empty plan produce byte-equal
        // report streams (the DESIGN.md §15 zero-overhead contract).
        let layout = small_layout();
        for spec in ["iwp:fixed", "dgc", "terngrad"] {
            let base = spec_cfg(spec, 5);
            let mut plain = SimEngine::new(layout.clone(), base.clone());
            let mut chaotic = SimEngine::new(
                layout.clone(),
                SimCfg {
                    chaos: Some(ChaosPlan::none()),
                    ..base
                },
            );
            for s in 0..4 {
                let a = plain.step(s);
                let b = chaotic.step(s);
                assert_eq!(a.wire_bytes_per_node, b.wire_bytes_per_node, "{spec} step {s}");
                assert_eq!(a.density.to_bits(), b.density.to_bits(), "{spec} step {s}");
                assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "{spec} step {s}");
                assert_eq!(a.support_nnz, b.support_nnz, "{spec} step {s}");
            }
        }
    }

    #[test]
    fn crash_recovers_within_one_step_in_both_modes() {
        // A mid-run crash shrinks the membership before the scheduled
        // step runs; every subsequent report stays well-formed and the
        // per-node wire bytes track the new ring size.
        let layout = small_layout();
        for mode in ["handoff", "rescale"] {
            let mut c = spec_cfg("iwp:fixed", 5);
            c.chaos = Some(ChaosPlan::parse(&format!("mode={mode},crash@2:1")).unwrap());
            let mut e = SimEngine::new(layout.clone(), c);
            for s in 0..5 {
                let r = e.step(s);
                assert!(r.wire_bytes_per_node > 0, "{mode} step {s}");
                assert!(r.density > 0.0 && r.density <= 1.0, "{mode} step {s}");
                assert!(r.seconds.is_finite() && r.seconds > 0.0, "{mode} step {s}");
                let want = if s < 2 { 5 } else { 4 };
                assert_eq!(e.cfg.nodes, want, "{mode} step {s}");
            }
            // Survivor state stays finite (bounded staleness).
            if let Some(p) = e.pending(0) {
                assert!(p.iter().all(|v| v.is_finite()), "{mode}");
            }
        }
    }

    #[test]
    fn join_grows_membership_and_caps_state() {
        let mut c = spec_cfg("iwp:fixed", 5);
        c.chaos = Some(ChaosPlan::parse("join@2").unwrap());
        let mut e = SimEngine::new(small_layout(), c);
        for s in 0..4 {
            let r = e.step(s);
            assert!(r.wire_bytes_per_node > 0, "step {s}");
        }
        assert_eq!(e.cfg.nodes, 6);
        // Materialized state never exceeds the exchangeable-node cap.
        assert_eq!(e.grads.len(), 6.min(SimEngine::SIM_NODE_CAP));
        assert_eq!(e.rngs.len(), 6);
        assert_eq!(e.links().len(), 6);
    }

    #[test]
    fn slow_then_heal_roundtrips_the_link_table() {
        let mut c = cfg(Method::Baseline, 4);
        c.chaos = Some(ChaosPlan::parse("slow@1:2:4,heal@3").unwrap());
        let mut e = SimEngine::new(small_layout(), c.clone());
        let r0 = e.step(0);
        let r1 = e.step(1);
        // Hop 2 at bandwidth/4 slows the (synchronous) round.
        assert!(e.links()[2].bandwidth_bps < c.link.bandwidth_bps);
        assert!(
            r1.wire_seconds > r0.wire_seconds,
            "straggler hop must slow the ring: {} vs {}",
            r1.wire_seconds,
            r0.wire_seconds
        );
        e.step(2);
        let r3 = e.step(3);
        // Heal restores the uniform base table and the original timing.
        assert!(e.links().iter().all(|l| l.bandwidth_bps == c.link.bandwidth_bps));
        assert_eq!(r3.wire_seconds.to_bits(), r0.wire_seconds.to_bits());
    }

    #[test]
    fn apply_chaos_is_idempotent_across_manual_and_step() {
        // Harnesses call apply_chaos manually to inspect state around
        // the event; the engine's own call inside step() must then be a
        // no-op, leaving reports identical to the auto-applied run.
        let layout = small_layout();
        let mut c = spec_cfg("iwp:fixed", 5);
        c.chaos = Some(ChaosPlan::parse("mode=rescale,crash@1:3,join@3").unwrap());
        let mut auto = SimEngine::new(layout.clone(), c.clone());
        let mut manual = SimEngine::new(layout, c);
        for s in 0..5 {
            let a = auto.step(s);
            manual.apply_chaos(s);
            assert!(!manual.apply_chaos(s), "second call at step {s} must no-op");
            let b = manual.step(s);
            assert_eq!(a.wire_bytes_per_node, b.wire_bytes_per_node, "step {s}");
            assert_eq!(a.density.to_bits(), b.density.to_bits(), "step {s}");
            assert_eq!(a.seconds.to_bits(), b.seconds.to_bits(), "step {s}");
        }
        assert_eq!(auto.cfg.nodes, manual.cfg.nodes);
    }

    #[test]
    fn wire_engine_re_rings_through_a_crash() {
        // The wire half of recovery: the same crash plan on sim and uds
        // transports yields bit-identical reports, with the socket ring
        // rebuilt over the survivors mid-run.
        let layout = small_layout();
        let mut c = spec_cfg("iwp:fixed", 4);
        c.chaos = Some(ChaosPlan::parse("mode=handoff,crash@1:2").unwrap());
        let mut sim = SimEngine::new(layout.clone(), c.clone());
        c.transport = TransportKind::Uds;
        let mut wire = WireEngine::new(layout, c).unwrap();
        for s in 0..4 {
            let a = sim.step(s);
            let b = wire.step(s);
            assert_eq!(a.wire_bytes_per_node, b.report.wire_bytes_per_node, "step {s}");
            assert_eq!(a.density.to_bits(), b.report.density.to_bits(), "step {s}");
            assert_eq!(a.seconds.to_bits(), b.report.seconds.to_bits(), "step {s}");
            assert_eq!(a.support_nnz, b.report.support_nnz, "step {s}");
        }
        assert_eq!(wire.sim().cfg.nodes, 3);
        assert_eq!(wire.ring().links().len(), 3);
        wire.shutdown().unwrap();
    }

    #[test]
    fn chaos_with_external_wire_dir_is_rejected() {
        let mut c = spec_cfg("baseline", 4);
        c.transport = TransportKind::Uds;
        c.wire_dir = Some(std::path::PathBuf::from("/tmp/nonexistent-ring"));
        c.chaos = Some(ChaosPlan::parse("crash@1:0").unwrap());
        assert!(WireEngine::new(small_layout(), c).is_err());
    }

    #[test]
    fn warmup_stage_loosens_early_thresholds() {
        // `+warmup:<e>` scales thresholds down early: epoch-0 density
        // must be at least the no-warmup density, converging once the
        // ramp ends.
        let layout = small_layout();
        let density0 = |spec: &str| -> f64 {
            let mut c = spec_cfg(spec, 8);
            c.steps_per_epoch = 1;
            let mut e = SimEngine::new(layout.clone(), c);
            e.step(0).density
        };
        let plain = density0("iwp:fixed+nosel");
        let warm = density0("iwp:fixed+nosel+warmup:4");
        assert!(
            warm > plain,
            "warm-up must loosen epoch-0 selection: {warm} vs {plain}"
        );
    }
}
