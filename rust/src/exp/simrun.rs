//! Synthetic-gradient simulation engine — runs the full compression +
//! ring transport stack over the paper's real AlexNet/ResNet50 layer
//! inventories at any ring size, without PJRT (the models are far too
//! large to *train* on this testbed; their wire behaviour is exact —
//! DESIGN.md §2).
//!
//! The engine mirrors `coordinator::Trainer`'s reduce paths 1:1 but
//! sources gradients from `grad::SynthGrads` and scores importance with
//! the CPU mirror of the L1 kernel (bit-identical semantics, cross-
//! validated in `tests/runtime_smoke.rs`).

use crate::compress::importance::{LayerStats, EPS};
use crate::compress::residual::ResidualStore;
use crate::compress::threshold::{ThresholdCfg, ThresholdPolicy};
use crate::compress::{dgc::Dgc, fuse, terngrad::TernGrad, warmup::Warmup, Method};
use crate::grad::SynthGrads;
use crate::metrics::CompressionAccount;
use crate::model::ParamLayout;
use crate::net::{LinkSpec, RingNet, TopoKind, Topology};
use crate::ring::{Arena, Executor};
use crate::sparse::BitMask;
use crate::util::rng::Rng;

/// Engine configuration (subset of `config::Config` relevant here).
#[derive(Debug, Clone)]
pub struct SimCfg {
    /// Simulated ring size N.
    pub nodes: usize,
    /// Compression method under test.
    pub method: Method,
    /// Importance threshold (α for the layerwise controller).
    pub threshold: f32,
    /// Eq. 4 dispersion gain β.
    pub beta: f32,
    /// Eq. 4 crossover C.
    pub c: f32,
    /// Number of random mask-broadcast nodes r (Alg. 1).
    pub mask_nodes: usize,
    /// Random gradient selection on/off (Sec. III-C).
    pub random_select: bool,
    /// Residual-store momentum (momentum correction).
    pub momentum: f32,
    /// DGC baseline per-node density.
    pub dgc_density: f64,
    /// Steps per "epoch" for epoch-indexed schedules.
    pub steps_per_epoch: usize,
    /// DGC/IWP warm-up epochs.
    pub warmup_epochs: usize,
    /// Root seed for every stochastic stream.
    pub seed: u64,
    /// Link model of the simulated ring.
    pub link: LinkSpec,
    /// Worker threads for the node-parallel engine (`ring::exec`,
    /// DESIGN.md §4). 1 = sequential oracle, bit-identical results at
    /// any width.
    pub parallelism: usize,
    /// Communication topology the reduce runs over (`net::topo`,
    /// DESIGN.md §10). Defaults to `RINGIWP_TOPOLOGY`, else the flat
    /// ring — which is bit-identical to the pre-topology engine.
    pub topology: TopoKind,
}

impl Default for SimCfg {
    fn default() -> Self {
        SimCfg {
            nodes: 96, // the paper's cluster size
            method: Method::IwpFixed,
            // Paper sweeps 0.005–0.1; the headline 64x/58.8x ratios live
            // at the aggressive end once random selection (P = I/thr)
            // adds its expected sub-threshold traffic.
            threshold: 0.05,
            beta: 0.002,
            c: 1.0,
            mask_nodes: 3,
            random_select: true,
            momentum: 0.9,
            dgc_density: 0.01,
            steps_per_epoch: 100,
            warmup_epochs: 0,
            seed: 17,
            link: LinkSpec::gigabit_ethernet(),
            parallelism: default_parallelism(),
            topology: TopoKind::from_env(),
        }
    }
}

/// Environment knob: `RINGIWP_PARALLELISM` sets the default executor
/// width for every experiment harness (results are bit-identical at any
/// width, so this only changes wall-clock).
fn default_parallelism() -> usize {
    std::env::var("RINGIWP_PARALLELISM")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&p| p >= 1)
        .unwrap_or(1)
}

/// Per-step report.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Mean wire bytes transmitted per node this step.
    pub wire_bytes_per_node: u64,
    /// Transmitted gradient density this step.
    pub density: f64,
    /// Virtual seconds this step occupied on the net.
    pub seconds: f64,
}

/// The simulation engine.
pub struct SimEngine {
    /// The configuration this engine was built with.
    pub cfg: SimCfg,
    layout: ParamLayout,
    synth: SynthGrads,
    stores: Vec<ResidualStore>,
    dgcs: Vec<Dgc>,
    net: RingNet,
    policy: ThresholdPolicy,
    warmup: Warmup,
    /// Trailing per-layer stats (layerwise controller input, Fig. 4 data).
    pub prev_stats: Vec<LayerStats>,
    rngs: Vec<Rng>,
    ctl_rng: Rng,
    /// Compression accounting over the whole run.
    pub account: CompressionAccount,
    exec: Executor,
    topo: Box<dyn Topology>,
    arena: Arena,
    imp_scratch: Vec<f32>,
    /// Cached per-layer stats buffer behind `importance_snapshot`
    /// (refilled in place — no per-call allocation).
    snap_stats: Vec<LayerStats>,
    /// Reusable per-layer threshold table (Eq. 4 controller output).
    thrs_buf: Vec<f32>,
    /// Per-node scratch for the fused scoring fan-out (DESIGN.md §11):
    /// masks are fully word-overwritten by `fuse::score_select_compact`
    /// and RNG streams are cloned in/out per step, so slot reuse is
    /// bit-identical to fresh allocation.
    scratch: Vec<NodeScratch>,
    grads: Vec<Vec<f32>>,
}

/// Reusable per-node slot for the fused IWP scoring fan-out: the cloned
/// RNG stream, the broadcaster's selection mask, and its per-layer stats
/// rows. `bcast` marks whether this node broadcasts this step.
struct NodeScratch {
    bcast: bool,
    rng: Rng,
    mask: BitMask,
    stats: Vec<LayerStats>,
}

impl SimEngine {
    /// Cap on *materialized* node states. Nodes are exchangeable
    /// (identical gradient distribution, disjoint shards), so wire
    /// accounting at ring size N only needs: the r mask broadcasters'
    /// residual states (IWP), one representative TernGrad encoder, and
    /// per-node *supports* (DGC — synthesized as exchangeable draws
    /// beyond the cap). Keeps 96-node x 61M-param sims in memory.
    const SIM_NODE_CAP: usize = 4;

    /// Build an engine over `layout` with configuration `cfg`.
    pub fn new(layout: ParamLayout, cfg: SimCfg) -> Self {
        let total = layout.total_params();
        let mut root = Rng::new(cfg.seed);
        let policy = match cfg.method {
            Method::IwpLayerwise => ThresholdPolicy::Layerwise(ThresholdCfg {
                alpha: cfg.threshold,
                beta: cfg.beta,
                c: cfg.c,
                ..Default::default()
            }),
            _ => ThresholdPolicy::Fixed(cfg.threshold),
        };
        let warmup = if cfg.warmup_epochs > 0 {
            Warmup {
                epochs: cfg.warmup_epochs,
                start_mult: 0.1,
            }
        } else {
            Warmup::none()
        };
        SimEngine {
            synth: SynthGrads::new(layout.clone(), cfg.seed ^ 0x5EED),
            stores: (0..cfg.nodes.min(Self::SIM_NODE_CAP))
                .map(|_| ResidualStore::new(total, cfg.momentum))
                .collect(),
            dgcs: (0..cfg.nodes.min(Self::SIM_NODE_CAP))
                .map(|_| Dgc::new(total, cfg.dgc_density, cfg.momentum))
                .collect(),
            net: RingNet::new(cfg.nodes, cfg.link, 0.05),
            prev_stats: vec![LayerStats::default(); layout.n_layers()],
            rngs: (0..cfg.nodes).map(|i| root.split(i as u64)).collect(),
            ctl_rng: root.split(0xC011),
            account: CompressionAccount::new(),
            exec: Executor::new(cfg.parallelism),
            topo: cfg.topology.build(cfg.nodes),
            arena: Arena::for_nodes(cfg.nodes),
            imp_scratch: vec![0.0; total],
            snap_stats: Vec::with_capacity(layout.n_layers()),
            thrs_buf: Vec::with_capacity(layout.n_layers()),
            scratch: (0..cfg.nodes.min(Self::SIM_NODE_CAP))
                .map(|_| NodeScratch {
                    bcast: false,
                    rng: Rng::new(0),
                    mask: BitMask::zeros(total),
                    stats: Vec::with_capacity(layout.n_layers()),
                })
                .collect(),
            grads: vec![vec![0.0; total]; cfg.nodes.min(Self::SIM_NODE_CAP)],
            policy,
            warmup,
            layout,
            cfg,
        }
    }

    /// The model layout under simulation.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// The virtual ring network (byte counters, clock, traces).
    pub fn net(&self) -> &RingNet {
        &self.net
    }

    /// The staging arena behind the reduce paths (DESIGN.md §9); exposes
    /// the (re)allocation counter the zero-alloc steady-state tests pin.
    pub fn arena(&self) -> &Arena {
        &self.arena
    }

    /// The communication topology this engine reduces over
    /// (DESIGN.md §10).
    pub fn topology(&self) -> TopoKind {
        self.topo.kind()
    }

    /// The synthetic weight buffer importance is scored against.
    pub fn weights(&self) -> &[f32] {
        &self.synth.weights
    }

    fn dense_ref_bytes(&self) -> u64 {
        let n = self.cfg.nodes as u64;
        2 * (n - 1) * self.layout.dense_bytes() / n
    }

    /// Importance scores of node 0's current pending gradient, per layer
    /// (Figs. 2–4 measurement hook). Call after at least one `step`.
    /// Both returned slices are engine-owned scratch refilled in place —
    /// the per-call `Vec<LayerStats>` allocation is gone.
    pub fn importance_snapshot(&mut self) -> (&[f32], &[LayerStats]) {
        let pending = self.stores[0].pending();
        let w = &self.synth.weights;
        for i in 0..pending.len() {
            self.imp_scratch[i] = pending[i].abs() / (w[i].abs() + EPS);
        }
        crate::compress::importance::layer_stats_into(
            &self.layout,
            &self.imp_scratch,
            &mut self.snap_stats,
        );
        (&self.imp_scratch, &self.snap_stats)
    }

    /// One synchronous step: generate per-node gradients, compress,
    /// ring-reduce, account. Per-node work fans out over the configured
    /// executor; reports are bit-identical at any `parallelism`.
    pub fn step(&mut self, step: usize) -> StepReport {
        let epoch = step / self.cfg.steps_per_epoch.max(1);
        let sim_nodes = self.grads.len();
        // Only materialize the gradient streams this method consumes
        // (25M+-param fills dominate wall time otherwise).
        let needed = match self.cfg.method {
            Method::Baseline => 0,
            Method::TernGrad => 1,
            _ => sim_nodes,
        };
        {
            // Counter-based synthesis + per-node jitter streams: each
            // node touches only its own buffer and RNG, so the fan-out
            // is deterministic.
            let synth = &self.synth;
            self.exec.map_mut2(
                &mut self.grads[..needed],
                &mut self.rngs[..needed],
                |node, grad, rng| {
                    synth.gen_step_node(step, node, grad);
                    // Decorrelate nodes with cheap multiplicative jitter.
                    for v in grad.iter_mut() {
                        *v *= 0.85 + 0.3 * rng.uniform();
                    }
                },
            );
        }

        let t0 = self.net.clock();
        let (wire, payload, density) = match self.cfg.method {
            Method::Baseline => {
                // Account-only dense rounds under the configured topology
                // (moving 61M f32 per node through the data path buys
                // nothing here; bytes are exact). total/N is the exact
                // per-node mean — for the flat ring it equals the paper's
                // 2(N-1)/N · V reference.
                let rep = self.topo.dense_bytes_only(
                    &mut self.net,
                    self.layout.total_params(),
                    &mut self.arena,
                );
                (
                    rep.total_bytes() / self.cfg.nodes as u64,
                    self.layout.dense_bytes(),
                    1.0,
                )
            }
            Method::TernGrad => {
                // Blob sizes are shape-determined (codes + scales), so one
                // representative encoding prices every node's blob.
                let n = self.cfg.nodes;
                let t = TernGrad::encode(&self.grads[0], &self.layout, &mut self.rngs[0]);
                let blob = t.wire_bytes();
                // Ternary values are not closed under addition, so no
                // topology can scatter-REDUCE them — the quantized blobs
                // must spread whole (every blob to every node). This is
                // why quantization alone does not help rings (the
                // paper's Sec. II point); the payload ratio below is
                // TernGrad's native parameter-server number.
                let rep = self
                    .topo
                    .spread_bytes(&mut self.net, blob, n, &mut self.arena);
                (rep.total_bytes() / n as u64, blob, 1.0)
            }
            Method::Dgc => {
                let density =
                    Dgc::density_at_epoch(self.cfg.dgc_density, epoch, self.cfg.warmup_epochs);
                let total = self.layout.total_params();
                let k = ((total as f64) * density).ceil() as usize;
                // Real top-k supports for materialized nodes; exchangeable
                // random k-subsets for the rest (supports across disjoint
                // data shards are near-independent — the same assumption
                // behind the paper's 1%->2% worst-case argument). Both
                // halves are per-node-independent, so they fan out.
                let grads = &self.grads;
                let mut supports: Vec<BitMask> =
                    self.exec.map_mut(&mut self.dgcs, |node, dgc| {
                        dgc.density = density;
                        let sv = dgc.step(&grads[node]);
                        let mut m = BitMask::zeros(total);
                        for &i in &sv.idx {
                            m.set(i as usize);
                        }
                        m
                    });
                supports.extend(self.exec.map_mut(
                    &mut self.rngs[sim_nodes..],
                    |_, rng| {
                        let mut m = BitMask::zeros(total);
                        for _ in 0..k {
                            m.set(rng.below(total));
                        }
                        m
                    },
                ));
                let rep = self.topo.sparse_support(
                    &mut self.net,
                    &supports,
                    &self.exec,
                    &mut self.arena,
                );
                // Paper-metric payload: each node's own encoded top-k.
                let payload = crate::sparse::wire_bytes(
                    crate::sparse::WireFormat::cheapest(total, k),
                    total,
                    k,
                );
                (
                    rep.mean_bytes_per_node() as u64,
                    payload,
                    rep.density_per_hop.last().copied().unwrap_or(density),
                )
            }
            Method::IwpFixed | Method::IwpLayerwise => {
                let wmult = self.warmup.multiplier(epoch);
                self.policy.layer_thresholds_into(
                    &self.layout,
                    &self.prev_stats,
                    epoch,
                    wmult,
                    &mut self.thrs_buf,
                );
                // Broadcasters drawn from the materialized (exchangeable)
                // node states.
                let broadcasters = self
                    .ctl_rng
                    .choose_distinct(sim_nodes, self.cfg.mask_nodes.min(sim_nodes));
                // Fused single-pass fan-out (DESIGN.md §11): every node
                // folds its gradient into its residual store; broadcaster
                // nodes additionally score, select, and pack their mask
                // in the *same* sweep (`fuse::score_select_compact`),
                // replacing the accumulate → fill_u → score_and_mask →
                // mask-merge chain. Broadcaster RNG streams are cloned
                // out and written back, so cross-step evolution matches
                // the multi-pass reference exactly.
                for scr in self.scratch.iter_mut() {
                    scr.bcast = false;
                }
                for &b in &broadcasters {
                    self.scratch[b].bcast = true;
                    self.scratch[b].rng = self.rngs[b].clone();
                }
                {
                    let grads = &self.grads;
                    let weights = &self.synth.weights;
                    let layout = &self.layout;
                    let thrs: &[f32] = &self.thrs_buf;
                    let random_select = self.cfg.random_select;
                    self.exec.map_mut2(
                        &mut self.stores,
                        &mut self.scratch,
                        |node, store, scr| {
                            if scr.bcast {
                                fuse::score_select_compact(
                                    layout,
                                    thrs,
                                    weights,
                                    &grads[node],
                                    EPS,
                                    random_select,
                                    &mut scr.rng,
                                    store,
                                    &mut scr.mask,
                                    &mut scr.stats,
                                );
                            } else {
                                store.accumulate(&grads[node]);
                            }
                        },
                    );
                }
                // Write RNG streams back and merge stats in broadcaster
                // order (the same f64 addition order as the reference).
                for s in self.prev_stats.iter_mut() {
                    *s = LayerStats::default();
                }
                for &b in &broadcasters {
                    self.rngs[b] = self.scratch[b].rng.clone();
                    for (li, st) in self.scratch[b].stats.iter().enumerate() {
                        self.prev_stats[li].merge(st);
                    }
                }
                let mask_refs: Vec<&BitMask> = broadcasters
                    .iter()
                    .map(|&b| &self.scratch[b].mask)
                    .collect();
                let (shared, rep) =
                    self.topo
                        .masked_bytes_only(&mut self.net, &mask_refs, &mut self.arena);
                // Fused residual take: zero residual + velocity on the
                // shared support in one sweep, no per-node Vec (the
                // accounting engine discards the transmitted values).
                let shared_ref = &shared;
                self.exec.map_mut(&mut self.stores, |_, store| {
                    store.clear_masked(shared_ref);
                });
                // Paper-metric payload: encode(sparse(G)) per node — the
                // selected values under the cheapest codec.
                let nnz = shared.count();
                let total = self.layout.total_params();
                let payload = crate::sparse::wire_bytes(
                    crate::sparse::WireFormat::cheapest(total, nnz),
                    total,
                    nnz,
                );
                (rep.mean_bytes_per_node() as u64, payload, shared.density())
            }
        };
        // Compute-phase gap (ResNet50 on a 1080ti: ~0.35 s/step at the
        // paper's batch size — gives Fig. 7/8 their burst/idle shape).
        self.net.advance(0.35);

        self.account.record_full(
            self.dense_ref_bytes(),
            wire,
            self.layout.dense_bytes(),
            payload,
            density,
        );
        StepReport {
            wire_bytes_per_node: wire,
            density,
            seconds: self.net.clock() - t0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::model::{LayerKind, ParamLayout};

    fn small_layout() -> ParamLayout {
        ParamLayout::new(
            "small",
            vec![
                ("conv".into(), vec![32, 16, 3, 3], LayerKind::Conv),
                ("bn".into(), vec![64], LayerKind::BatchNorm),
                ("fc".into(), vec![128, 10], LayerKind::Fc),
            ],
        )
    }

    fn cfg(method: Method, nodes: usize) -> SimCfg {
        SimCfg {
            nodes,
            method,
            link: LinkSpec::new(1e9, 0.0),
            ..Default::default()
        }
    }

    #[test]
    fn iwp_compresses_hard() {
        let mut c = cfg(Method::IwpFixed, 8);
        c.threshold = 0.05;
        let mut e = SimEngine::new(small_layout(), c);
        for s in 0..5 {
            e.step(s);
        }
        assert!(e.account.ratio() > 4.0, "ratio {}", e.account.ratio());
        assert!(e.account.mean_density() < 0.25);
    }

    #[test]
    fn baseline_ratio_is_one() {
        let mut e = SimEngine::new(small_layout(), cfg(Method::Baseline, 8));
        e.step(0);
        assert!((e.account.ratio() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dgc_density_grows_with_ring_but_iwp_does_not() {
        let layout = small_layout();
        let density_of = |method: Method, nodes: usize| -> f64 {
            let mut c = cfg(method, nodes);
            c.dgc_density = 0.01;
            c.threshold = 0.05;
            let mut e = SimEngine::new(layout.clone(), c);
            let mut last = 0.0;
            for s in 0..3 {
                last = e.step(s).density;
            }
            last
        };
        let dgc_small = density_of(Method::Dgc, 4);
        let dgc_big = density_of(Method::Dgc, 32);
        assert!(
            dgc_big > dgc_small * 2.0,
            "DGC should densify: {dgc_small} -> {dgc_big}"
        );
        let iwp_small = density_of(Method::IwpFixed, 4);
        let iwp_big = density_of(Method::IwpFixed, 32);
        assert!(
            (iwp_big / iwp_small.max(1e-9)) < 2.0,
            "IWP should stay sparse: {iwp_small} -> {iwp_big}"
        );
    }

    #[test]
    fn topologies_trade_time_for_bytes() {
        // Same Baseline workload on all three topologies: the tree moves
        // the same 2(N-1)·V total as the ring but in full-payload rounds,
        // so its virtual wire time is far worse — the constant-cost
        // property the paper builds on is a *ring* property. The flat
        // per-node mean stays at the 2(N-1)/N reference.
        let layout = small_layout();
        let run = |topology: TopoKind| -> (u64, f64) {
            let mut c = cfg(Method::Baseline, 8);
            c.topology = topology;
            let mut e = SimEngine::new(layout.clone(), c);
            let r = e.step(0);
            assert_eq!(e.topology(), topology);
            (r.wire_bytes_per_node, r.seconds)
        };
        let (flat_b, flat_s) = run(TopoKind::Flat);
        let (tree_b, tree_s) = run(TopoKind::Tree);
        let (hier_b, hier_s) = run(TopoKind::Hier { group: 4 });
        let v = layout.dense_bytes();
        assert_eq!(flat_b, 2 * 7 * v / 8, "flat stays at the 2(N-1)/N reference");
        assert_eq!(tree_b, 2 * 7 * v / 8, "tree total is also 2(N-1)V");
        // Flat: 2(N-1) rounds of V/N; tree: 2·log2(N) rounds of V. Both
        // step times share the same fixed compute gap, so strict
        // inequality isolates the wire-time difference.
        assert!(
            tree_s > flat_s,
            "tree wire time {tree_s} should exceed flat {flat_s}"
        );
        // The hierarchy's chain broadcast also ships full payloads.
        assert!(hier_b > 0 && hier_s > flat_s);
    }

    #[test]
    fn resnet50_inventory_runs() {
        let mut e = SimEngine::new(zoo::resnet50(), cfg(Method::IwpFixed, 4));
        let rep = e.step(0);
        assert!(rep.wire_bytes_per_node > 0);
        assert!(rep.density < 1.0);
        let n_layers = e.layout().n_layers();
        let (_imp, stats) = e.importance_snapshot();
        assert_eq!(stats.len(), n_layers);
    }
}
