//! Figs. 5/6 — accuracy and loss curves, baseline vs IWP, same seeds.
//!
//! The paper plots ResNet-50 on ImageNet; we plot the real PJRT-trained
//! MLP (and optionally the transformer) on the synthetic task — the
//! reproducible *shape* is "compressed training tracks the baseline
//! curve with no visible accuracy gap" (DESIGN.md §5).

use crate::compress::Method;
use crate::config::Config;
use crate::coordinator::Trainer;
use crate::csv_row;
use crate::metrics::CsvWriter;
use crate::runtime::Runtime;

/// Train `model` under baseline + both IWP variants and write the
/// Fig. 5/6 curve CSVs.
pub fn run(
    rt: &Runtime,
    out_dir: &str,
    model: &str,
    steps: usize,
    seed: u64,
) -> anyhow::Result<()> {
    let methods = [Method::Baseline, Method::IwpFixed, Method::IwpLayerwise];
    let mut loss_csv = CsvWriter::create(
        format!("{out_dir}/fig6_loss_curves.csv"),
        &["method", "step", "train_loss"],
    )?;
    let mut acc_csv = CsvWriter::create(
        format!("{out_dir}/fig5_accuracy_curves.csv"),
        &["method", "step", "eval_loss", "eval_acc"],
    )?;

    println!("== Fig 5/6: {model} curves over {steps} steps (baseline vs IWP) ==");
    for method in methods {
        let cfg = Config {
            model: model.into(),
            method: method.spec(),
            steps,
            seed,
            threshold: 200.0, // see table1::accuracy_rows on scaling
            ..Config::default()
        };
        let mut t = Trainer::new(cfg, rt)?;
        let out = t.run()?;
        for &(s, l) in &out.losses {
            csv_row!(loss_csv, method.name(), s, l)?;
        }
        for &(s, el, ea) in &out.evals {
            csv_row!(acc_csv, method.name(), s, el, ea)?;
        }
        println!(
            "  {:<22} final eval loss {:.4}, acc {:.4}, ratio {:.1}x",
            method.table_label(),
            out.final_eval_loss,
            out.final_eval_acc,
            out.account.ratio()
        );
    }
    loss_csv.flush()?;
    acc_csv.flush()?;
    println!("paper: IWP curves track the baseline; final accuracy within 0.2pt");
    Ok(())
}
