//! Table I — "Comparison of gradient compression ratio on ImageNet".
//!
//! Two halves (DESIGN.md §2):
//! * **Ratio columns** — exact wire accounting of every method on the
//!   real AlexNet / ResNet50 layer inventories, 96-node gigabit ring,
//!   synthetic gradients (`SimEngine`).
//! * **Accuracy columns** — real end-to-end training of the small PJRT
//!   models (MLP / transformer) under the same methods, same seeds and
//!   step budget, reporting final eval accuracy/loss.
//!
//! Paper values for comparison: AlexNet 64× (fixed) / 53× (layerwise),
//! ResNet50 58.8× / 47.6×, TernGrad 8×, with ≤0.2pt top-1 delta.

use crate::compress::Method;
use crate::config::Config;
use crate::coordinator::Trainer;
use crate::csv_row;
use crate::exp::simrun::{SimCfg, SimEngine};
use crate::metrics::CsvWriter;
use crate::model::zoo;
use crate::runtime::Runtime;

/// Ratio half: (model, method, payload_ratio, wire_ratio, mean_density).
pub fn ratio_rows(
    nodes: usize,
    steps: usize,
    threshold: f32,
    seed: u64,
) -> Vec<(String, Method, f64, f64, f64)> {
    let mut rows = Vec::new();
    for (model_name, layout) in [("AlexNet", zoo::alexnet()), ("ResNet50", zoo::resnet50())] {
        for method in [
            Method::Baseline,
            Method::TernGrad,
            Method::IwpFixed,
            Method::IwpLayerwise,
        ] {
            let cfg = SimCfg {
                nodes,
                method: method.spec(),
                threshold,
                seed,
                ..Default::default()
            };
            let mut engine = SimEngine::new(layout.clone(), cfg);
            for s in 0..steps {
                engine.step(s);
            }
            rows.push((
                model_name.to_string(),
                method,
                engine.account.payload_ratio(),
                engine.account.ratio(),
                engine.account.mean_density(),
            ));
        }
    }
    rows
}

/// Accuracy half: train the real small models under each method.
pub fn accuracy_rows(
    rt: &Runtime,
    steps: usize,
    seed: u64,
) -> anyhow::Result<Vec<(String, Method, f64, f64, f64)>> {
    let mut rows = Vec::new();
    for model in ["mlp"] {
        for method in [
            Method::Baseline,
            Method::TernGrad,
            Method::IwpFixed,
            Method::IwpLayerwise,
        ] {
            let cfg = Config {
                model: model.into(),
                method: method.spec(),
                steps,
                seed,
                nodes: 4,
                // Real small models early in training have importance
                // values O(1-10) (large gradients vs freshly-initialized
                // weights); the IWP threshold scales accordingly (the
                // paper's 0.005-0.1 regime corresponds to ImageNet
                // steady-state gradients).
                threshold: 200.0,
                ..Config::default()
            };
            let mut t = Trainer::new(cfg, rt)?;
            let out = t.run()?;
            rows.push((
                model.to_string(),
                method,
                out.final_eval_acc,
                out.final_eval_loss,
                out.account.ratio(),
            ));
        }
    }
    Ok(rows)
}

/// Full harness: print the table and write CSVs.
pub fn run(
    rt: Option<&Runtime>,
    out_dir: &str,
    nodes: usize,
    sim_steps: usize,
    train_steps: usize,
    threshold: f32,
    seed: u64,
) -> anyhow::Result<()> {
    println!("== Table I (ratio half): {nodes}-node ring, synthetic grads on real inventories ==");
    println!("  CompressRatio = the paper's size[G]/size[encode(sparse(G))] payload metric;");
    println!("  WireRatio additionally counts mask AllGather + ring transport end-to-end.");
    println!(
        "{:<10} {:<22} {:>14} {:>11} {:>12}",
        "Model", "Training Method", "CompressRatio", "WireRatio", "MeanDensity"
    );
    let mut csv = CsvWriter::create(
        format!("{out_dir}/table1_ratio.csv"),
        &["model", "method", "compress_ratio_payload", "wire_ratio", "mean_density"],
    )?;
    for (model, method, payload, wire, density) in
        ratio_rows(nodes, sim_steps, threshold, seed)
    {
        println!(
            "{model:<10} {:<22} {payload:>13.1}x {wire:>10.1}x {density:>12.5}",
            method.table_label()
        );
        csv_row!(csv, model.as_str(), method.name(), payload, wire, density)?;
    }
    csv.flush()?;

    if let Some(rt) = rt {
        println!("\n== Table I (accuracy half): real training, {train_steps} steps, 4-node ring ==");
        println!(
            "{:<10} {:<22} {:>10} {:>10} {:>14}",
            "Model", "Training Method", "EvalAcc", "EvalLoss", "CompressRatio"
        );
        let mut csv = CsvWriter::create(
            format!("{out_dir}/table1_accuracy.csv"),
            &["model", "method", "eval_acc", "eval_loss", "compress_ratio"],
        )?;
        for (model, method, acc, loss, ratio) in accuracy_rows(rt, train_steps, seed)? {
            println!(
                "{model:<10} {:<22} {acc:>10.4} {loss:>10.4} {ratio:>13.1}x",
                method.table_label()
            );
            csv_row!(csv, model.as_str(), method.name(), acc, loss, ratio)?;
        }
        csv.flush()?;
    } else {
        println!("\n(no artifacts — skipping accuracy half; run `make artifacts`)");
    }
    println!("\npaper: AlexNet 64x/53x, ResNet50 58.8x/47.6x, TernGrad 8x; accuracy within 0.2pt of baseline");
    Ok(())
}
