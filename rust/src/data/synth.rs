//! Synthetic classification dataset — a learnable CIFAR-10 stand-in.
//!
//! Class c's examples are `prototype_c + noise`: a Gaussian mixture with
//! one anchor per class in input space. An MLP reaches high accuracy on
//! it within a few hundred steps, which is exactly what the Table-I
//! accuracy columns and Fig. 5/6 curves need: a task where compression-
//! induced accuracy loss is *measurable* against a converging baseline.

use crate::util::rng::Rng;

/// Gaussian-mixture classification data, sharded per node.
#[derive(Debug, Clone)]
pub struct SynthClassification {
    /// Input dimensionality.
    pub dim: usize,
    /// Number of mixture components / labels.
    pub n_classes: usize,
    /// Per-class anchor vectors.
    prototypes: Vec<Vec<f32>>,
    /// Within-class noise stddev (controls task difficulty).
    pub noise: f32,
}

impl SynthClassification {
    /// Draw `n_classes` Gaussian anchors in `dim` dimensions from `seed`.
    pub fn new(dim: usize, n_classes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let prototypes = (0..n_classes)
            .map(|_| {
                let mut p = vec![0.0f32; dim];
                rng.fill_normal(&mut p, 0.0, 1.0);
                p
            })
            .collect();
        SynthClassification {
            dim,
            n_classes,
            prototypes,
            noise,
        }
    }

    /// CIFAR-like default: 3072-dim inputs, 10 classes.
    pub fn cifar_like(seed: u64) -> Self {
        SynthClassification::new(3 * 32 * 32, 10, 1.2, seed)
    }

    /// Sample a batch with a node-local RNG (shards never overlap because
    /// each node derives its own stream). Returns (x: B*dim, y: B).
    pub fn batch(&self, rng: &mut Rng, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(batch * self.dim);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = rng.below(self.n_classes);
            y.push(c as f32);
            let proto = &self.prototypes[c];
            for &p in proto {
                x.push(p + self.noise * rng.normal());
            }
        }
        (x, y)
    }

    /// A fixed evaluation set (same for every node/method — fair
    /// accuracy comparisons across Table-I rows).
    pub fn eval_set(&self, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed ^ 0xEEE);
        self.batch(&mut rng, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let d = SynthClassification::new(16, 4, 0.5, 1);
        let mut rng = Rng::new(2);
        let (x, y) = d.batch(&mut rng, 8);
        assert_eq!(x.len(), 8 * 16);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&c| c >= 0.0 && c < 4.0));
    }

    #[test]
    fn classes_are_linearly_separable_enough() {
        // Nearest-prototype classification should beat chance easily.
        let d = SynthClassification::new(32, 4, 0.5, 3);
        let mut rng = Rng::new(4);
        let (x, y) = d.batch(&mut rng, 200);
        let mut correct = 0;
        for b in 0..200 {
            let xb = &x[b * 32..(b + 1) * 32];
            let mut best = (f32::INFINITY, 0usize);
            for (c, proto) in d.prototypes.iter().enumerate() {
                let dist: f32 = xb
                    .iter()
                    .zip(proto)
                    .map(|(a, p)| (a - p) * (a - p))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y[b] as usize {
                correct += 1;
            }
        }
        assert!(correct > 150, "nearest-prototype acc {correct}/200");
    }

    #[test]
    fn eval_set_is_deterministic() {
        let d = SynthClassification::new(8, 2, 0.3, 9);
        assert_eq!(d.eval_set(16, 7), d.eval_set(16, 7));
    }

    #[test]
    fn different_seeds_different_data() {
        let d = SynthClassification::new(8, 2, 0.3, 9);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(2);
        assert_ne!(d.batch(&mut r1, 4).0, d.batch(&mut r2, 4).0);
    }
}
