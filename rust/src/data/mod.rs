//! Datasets: synthetic classification (CIFAR-stand-in for the MLP) and
//! the embedded tiny text corpus (char-LM transformer). Both shard across
//! simulated nodes the way the paper shards ImageNet across workers.

pub mod corpus;
pub mod synth;

pub use corpus::CharCorpus;
pub use synth::SynthClassification;
