//! Embedded tiny text corpus + char tokenizer for the transformer LM.
//!
//! Vocab = 96 printable ASCII codes (32..=126 plus newline mapped to 95),
//! matching `python/compile/models/transformer.py`. The corpus is a
//! distribution-systems themed passage embedded in the binary so the e2e
//! driver needs no external data.

const VOCAB: usize = 96;

/// The training text. A few KB of real English prose is plenty for a
/// char-LM to show a cleanly decreasing loss curve over a few hundred
/// steps (EXPERIMENTS.md §E2E).
pub const TINY_CORPUS: &str = "\
Training deep neural networks on a single machine is limited by the memory \
and compute of one accelerator, so modern systems distribute the work across \
many nodes. In synchronous data parallel training every node holds a replica \
of the model, computes gradients on its own shard of the data, and then all \
nodes must agree on a single averaged gradient before taking a step. The \
simplest design routes every gradient through a central parameter server, \
but the server's network link saturates as the cluster grows. Ring all \
reduce removes the central bottleneck: the nodes form a ring, each node \
sends one chunk of its gradient to its neighbour while receiving another, \
and after two sweeps around the ring every node holds the averaged result. \
The bytes each node transmits are constant in the number of nodes, which \
makes the ring attractive for large clusters built from commodity gigabit \
ethernet rather than expensive infiniband fabrics. Even so, the gradient of \
a modern network is tens or hundreds of megabytes, and exchanging it every \
step keeps the links near full load. Gradient compression attacks this cost \
directly. Most coordinates of the gradient barely move the weights, so a \
node can transmit only the important coordinates and accumulate the rest \
locally until they matter. Importance can be measured by the ratio of the \
gradient to the weight it updates: a small weight moved by a large gradient \
changes the function of the network far more than a large weight nudged \
slightly. A fixed threshold on this ratio already removes most of the \
traffic. A layer wise threshold adapts further, because convolutional \
layers, normalisation layers and fully connected layers have very different \
importance distributions, and the dispersion of each layer's distribution \
signals whether its gradients are ordered enough to prune aggressively. \
Pruning on a ring has a subtle failure mode: if every node selects its own \
top coordinates, the union of selections grows at every hop and the \
gradient arriving back at each node is nearly dense, wasting the bandwidth \
the pruning was meant to save. Sharing one mask fixes this. A few randomly \
chosen nodes broadcast the indices they consider important, every node \
combines those masks, and the ring then reduces exactly the shared support, \
so the sparsity survives the whole journey regardless of how many nodes \
join the ring. Stale residuals are refreshed by occasionally transmitting \
unimportant gradients with probability proportional to their importance, \
which keeps slow moving parameters from freezing in place. Together these \
pieces let a commodity cluster train image classifiers at full accuracy \
while moving a tiny fraction of the original bytes.\n";

/// Char tokenizer: printable ASCII 32..=126 -> 0..=94, everything else
/// (incl. newline) -> 95.
pub fn encode_char(c: u8) -> u8 {
    if (32..=126).contains(&c) {
        c - 32
    } else {
        (VOCAB - 1) as u8
    }
}

/// Inverse of [`encode_char`] (the overflow token renders as newline).
pub fn decode_char(t: u8) -> char {
    if (t as usize) < VOCAB - 1 {
        (t + 32) as char
    } else {
        '\n'
    }
}

/// Tokenized corpus with sharded batch sampling.
#[derive(Debug, Clone)]
pub struct CharCorpus {
    tokens: Vec<u8>,
    /// Vocabulary size (96 printable-ASCII codes).
    pub vocab: usize,
}

impl CharCorpus {
    /// The embedded [`TINY_CORPUS`].
    pub fn tiny() -> Self {
        CharCorpus::from_text(TINY_CORPUS)
    }

    /// Tokenize arbitrary text with the char tokenizer.
    pub fn from_text(text: &str) -> Self {
        CharCorpus {
            tokens: text.bytes().map(encode_char).collect(),
            vocab: VOCAB,
        }
    }

    /// Token count.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True for an empty corpus.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Sample a batch of (seq_len + 1)-token windows as f32 (the artifact
    /// takes f32 tokens and casts inside — see transformer.py).
    /// Returns a flat B*(seq_len+1) buffer.
    pub fn batch(&self, rng: &mut crate::util::rng::Rng, batch: usize, seq_len: usize) -> Vec<f32> {
        let window = seq_len + 1;
        assert!(
            self.tokens.len() > window,
            "corpus shorter than one window"
        );
        let mut out = Vec::with_capacity(batch * window);
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - window);
            out.extend(
                self.tokens[start..start + window]
                    .iter()
                    .map(|&t| t as f32),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn corpus_is_nontrivial() {
        let c = CharCorpus::tiny();
        assert!(c.len() > 2000, "corpus too small: {}", c.len());
    }

    #[test]
    fn tokens_in_vocab() {
        let c = CharCorpus::tiny();
        assert!(c.tokens.iter().all(|&t| (t as usize) < VOCAB));
    }

    #[test]
    fn encode_decode_roundtrip_printables() {
        for c in 32u8..=126 {
            assert_eq!(decode_char(encode_char(c)), c as char);
        }
        assert_eq!(decode_char(encode_char(b'\n')), '\n');
    }

    #[test]
    fn batch_shape_and_range() {
        let c = CharCorpus::tiny();
        let mut rng = Rng::new(1);
        let b = c.batch(&mut rng, 4, 64);
        assert_eq!(b.len(), 4 * 65);
        assert!(b.iter().all(|&t| t >= 0.0 && t < VOCAB as f32));
    }

    #[test]
    fn batches_vary() {
        let c = CharCorpus::tiny();
        let mut rng = Rng::new(1);
        let a = c.batch(&mut rng, 2, 32);
        let b = c.batch(&mut rng, 2, 32);
        assert_ne!(a, b);
    }
}
