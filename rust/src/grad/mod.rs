//! Gradient substrates: synthetic generators for bandwidth-scale
//! experiments (ImageNet-model inventories are too large to *train* on
//! this testbed, but their gradient *statistics* are reproducible).

pub mod synth;

pub use synth::SynthGrads;
