//! Synthetic gradient generator with per-layer-kind statistics.
//!
//! The paper's bandwidth results (Table I ratio columns, Figs. 2–4, 7–8)
//! depend on the *distribution of importance values* `I = |g|/(|w|+ε)` per
//! layer, not on actual ImageNet data.  We model each layer's weights and
//! gradients the way deep-CNN training empirically behaves:
//!
//! * weights ~ N(0, 2/fan_in) (He init scale; BN gains ≈ 1, biases small),
//! * gradients ~ N(0, σ_k²·decay(t)) with σ per layer kind — BN/bias
//!   gradients are relatively larger vs their tiny weights, giving them
//!   the fat-importance distributions of Fig. 3,
//! * a per-layer log-normal "activity" factor resampled over time models
//!   the paper's observation that "in different epoch and different steps,
//!   the neural networks focus on updating different layers" (the false
//!   frozen-layer phenomenon), which drives the var/mean dynamics of
//!   Fig. 4,
//! * gradient scale decays with step (lr/loss decay), which the paper says
//!   raises the judged importance over training.
//!
//! The importance I is then a ratio of (correlated scale) normals — a
//! heavy-tailed distribution, exactly the regime where a fixed threshold
//! transmits a small top fraction.

use crate::model::{LayerKind, ParamLayout};
use crate::util::rng::Rng;

/// Per-kind gradient scale relative to weight scale.
///
/// Calibrated so the typical per-step importance `|g|/|w|` sits at
/// ~1e-4–1e-3 (what SGD on a converging CNN actually produces — the
/// per-step relative weight change is on the order of the learning
/// rate times the gradient-to-weight ratio). The ratio-of-normals tail
/// then puts ~0.1–2% of coordinates above the paper's 0.005–0.1
/// thresholds, the regime its 50–64x ratios live in.
fn kind_grad_scale(kind: LayerKind) -> f32 {
    match kind {
        LayerKind::Conv => 5.0e-6,
        LayerKind::Fc => 4.0e-6,
        LayerKind::Attn => 5.0e-6,
        LayerKind::Embed => 2.5e-6,
        // Norm/bias params are O(1)/O(0.01) with comparatively large
        // gradients -> importance distribution shifted right (Fig. 3).
        LayerKind::BatchNorm => 2.0e-5,
        LayerKind::Norm => 2.0e-5,
        LayerKind::Bias => 1.2e-5,
    }
}

/// Synthetic (weights, gradients) stream over a model layout.
///
/// Generation is **counter-based**: every (step, node, layer) triple
/// derives its own SplitMix64 stream from the base seed, so gradients
/// are a pure function of those coordinates. That makes per-node
/// generation order-independent — the parallel executor (DESIGN.md §4)
/// fills node buffers concurrently and gets bit-identical streams to
/// the sequential path, with no shared RNG cursor to race on.
pub struct SynthGrads {
    layout: ParamLayout,
    /// Fixed synthetic weights (He-init scale per layer kind).
    pub weights: Vec<f32>,
    /// Steps between per-layer activity resamples (the paper's "focus
    /// shifts between layers over 100-300 steps" observation).
    refocus_every: usize,
    seed: u64,
}

/// Domain-separation tags for the counter-based streams.
const TAG_GRAD: u64 = 0x6772_6164; // "grad"
const TAG_ACTIVITY: u64 = 0xAC71_F17F;

impl SynthGrads {
    /// Build a generator over `layout` with all randomness derived from
    /// `seed`.
    pub fn new(layout: ParamLayout, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut weights = vec![0.0f32; layout.total_params()];
        for layer in layout.layers() {
            let w = &mut weights[layer.range()];
            match layer.kind {
                LayerKind::BatchNorm | LayerKind::Norm => {
                    // gains near 1, biases near 0 — split halves as in bn(w,b)
                    rng.fill_normal(w, 1.0, 0.05);
                }
                LayerKind::Bias => rng.fill_normal(w, 0.0, 0.01),
                _ => {
                    let sigma = (2.0 / layer.fan_in() as f32).sqrt();
                    rng.fill_normal(w, 0.0, sigma);
                }
            }
        }
        SynthGrads {
            layout,
            weights,
            refocus_every: 100,
            seed,
        }
    }

    /// The layout this generator produces gradients for.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// Stateless stream derivation: one independent RNG per
    /// (tag, a, b, c) coordinate, mixed with distinct odd constants.
    fn stream(&self, tag: u64, a: u64, b: u64, c: u64) -> Rng {
        Rng::new(
            self.seed
                ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ a.wrapping_mul(0xBF58_476D_1CE4_E5B9)
                ^ b.wrapping_mul(0x94D0_49BB_1331_11EB)
                ^ c.wrapping_mul(0xD6E8_FEB8_6659_FD93),
        )
    }

    /// Per-layer log-normal activity multiplier at `step`: most layers
    /// quiet, a few "in focus", resampled every `refocus_every` steps
    /// (the paper's false frozen-layer phenomenon driving Fig. 4).
    pub fn activity_at(&self, layer_idx: usize, step: usize) -> f32 {
        let epoch = step / self.refocus_every.max(1);
        self.stream(TAG_ACTIVITY, epoch as u64, layer_idx as u64, 0)
            .lognormal(0.0, 1.0)
    }

    /// Gradient scale decay over steps (lr schedule proxy).
    fn decay(step: usize) -> f32 {
        1.0 / (1.0 + step as f32 / 2000.0)
    }

    /// Fill `grads` (len == total_params) with `node`'s gradient at
    /// `step`. Pure in (step, node): any call order — including
    /// concurrent per-node calls from the executor — produces identical
    /// buffers.
    pub fn gen_step_node(&self, step: usize, node: usize, grads: &mut [f32]) {
        assert_eq!(grads.len(), self.layout.total_params());
        let decay = Self::decay(step);
        for (li, layer) in self.layout.layers().iter().enumerate() {
            let sigma = kind_grad_scale(layer.kind)
                * self.activity_at(li, step)
                * decay
                * (2.0 / layer.fan_in() as f32).sqrt().max(0.05);
            let g = &mut grads[layer.range()];
            self.stream(TAG_GRAD, step as u64, node as u64, li as u64)
                .fill_normal(g, 0.0, sigma);
        }
    }

    /// Fill `grads` for node 0 (single-stream callers).
    pub fn gen_step(&self, step: usize, grads: &mut [f32]) {
        self.gen_step_node(step, 0, grads);
    }

    /// Convenience: allocate and fill node 0's gradient.
    pub fn step(&self, step: usize) -> Vec<f32> {
        let mut g = vec![0.0f32; self.layout.total_params()];
        self.gen_step(step, &mut g);
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::model::LayerKind;
    use crate::util::stats::Welford;

    fn tiny_layout() -> ParamLayout {
        ParamLayout::new(
            "tiny",
            vec![
                ("conv".into(), vec![8, 4, 3, 3], LayerKind::Conv),
                ("bn".into(), vec![16], LayerKind::BatchNorm),
                ("fc".into(), vec![32, 10], LayerKind::Fc),
            ],
        )
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthGrads::new(tiny_layout(), 7);
        let b = SynthGrads::new(tiny_layout(), 7);
        assert_eq!(a.step(0), b.step(0));
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn node_streams_are_decorrelated_and_order_independent() {
        let s = SynthGrads::new(tiny_layout(), 7);
        let total = s.layout().total_params();
        let mut g0 = vec![0.0f32; total];
        let mut g1 = vec![0.0f32; total];
        // Generate node 1 before node 0: counter-based streams must not
        // care about call order.
        s.gen_step_node(3, 1, &mut g1);
        s.gen_step_node(3, 0, &mut g0);
        assert_ne!(g0, g1, "nodes must see different gradients");
        let mut g0_again = vec![0.0f32; total];
        s.gen_step_node(3, 0, &mut g0_again);
        assert_eq!(g0, g0_again, "same (step, node) must replay exactly");
    }

    #[test]
    fn conv_and_bn_importance_distributions_differ() {
        // The Fig.2-vs-Fig.3 asymmetry the generator must reproduce: the
        // per-kind importance distributions are materially different
        // (conv weights are tiny He-scaled values -> heavy-tailed ratio;
        // BN gains sit near 1 -> compact, low-mean importance).
        let s = SynthGrads::new(zoo::resnet50(), 3);
        let g = s.step(0);
        let mut conv = Welford::new();
        let mut bnw = Welford::new();
        for layer in s.layout().layers() {
            let dst = match layer.kind {
                LayerKind::Conv => &mut conv,
                LayerKind::BatchNorm => &mut bnw,
                _ => continue,
            };
            for i in layer.range() {
                dst.push((g[i].abs() / (s.weights[i].abs() + 1e-8)) as f64);
            }
        }
        let ratio = conv.mean() / bnw.mean().max(1e-12);
        assert!(
            !(0.5..=2.0).contains(&ratio),
            "distributions too similar: conv {} vs bn {}",
            conv.mean(),
            bnw.mean()
        );
        assert!(conv.var() > 0.0 && bnw.var() > 0.0);
    }

    #[test]
    fn gradient_scale_decays_over_steps() {
        let s = SynthGrads::new(tiny_layout(), 5);
        let g0 = s.step(0);
        let g9k = s.step(9000);
        let rms = |v: &[f32]| {
            (v.iter().map(|x| (x * x) as f64).sum::<f64>() / v.len() as f64).sqrt()
        };
        assert!(rms(&g9k) < rms(&g0) * 0.5);
    }

    #[test]
    fn activity_refocuses_layers() {
        let s = SynthGrads::new(tiny_layout(), 11);
        // Constant within an epoch interval, resampled across intervals.
        assert_eq!(s.activity_at(0, 0), s.activity_at(0, 99));
        assert_ne!(s.activity_at(0, 0), s.activity_at(0, 100));
        // Layers refocus independently.
        assert_ne!(s.activity_at(0, 0), s.activity_at(1, 0));
    }
}
