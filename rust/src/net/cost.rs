//! Closed-form wire-time cost model for ring schedules (DESIGN.md §9)
//! and for the topology subsystem's schedules (DESIGN.md §10).
//!
//! [`RingNet`](super::RingNet) *executes* schedules round by round;
//! this module *predicts* the same byte and virtual-time totals from
//! the link parameters alone. Time predictions accumulate per-round
//! durations in the exact order the simulator advances its clock, so
//! for the uniform schedules (dense, masked, allgather) the prediction
//! equals the simulated clock **to the last bit** — cross-validated in
//! the tests here and in `exp::bench`, whose `BENCH_*.json` rows carry
//! both numbers as a built-in sanity check. For the sparse DGC schedule
//! (data-dependent densification) the model uses the paper's
//! independence approximation and is an estimate, not an oracle.
//!
//! The per-topology predictions (`CostModel::topo_dense_seconds` and
//! friends) consume the same net-free round plans the accounting-only
//! simulation paths drive `RingNet` with (`net::topo`, DESIGN.md §10),
//! so prediction and simulation agree bit for bit *by construction*
//! for every topology, not just the flat ring.

use super::topo::{
    chunk_size, hier_dense_plan, hier_spread_plan, pipeline, tree_dense_plan, tree_spread_plan,
};
use super::{LinkSpec, TopoKind};
use crate::ring::chunk_ranges;
use crate::sparse::{wire_bytes, WireFormat};

/// Analytic byte/time model of one `n`-node ring — homogeneous by
/// default, heterogeneous once a per-hop table is installed
/// ([`CostModel::set_links`]).
#[derive(Debug, Clone)]
pub struct CostModel {
    nodes: usize,
    link: LinkSpec,
    /// Per-hop link table (entry `i` = node `i`'s outgoing edge).
    /// `None` prices every hop at `link` — bit-identical to the
    /// pre-heterogeneous model; a uniform table equal to `link` is too.
    links: Option<Vec<LinkSpec>>,
}

impl CostModel {
    /// Model an `n`-node ring (`n >= 2`) with homogeneous `link`s.
    pub fn new(nodes: usize, link: LinkSpec) -> Self {
        assert!(nodes >= 2, "a ring needs at least 2 nodes");
        CostModel {
            nodes,
            link,
            links: None,
        }
    }

    /// Ring size N.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// The base link parameters this model prices against (per-hop
    /// overrides, when installed, take precedence — see
    /// [`CostModel::set_links`]).
    pub fn link(&self) -> &LinkSpec {
        &self.link
    }

    /// Install a per-hop link table (one [`LinkSpec`] per ring hop, in
    /// node order) so predictions price heterogeneous rings — e.g. a
    /// chaos straggler (`net::chaos`, DESIGN.md §15). Synchronous
    /// rounds are paced by their slowest transfer, so one degraded hop
    /// slows every prediction, exactly as it slows the simulated ring.
    pub fn set_links(&mut self, links: Vec<LinkSpec>) {
        assert_eq!(links.len(), self.nodes, "one link per ring hop");
        self.links = Some(links);
    }

    /// The installed per-hop table, if any.
    pub fn links(&self) -> Option<&[LinkSpec]> {
        self.links.as_deref()
    }

    /// Transfer time of `bytes` on hop `i`'s link.
    fn hop_time(&self, i: usize, bytes: u64) -> f64 {
        match &self.links {
            Some(ls) => ls[i % ls.len()].transfer_time(bytes),
            None => self.link.transfer_time(bytes),
        }
    }

    /// Virtual seconds of one synchronous round whose slowest transfer
    /// moves `max_bytes` (the paper's "the limit of the system is
    /// determined only by the slowest connection") — with a per-hop
    /// table, the slowest *link* paces the round.
    pub fn round_seconds(&self, max_bytes: u64) -> f64 {
        match &self.links {
            Some(ls) => ls
                .iter()
                .map(|l| l.transfer_time(max_bytes))
                .fold(0.0f64, f64::max),
            None => self.link.transfer_time(max_bytes),
        }
    }

    /// Largest chunk (in bytes) of the balanced partition of `coords`
    /// f32 coordinates — every dense round is paced by it.
    fn max_chunk_bytes(&self, coords: usize) -> u64 {
        let n = self.nodes;
        ((coords / n + usize::from(coords % n != 0)) * 4) as u64
    }

    /// Dense scatter-reduce + allgather: `2(N-1)` rounds, each paced by
    /// the largest chunk. Matches the simulated clock bit-for-bit.
    pub fn dense_seconds(&self, coords: usize) -> f64 {
        if coords == 0 {
            return 0.0;
        }
        let per_round = self.round_seconds(self.max_chunk_bytes(coords));
        let mut t = 0.0;
        for _ in 0..2 * (self.nodes - 1) {
            t += per_round;
        }
        t
    }

    /// Total wire bytes of a dense all-reduce across all nodes: every
    /// round moves one full rotation of the chunk set.
    pub fn dense_total_bytes(&self, coords: usize) -> u64 {
        if coords == 0 {
            return 0;
        }
        2 * (self.nodes as u64 - 1) * (coords as u64) * 4
    }

    /// Mean per-node wire bytes of a dense all-reduce — the paper's
    /// `2(N-1)/N · V` constant-cost property.
    pub fn dense_bytes_per_node(&self, coords: usize) -> f64 {
        self.dense_total_bytes(coords) as f64 / self.nodes as f64
    }

    /// Ring allgather of `k` equal `blob_bytes` blobs (zero blobs on the
    /// other nodes): `N-1` rounds, each paced by one blob (when `k >= 1`).
    /// Matches the simulated clock bit-for-bit.
    pub fn allgather_seconds(&self, blob_bytes: u64, k: usize) -> f64 {
        let per_round = if k == 0 {
            0.0
        } else {
            self.round_seconds(blob_bytes)
        };
        let mut t = 0.0;
        for _ in 0..self.nodes - 1 {
            t += per_round;
        }
        t
    }

    /// Total allgather bytes: each of the `k` blobs crosses `N-1` links.
    pub fn allgather_total_bytes(&self, blob_bytes: u64, k: usize) -> u64 {
        blob_bytes * k.min(self.nodes) as u64 * (self.nodes as u64 - 1)
    }

    /// Algorithm 1's masked schedule: allgather of `k` broadcaster masks
    /// over `coords` coordinates, then dense value rounds over the
    /// `support`-coordinate compacted vectors. Accumulates round by
    /// round in the simulator's clock order (not phase-by-phase — f64
    /// addition does not reassociate), so it matches the simulated clock
    /// bit-for-bit.
    pub fn masked_seconds(&self, coords: usize, k: usize, support: usize) -> f64 {
        let mask_bytes = (coords.div_ceil(8)) as u64;
        let mut t = self.allgather_seconds(mask_bytes, k);
        if support > 0 {
            let per_round = self.round_seconds(self.max_chunk_bytes(support));
            for _ in 0..2 * (self.nodes - 1) {
                t += per_round;
            }
        }
        t
    }

    /// Total wire bytes of the masked schedule.
    pub fn masked_total_bytes(&self, coords: usize, k: usize, support: usize) -> u64 {
        let mask_bytes = (coords.div_ceil(8)) as u64;
        self.allgather_total_bytes(mask_bytes, k) + self.dense_total_bytes(support)
    }

    /// Estimated seconds of the sparse (DGC-on-a-ring) scatter-reduce +
    /// allgather at per-node density `d0`, under the independence
    /// approximation `d_h = 1 - (1 - d0)^(h+1)` (the paper's Sec. II
    /// densification model). An estimate: actual supports are random.
    pub fn sparse_seconds_estimate(&self, coords: usize, d0: f64) -> f64 {
        let n = self.nodes;
        let chunks = chunk_ranges(coords, n);
        let max_chunk = chunks.iter().map(|c| c.len()).max().unwrap_or(0);
        let seg_bytes = |chunk: usize, d: f64| -> u64 {
            let nnz = ((chunk as f64 * d).round() as usize).min(chunk);
            wire_bytes(WireFormat::cheapest(chunk, nnz), chunk, nnz)
        };
        let mut t = 0.0;
        // Scatter hop r sends segments that have absorbed r+1 supports.
        for r in 0..n - 1 {
            let d = 1.0 - (1.0 - d0).powi(r as i32 + 1);
            t += self.round_seconds(seg_bytes(max_chunk, d));
        }
        // Allgather at the final density.
        let d_final = 1.0 - (1.0 - d0).powi(n as i32);
        for _ in 0..n - 1 {
            t += self.round_seconds(seg_bytes(max_chunk, d_final));
        }
        t
    }

    // ---- per-topology predictions (DESIGN.md §10) ----------------------

    /// Accumulate (total bytes, virtual seconds) over a round plan,
    /// pricing each round exactly as [`RingNet::round`](super::RingNet::round)
    /// does: the round lasts as long as its slowest transfer (each
    /// node's send on its own hop's link), folded in node order.
    fn run_plan(&self, plan: impl FnOnce(&mut dyn FnMut(&[u64]))) -> (u64, f64) {
        let mut bytes = 0u64;
        let mut t = 0.0f64;
        plan(&mut |sends: &[u64]| {
            let dur = sends
                .iter()
                .enumerate()
                .map(|(i, &b)| self.hop_time(i, b))
                .fold(0.0f64, f64::max);
            bytes += sends.iter().sum::<u64>();
            t += dur;
        });
        (bytes, t)
    }

    /// Per-round `(Σ bytes, duration)` stream of the dense schedule
    /// under a **base** topology, in the exact simulation round order —
    /// the building block the pipelined predictions accumulate from.
    fn base_dense_rounds(&self, base: TopoKind, coords: usize, f: &mut dyn FnMut(u64, f64)) {
        match base {
            TopoKind::Flat => {
                if coords == 0 {
                    return;
                }
                // Flat rounds are max-chunk paced; under a per-hop
                // table the slowest link paces every round (the chunk
                // rotation puts the max chunk on each hop in turn, so
                // this stays the synchronous-round worst case).
                let per_round = self.round_seconds(self.max_chunk_bytes(coords));
                let bytes = coords as u64 * 4;
                for _ in 0..2 * (self.nodes - 1) {
                    f(bytes, per_round);
                }
            }
            TopoKind::Hier { group } => {
                hier_dense_plan(self.nodes, group, coords, &mut Vec::new(), |s| {
                    let dur = s
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| self.hop_time(i, b))
                        .fold(0.0f64, f64::max);
                    f(s.iter().sum::<u64>(), dur);
                })
            }
            TopoKind::Tree => tree_dense_plan(self.nodes, coords, &mut Vec::new(), |s| {
                let dur = s
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| self.hop_time(i, b))
                    .fold(0.0f64, f64::max);
                f(s.iter().sum::<u64>(), dur);
            }),
            TopoKind::Pipeline { .. } => unreachable!("pipelines do not nest"),
        }
    }

    /// Per-round `(Σ bytes, duration)` stream of the blob spread under a
    /// base topology, in simulation round order.
    fn base_spread_rounds(&self, base: TopoKind, blob: u64, k: usize, f: &mut dyn FnMut(u64, f64)) {
        let k = k.min(self.nodes);
        match base {
            TopoKind::Flat => {
                let per_round = if k == 0 {
                    0.0
                } else {
                    self.round_seconds(blob)
                };
                let bytes = blob * k as u64;
                for _ in 0..self.nodes - 1 {
                    f(bytes, per_round);
                }
            }
            TopoKind::Hier { group } => {
                hier_spread_plan(self.nodes, group, blob, k, &mut Vec::new(), |s| {
                    let dur = s
                        .iter()
                        .enumerate()
                        .map(|(i, &b)| self.hop_time(i, b))
                        .fold(0.0f64, f64::max);
                    f(s.iter().sum::<u64>(), dur);
                })
            }
            TopoKind::Tree => tree_spread_plan(self.nodes, blob, k, &mut Vec::new(), |s| {
                let dur = s
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| self.hop_time(i, b))
                    .fold(0.0f64, f64::max);
                f(s.iter().sum::<u64>(), dur);
            }),
            TopoKind::Pipeline { .. } => unreachable!("pipelines do not nest"),
        }
    }

    fn topo_dense(&self, topo: TopoKind, coords: usize) -> (u64, f64) {
        match topo {
            TopoKind::Flat => (self.dense_total_bytes(coords), self.dense_seconds(coords)),
            TopoKind::Hier { group } => self.run_plan(|round| {
                hier_dense_plan(self.nodes, group, coords, &mut Vec::new(), round)
            }),
            TopoKind::Tree => self.run_plan(|round| {
                tree_dense_plan(self.nodes, coords, &mut Vec::new(), round)
            }),
            // Pipelined dense has no prep stage: the chunks' round
            // sequences run back-to-back (DESIGN.md §11).
            TopoKind::Pipeline { chunks, inner } => {
                let (mut bytes, mut t) = (0u64, 0.0f64);
                for ci in 0..chunks {
                    let clen = chunk_size(coords, chunks, ci);
                    if clen == 0 {
                        continue;
                    }
                    self.base_dense_rounds(inner.kind(), clen, &mut |b, d| {
                        bytes += b;
                        t += d;
                    });
                }
                (bytes, t)
            }
        }
    }

    fn topo_spread(&self, topo: TopoKind, blob_bytes: u64, k: usize) -> (u64, f64) {
        match topo {
            TopoKind::Flat => (
                self.allgather_total_bytes(blob_bytes, k),
                self.allgather_seconds(blob_bytes, k),
            ),
            TopoKind::Hier { group } => self.run_plan(|round| {
                hier_spread_plan(self.nodes, group, blob_bytes, k, &mut Vec::new(), round)
            }),
            TopoKind::Tree => self.run_plan(|round| {
                tree_spread_plan(self.nodes, blob_bytes, k, &mut Vec::new(), round)
            }),
            // The pipeline wrapper delegates opaque blob spreads to its
            // wrapped topology verbatim.
            TopoKind::Pipeline { inner, .. } => self.topo_spread(inner.kind(), blob_bytes, k),
        }
    }

    /// Virtual seconds of the dense allreduce under `topo`. Matches the
    /// simulated clock of the topology's exact and accounting-only
    /// paths to the last bit (`TopoKind::Flat` delegates to
    /// [`CostModel::dense_seconds`]).
    pub fn topo_dense_seconds(&self, topo: TopoKind, coords: usize) -> f64 {
        self.topo_dense(topo, coords).1
    }

    /// Total wire bytes of the dense allreduce under `topo`.
    pub fn topo_dense_total_bytes(&self, topo: TopoKind, coords: usize) -> u64 {
        self.topo_dense(topo, coords).0
    }

    /// Virtual seconds of spreading `k` blobs of `blob_bytes` (held by
    /// nodes `0..k`) to every node under `topo` — the mask/quantized-
    /// blob distribution primitive.
    pub fn topo_spread_seconds(&self, topo: TopoKind, blob_bytes: u64, k: usize) -> f64 {
        self.topo_spread(topo, blob_bytes, k).1
    }

    /// Total wire bytes of the blob spread under `topo`.
    pub fn topo_spread_total_bytes(&self, topo: TopoKind, blob_bytes: u64, k: usize) -> u64 {
        self.topo_spread(topo, blob_bytes, k).0
    }

    /// One accumulator over the masked schedule's full round sequence —
    /// mask spread immediately followed by the dense rounds over the
    /// compacted support, in the simulator's clock order (not
    /// phase-by-phase: f64 addition does not reassociate).
    fn topo_masked(&self, topo: TopoKind, coords: usize, k: usize, support: usize) -> (u64, f64) {
        let n = self.nodes;
        let mask_bytes = (coords.div_ceil(8)) as u64;
        match topo {
            TopoKind::Flat => (
                self.masked_total_bytes(coords, k, support),
                self.masked_seconds(coords, k, support),
            ),
            TopoKind::Hier { group } => self.run_plan(|round| {
                hier_spread_plan(n, group, mask_bytes, k, &mut Vec::new(), &mut *round);
                hier_dense_plan(n, group, support, &mut Vec::new(), round);
            }),
            TopoKind::Tree => self.run_plan(|round| {
                tree_spread_plan(n, mask_bytes, k, &mut Vec::new(), &mut *round);
                tree_dense_plan(n, support, &mut Vec::new(), round);
            }),
            TopoKind::Pipeline { .. } => panic!(
                "pipelined masked predictions are per-chunk-support-dependent — use \
                 CostModel::pipelined_masked_seconds / pipelined_masked_total_bytes \
                 with pipeline::chunk_supports"
            ),
        }
    }

    /// One accumulator over the layer-pipelined masked schedule
    /// (DESIGN.md §11): per chunk, the prep clock advances first
    /// (`pipeline::prep_seconds`, overlapped with earlier chunks' wire
    /// rounds), then the chunk's mask spread and compacted dense rounds
    /// fold in, replicating `PipelineRing::masked_bytes_only`'s f64
    /// operations exactly — bit-exact against a fresh-net simulation.
    fn pipelined_masked(
        &self,
        inner: TopoKind,
        chunks: usize,
        coords: usize,
        k: usize,
        chunk_supports: &[usize],
    ) -> (u64, f64) {
        assert!(
            !matches!(inner, TopoKind::Pipeline { .. }),
            "pipelines do not nest"
        );
        assert_eq!(
            chunk_supports.len(),
            chunks,
            "one support count per pipeline chunk (pipeline::chunk_supports)"
        );
        let k = k.min(self.nodes);
        let (mut bytes, mut t) = (0u64, 0.0f64);
        let mut prep_done = 0.0f64;
        for ci in 0..chunks {
            let clen = chunk_size(coords, chunks, ci);
            prep_done += pipeline::prep_seconds(clen);
            if t < prep_done {
                t += prep_done - t;
            }
            if clen == 0 {
                continue;
            }
            self.base_spread_rounds(inner, clen.div_ceil(8) as u64, k, &mut |b, d| {
                bytes += b;
                t += d;
            });
            let sup = chunk_supports[ci];
            if sup == 0 {
                continue;
            }
            self.base_dense_rounds(inner, sup, &mut |b, d| {
                bytes += b;
                t += d;
            });
        }
        (bytes, t)
    }

    /// Virtual makespan of the `pipeline:<chunks>:<inner>` masked
    /// schedule — the 2-stage pipeline recurrence
    /// `T = max_l (Σ_{j≤l} prep_j + Σ_{j≥l} wire_j)` accumulated in the
    /// simulator's clock order, so the prediction equals
    /// `PipelineRing::masked_bytes_only` on a fresh net to the last bit.
    /// `chunk_supports` comes from [`pipeline::chunk_supports`] on the
    /// shared mask. `chunks = 1` is the serial reference: the same
    /// schedule with the whole prep pass upfront.
    pub fn pipelined_masked_seconds(
        &self,
        inner: TopoKind,
        chunks: usize,
        coords: usize,
        k: usize,
        chunk_supports: &[usize],
    ) -> f64 {
        self.pipelined_masked(inner, chunks, coords, k, chunk_supports).1
    }

    /// Total wire bytes of the pipelined masked schedule (per-chunk
    /// mask framing rounds each chunk's bit-slice up to whole bytes).
    pub fn pipelined_masked_total_bytes(
        &self,
        inner: TopoKind,
        chunks: usize,
        coords: usize,
        k: usize,
        chunk_supports: &[usize],
    ) -> u64 {
        self.pipelined_masked(inner, chunks, coords, k, chunk_supports).0
    }

    /// Virtual seconds of the masked (Algorithm 1) schedule under
    /// `topo`: mask spread followed by the dense schedule over the
    /// `support`-coordinate compacted vectors, accumulated in the
    /// simulator's round order so the prediction is bit-exact.
    pub fn topo_masked_seconds(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        support: usize,
    ) -> f64 {
        self.topo_masked(topo, coords, k, support).1
    }

    /// Total wire bytes of the masked schedule under `topo`.
    pub fn topo_masked_total_bytes(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        support: usize,
    ) -> u64 {
        self.topo_masked(topo, coords, k, support).0
    }

    /// One accumulator over the `+tern` masked pipeline stage's round
    /// sequence (DESIGN.md §12): spread the `k` broadcaster masks, then
    /// spread every node's ternary-encoded compacted payload *whole*
    /// (ternary values are not closed under addition, so no topology
    /// can scatter-reduce them). Rounds fold in the simulator's clock
    /// order, so on a fresh clock the prediction equals the engine's
    /// wire phase bit for bit. Pipeline wrappers delegate blob spreads
    /// to their inner topology, exactly as the simulation does.
    fn masked_tern(&self, topo: TopoKind, coords: usize, k: usize, nnz: usize) -> (u64, f64) {
        let base = match topo {
            TopoKind::Pipeline { inner, .. } => inner.kind(),
            t => t,
        };
        let mask_bytes = (coords.div_ceil(8)) as u64;
        let blob = crate::compress::terngrad::TernBlob::wire_bytes_for(nnz);
        let (mut bytes, mut t) = (0u64, 0.0f64);
        self.base_spread_rounds(base, mask_bytes, k, &mut |b, d| {
            bytes += b;
            t += d;
        });
        self.base_spread_rounds(base, blob, self.nodes, &mut |b, d| {
            bytes += b;
            t += d;
        });
        (bytes, t)
    }

    /// Virtual seconds of the `+tern` masked stage under `topo` for an
    /// `nnz`-coordinate shared support and `k` broadcaster masks.
    pub fn masked_tern_seconds(&self, topo: TopoKind, coords: usize, k: usize, nnz: usize) -> f64 {
        self.masked_tern(topo, coords, k, nnz).1
    }

    /// One accumulator over the sparse-allgather ("gather") wire
    /// format's round sequence (DESIGN.md §14): spread the `k`
    /// broadcaster masks, then spread every node's compacted f32
    /// payload *whole* (`4·nnz` bytes — receivers decode the shared
    /// mask, so no index stream travels) and sum locally. The
    /// RedSync-style alternative to the masked schedule's reduce
    /// rounds: no scatter-reduce, `N·(N−1)` blob crossings, wins at
    /// tiny supports on latency-dominated links. Rounds fold in the
    /// simulator's clock order (fresh-clock bit-exactness, like
    /// [`CostModel::masked_tern_seconds`]); pipeline wrappers delegate
    /// blob spreads to their inner topology.
    fn masked_gather(&self, topo: TopoKind, coords: usize, k: usize, nnz: usize) -> (u64, f64) {
        let base = match topo {
            TopoKind::Pipeline { inner, .. } => inner.kind(),
            t => t,
        };
        let mask_bytes = (coords.div_ceil(8)) as u64;
        let blob = crate::sparse::values_only_bytes(nnz);
        let (mut bytes, mut t) = (0u64, 0.0f64);
        self.base_spread_rounds(base, mask_bytes, k, &mut |b, d| {
            bytes += b;
            t += d;
        });
        self.base_spread_rounds(base, blob, self.nodes, &mut |b, d| {
            bytes += b;
            t += d;
        });
        (bytes, t)
    }

    /// Virtual seconds of the sparse-allgather format under `topo` for
    /// an `nnz`-coordinate shared support and `k` broadcaster masks.
    pub fn masked_gather_seconds(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        nnz: usize,
    ) -> f64 {
        self.masked_gather(topo, coords, k, nnz).1
    }

    /// Total wire bytes of the sparse-allgather format under `topo`.
    pub fn masked_gather_total_bytes(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        nnz: usize,
    ) -> u64 {
        self.masked_gather(topo, coords, k, nnz).0
    }

    /// Total wire bytes of the `+tern` masked stage under `topo`.
    pub fn masked_tern_total_bytes(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        nnz: usize,
    ) -> u64 {
        self.masked_tern(topo, coords, k, nnz).0
    }

    /// One accumulator over the `+q:<bits>` masked stage's round
    /// sequence (DESIGN.md §17): the [`CostModel::masked_tern_seconds`]
    /// shape — spread the `k` broadcaster masks, then spread every
    /// node's [`QBlob`]-encoded compacted payload *whole* — with the
    /// width's closed-form blob size. At `QuantWidth::Q2` the blob size
    /// delegates to `TernBlob::wire_bytes_for`, so the prediction equals
    /// `masked_tern` bit for bit (the engine ships the 2-bit width on
    /// the tern path). Rounds fold in the simulator's clock order
    /// (fresh-clock bit-exactness); pipeline wrappers delegate blob
    /// spreads to their inner topology.
    ///
    /// [`QBlob`]: crate::compress::quant::QBlob
    fn masked_q(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        nnz: usize,
        width: crate::compress::quant::QuantWidth,
    ) -> (u64, f64) {
        let base = match topo {
            TopoKind::Pipeline { inner, .. } => inner.kind(),
            t => t,
        };
        let mask_bytes = (coords.div_ceil(8)) as u64;
        let blob = crate::compress::quant::QBlob::wire_bytes_for(nnz, width);
        let (mut bytes, mut t) = (0u64, 0.0f64);
        self.base_spread_rounds(base, mask_bytes, k, &mut |b, d| {
            bytes += b;
            t += d;
        });
        self.base_spread_rounds(base, blob, self.nodes, &mut |b, d| {
            bytes += b;
            t += d;
        });
        (bytes, t)
    }

    /// Virtual seconds of the `+q:<bits>` masked stage under `topo` for
    /// an `nnz`-coordinate shared support and `k` broadcaster masks.
    pub fn masked_q_seconds(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        nnz: usize,
        width: crate::compress::quant::QuantWidth,
    ) -> f64 {
        self.masked_q(topo, coords, k, nnz, width).1
    }

    /// Total wire bytes of the `+q:<bits>` masked stage under `topo`.
    pub fn masked_q_total_bytes(
        &self,
        topo: TopoKind,
        coords: usize,
        k: usize,
        nnz: usize,
        width: crate::compress::quant::QuantWidth,
    ) -> u64 {
        self.masked_q(topo, coords, k, nnz, width).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::RingNet;
    use crate::ring;
    use crate::sparse::{BitMask, SparseVec};
    use crate::util::rng::Rng;

    fn link() -> LinkSpec {
        LinkSpec::gigabit_ethernet()
    }

    #[test]
    fn dense_prediction_matches_simulation_bit_for_bit() {
        for (n, len) in [(2usize, 100usize), (4, 1000), (7, 12345), (8, 4096)] {
            let model = CostModel::new(n, link());
            let mut net = RingNet::new(n, link(), 1.0);
            let mut bufs = vec![vec![1.0f32; len]; n];
            let rep = ring::dense::allreduce(&mut net, &mut bufs);
            assert_eq!(
                model.dense_seconds(len).to_bits(),
                rep.seconds.to_bits(),
                "n={n} len={len}: {} vs {}",
                model.dense_seconds(len),
                rep.seconds
            );
            assert_eq!(model.dense_total_bytes(len), rep.total_bytes());
        }
    }

    #[test]
    fn masked_prediction_matches_simulation_bit_for_bit() {
        let (n, len) = (6usize, 20_000usize);
        let mut rng = Rng::new(5);
        let mut mask = BitMask::zeros(len);
        for _ in 0..300 {
            mask.set(rng.below(len));
        }
        let values: Vec<Vec<f32>> = (0..n).map(|_| vec![0.5f32; len]).collect();
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        let mut net = RingNet::new(n, link(), 1.0);
        let (shared, _, rep) = ring::masked::allreduce(&mut net, &[&mask], &refs);
        let model = CostModel::new(n, link());
        let predicted = model.masked_seconds(len, 1, shared.count());
        assert_eq!(
            predicted.to_bits(),
            rep.seconds.to_bits(),
            "{predicted} vs {}",
            rep.seconds
        );
        assert_eq!(
            model.masked_total_bytes(len, 1, shared.count()),
            rep.total_bytes()
        );
    }

    #[test]
    fn allgather_prediction_matches_simulation() {
        let n = 5;
        let model = CostModel::new(n, link());
        let mut net = RingNet::new(n, link(), 1.0);
        let blobs = vec![700u64; n];
        let t = net.allgather(&blobs);
        assert_eq!(model.allgather_seconds(700, n).to_bits(), t.to_bits());
        assert_eq!(model.allgather_total_bytes(700, n), net.total_bytes());
    }

    #[test]
    fn sparse_estimate_is_in_the_simulated_ballpark() {
        let (n, len, d0) = (8usize, 40_000usize, 0.01f64);
        let mut rng = Rng::new(2);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| {
                let mut dense = vec![0.0f32; len];
                for v in dense.iter_mut() {
                    if (rng.uniform() as f64) < d0 {
                        *v = rng.normal();
                    }
                }
                SparseVec::from_dense(&dense)
            })
            .collect();
        let mut net = RingNet::new(n, link(), 1.0);
        let (_, rep) = ring::sparse::allreduce(&mut net, &inputs);
        let est = CostModel::new(n, link()).sparse_seconds_estimate(len, d0);
        assert!(
            est > rep.seconds * 0.4 && est < rep.seconds * 2.5,
            "estimate {est} vs simulated {}",
            rep.seconds
        );
    }

    #[test]
    fn pipelined_masked_prediction_matches_simulation_bit_for_bit() {
        use crate::net::topo::{pipeline, PipeInner, PipelineRing, Topology};
        use crate::ring::Arena;
        let (n, len) = (5usize, 6000usize);
        let mut rng = Rng::new(77);
        let mut mask = BitMask::zeros(len);
        for _ in 0..250 {
            mask.set(rng.below(len));
        }
        let model = CostModel::new(n, link());
        for inner in [PipeInner::Flat, PipeInner::Hier { group: 2 }, PipeInner::Tree] {
            for chunks in [1usize, 3, 8] {
                let pipe = PipelineRing::new(n, chunks, inner);
                let mut nw = RingNet::new(n, link(), 1.0);
                let (shared, rep) =
                    pipe.masked_bytes_only(&mut nw, &[&mask], &mut Arena::for_nodes(n));
                let sups = pipeline::chunk_supports(&shared, chunks);
                let predicted =
                    model.pipelined_masked_seconds(inner.kind(), chunks, len, 1, &sups);
                assert_eq!(
                    predicted.to_bits(),
                    rep.seconds.to_bits(),
                    "inner={inner:?} chunks={chunks}: {predicted} vs {}",
                    rep.seconds
                );
                assert_eq!(
                    model.pipelined_masked_total_bytes(inner.kind(), chunks, len, 1, &sups),
                    rep.total_bytes(),
                    "inner={inner:?} chunks={chunks}: bytes"
                );
            }
        }
    }

    #[test]
    fn pipelined_dense_prediction_matches_simulation_bit_for_bit() {
        use crate::net::topo::{PipeInner, PipelineRing, Topology};
        use crate::ring::Arena;
        let (n, len) = (6usize, 4321usize);
        let model = CostModel::new(n, link());
        for inner in [PipeInner::Flat, PipeInner::Hier { group: 4 }, PipeInner::Tree] {
            for chunks in [1usize, 4] {
                let kind = TopoKind::Pipeline { chunks, inner };
                let pipe = PipelineRing::new(n, chunks, inner);
                let mut nw = RingNet::new(n, link(), 1.0);
                let rep = pipe.dense_bytes_only(&mut nw, len, &mut Arena::for_nodes(n));
                assert_eq!(model.topo_dense_total_bytes(kind, len), rep.total_bytes());
                assert_eq!(
                    model.topo_dense_seconds(kind, len).to_bits(),
                    rep.seconds.to_bits(),
                    "inner={inner:?} chunks={chunks}"
                );
            }
        }
    }

    #[test]
    fn pipelining_lowers_masked_makespan_on_paper_inventories() {
        // The headline claim of the pipelined wrapper (ISSUE 4
        // acceptance): on the AlexNet / ResNet50 inventories at the
        // paper's ~1% masked density, overlapping per-chunk selection
        // prep with the previous chunk's wire rounds beats the
        // phase-serialized reference (`pipeline:1`, same prep
        // accounting) — the hidden prep outweighs the added round
        // latency at these payload sizes.
        use crate::model::zoo;
        let model = CostModel::new(8, link());
        for (name, coords) in [
            ("alexnet", zoo::alexnet().total_params()),
            ("resnet50", zoo::resnet50().total_params()),
        ] {
            let support = coords / 100;
            let serial =
                model.pipelined_masked_seconds(TopoKind::Flat, 1, coords, 3, &[support]);
            for chunks in [2usize, 4, 8] {
                // Even support split (any split works; only the per-chunk
                // dense round sizes depend on it).
                let sups: Vec<usize> = (0..chunks)
                    .map(|ci| support / chunks + usize::from(ci < support % chunks))
                    .collect();
                let piped =
                    model.pipelined_masked_seconds(TopoKind::Flat, chunks, coords, 3, &sups);
                assert!(
                    piped < serial,
                    "{name} chunks={chunks}: pipelined {piped} should beat serial {serial}"
                );
            }
        }
    }

    #[test]
    fn masked_tern_composes_two_spreads() {
        // The `+tern` stage's byte total is exactly the mask spread plus
        // the whole-blob spread, on every base topology (times are
        // accumulated on one clock, so they are checked against the
        // engine in `tests/compressor_equivalence.rs` instead).
        let n = 6;
        let model = CostModel::new(n, link());
        let (coords, k, nnz) = (10_000usize, 2usize, 300usize);
        let mask_bytes = (coords.div_ceil(8)) as u64;
        let blob = crate::compress::terngrad::TernBlob::wire_bytes_for(nnz);
        for topo in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            assert_eq!(
                model.masked_tern_total_bytes(topo, coords, k, nnz),
                model.topo_spread_total_bytes(topo, mask_bytes, k)
                    + model.topo_spread_total_bytes(topo, blob, n),
                "{topo:?}"
            );
        }
    }

    #[test]
    fn masked_q_composes_two_spreads() {
        // Every `+q:<bits>` width prices as exactly the mask spread plus
        // the whole-QBlob spread; the Q2 special case must equal
        // `masked_tern` bit for bit (the engine ships that width on the
        // tern path), and pipeline wrappers delegate to their inner
        // topology as everywhere else.
        use crate::compress::quant::{QBlob, QuantWidth};
        let n = 6;
        let model = CostModel::new(n, link());
        let (coords, k, nnz) = (10_000usize, 2usize, 300usize);
        let mask_bytes = (coords.div_ceil(8)) as u64;
        for width in QuantWidth::ALL {
            let blob = QBlob::wire_bytes_for(nnz, width);
            for topo in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
                assert_eq!(
                    model.masked_q_total_bytes(topo, coords, k, nnz, width),
                    model.topo_spread_total_bytes(topo, mask_bytes, k)
                        + model.topo_spread_total_bytes(topo, blob, n),
                    "{width} {topo:?}"
                );
            }
        }
        for topo in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            assert_eq!(
                model.masked_q_total_bytes(topo, coords, k, nnz, QuantWidth::Q2),
                model.masked_tern_total_bytes(topo, coords, k, nnz),
                "q:2 bytes must equal +tern on {topo:?}"
            );
            assert_eq!(
                model.masked_q_seconds(topo, coords, k, nnz, QuantWidth::Q2).to_bits(),
                model.masked_tern_seconds(topo, coords, k, nnz).to_bits(),
                "q:2 seconds must equal +tern on {topo:?}"
            );
        }
        assert_eq!(
            model
                .masked_q_seconds(
                    TopoKind::Pipeline { chunks: 4, inner: crate::net::PipeInner::Tree },
                    coords,
                    k,
                    nnz,
                    QuantWidth::Q8
                )
                .to_bits(),
            model.masked_q_seconds(TopoKind::Tree, coords, k, nnz, QuantWidth::Q8).to_bits(),
            "pipeline wrappers delegate quant spreads to the inner topology"
        );
    }

    #[test]
    fn masked_gather_composes_two_spreads() {
        // The gather format's byte total is exactly the mask spread plus
        // the whole-values spread (4·nnz per node), on every base
        // topology — mirroring `masked_tern_composes_two_spreads`.
        let n = 6;
        let model = CostModel::new(n, link());
        let (coords, k, nnz) = (10_000usize, 2usize, 300usize);
        let mask_bytes = (coords.div_ceil(8)) as u64;
        let blob = crate::sparse::values_only_bytes(nnz);
        for topo in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            assert_eq!(
                model.masked_gather_total_bytes(topo, coords, k, nnz),
                model.topo_spread_total_bytes(topo, mask_bytes, k)
                    + model.topo_spread_total_bytes(topo, blob, n),
                "{topo:?}"
            );
            assert_eq!(
                model
                    .masked_gather_seconds(
                        TopoKind::Pipeline {
                            chunks: 4,
                            inner: crate::net::PipeInner::Tree
                        },
                        coords,
                        k,
                        nnz
                    )
                    .to_bits(),
                model.masked_gather_seconds(TopoKind::Tree, coords, k, nnz).to_bits(),
                "pipeline wrappers delegate gather spreads to the inner topology"
            );
        }
    }

    #[test]
    fn uniform_link_table_prices_bit_identical_to_global_link() {
        // The per-hop seam must be free when unused: a uniform table
        // equal to the base link reproduces every prediction bit for
        // bit (mirrors RingNet's uniform-table contract).
        let n = 6;
        let plain = CostModel::new(n, link());
        let mut tabled = CostModel::new(n, link());
        tabled.set_links(vec![link(); n]);
        let coords = 12_345;
        for topo in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            assert_eq!(
                plain.topo_dense_seconds(topo, coords).to_bits(),
                tabled.topo_dense_seconds(topo, coords).to_bits(),
                "{topo:?} dense"
            );
            assert_eq!(
                plain.topo_masked_seconds(topo, coords, 2, 400).to_bits(),
                tabled.topo_masked_seconds(topo, coords, 2, 400).to_bits(),
                "{topo:?} masked"
            );
            assert_eq!(
                plain.masked_gather_seconds(topo, coords, 2, 400).to_bits(),
                tabled.masked_gather_seconds(topo, coords, 2, 400).to_bits(),
                "{topo:?} gather"
            );
        }
    }

    #[test]
    fn straggler_hop_slows_every_prediction() {
        // One degraded hop paces every synchronous round: all schedule
        // predictions move up, none stay flat.
        let n = 6;
        let base = CostModel::new(n, link());
        let mut slow = CostModel::new(n, link());
        let mut ls = vec![link(); n];
        ls[2] = LinkSpec::new(link().bandwidth_bps / 8.0, link().latency_s);
        slow.set_links(ls);
        let coords = 40_000;
        for topo in [TopoKind::Flat, TopoKind::Hier { group: 3 }, TopoKind::Tree] {
            assert!(
                slow.topo_dense_seconds(topo, coords) > base.topo_dense_seconds(topo, coords),
                "{topo:?} dense"
            );
            assert!(
                slow.topo_masked_seconds(topo, coords, 2, 500)
                    > base.topo_masked_seconds(topo, coords, 2, 500),
                "{topo:?} masked"
            );
        }
        assert!(
            slow.pipelined_masked_seconds(TopoKind::Flat, 4, coords, 2, &[125, 125, 125, 125])
                > base.pipelined_masked_seconds(TopoKind::Flat, 4, coords, 2, &[125, 125, 125, 125])
        );
    }

    #[test]
    fn model_scales_with_link_and_ring() {
        let slow = CostModel::new(8, LinkSpec::new(1e6, 0.0));
        let fast = CostModel::new(8, LinkSpec::new(1e9, 0.0));
        assert!(slow.dense_seconds(10_000) > fast.dense_seconds(10_000) * 100.0);
        let small = CostModel::new(4, link());
        let big = CostModel::new(96, link());
        // Per-node dense cost is near-constant in N (the ring property).
        let per_node_small = small.dense_bytes_per_node(1_000_000);
        let per_node_big = big.dense_bytes_per_node(1_000_000);
        assert!((per_node_small / per_node_big - 1.0).abs() < 0.35);
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn rejects_degenerate_ring() {
        let _ = CostModel::new(1, link());
    }
}
