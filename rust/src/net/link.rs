//! Link bandwidth/latency model.

/// Homogeneous link parameters.
#[derive(Debug, Clone, Copy)]
pub struct LinkSpec {
    /// Usable bandwidth in bytes per second.
    pub bandwidth_bps: f64,
    /// Per-transfer latency in seconds (propagation + stack overhead).
    pub latency_s: f64,
}

impl LinkSpec {
    /// Link with the given bandwidth (bytes/s, > 0) and latency (s, ≥ 0).
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0 && latency_s >= 0.0);
        LinkSpec {
            bandwidth_bps,
            latency_s,
        }
    }

    /// The paper's testbed: gigabit Ethernet, no Infiniband.
    /// ~117 MiB/s usable (protocol overhead off 125 MB/s line rate) and
    /// 100 µs software latency.
    pub fn gigabit_ethernet() -> Self {
        LinkSpec::new(117.0 * 1024.0 * 1024.0, 100e-6)
    }

    /// 10-gigabit variant for scaling sweeps.
    pub fn ten_gigabit() -> Self {
        LinkSpec::new(1170.0 * 1024.0 * 1024.0, 50e-6)
    }

    /// Time to move `bytes` across this link. Zero-byte transfers are
    /// free (no message sent).
    #[inline]
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency_s + bytes as f64 / self.bandwidth_bps
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_linear_in_bytes() {
        let l = LinkSpec::new(1000.0, 0.1);
        assert!((l.transfer_time(1000) - 1.1).abs() < 1e-12);
        assert!((l.transfer_time(2000) - 2.1).abs() < 1e-12);
    }

    #[test]
    fn zero_bytes_is_free() {
        let l = LinkSpec::new(1000.0, 0.1);
        assert_eq!(l.transfer_time(0), 0.0);
    }

    #[test]
    fn gigabit_sanity() {
        let g = LinkSpec::gigabit_ethernet();
        // 117 MiB should take ~1 s.
        let t = g.transfer_time(117 * 1024 * 1024);
        assert!((t - 1.0001).abs() < 1e-3, "{t}");
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bandwidth() {
        let _ = LinkSpec::new(0.0, 0.0);
    }
}
