//! The flat single-ring topology — the paper's testbed (DESIGN.md §10).
//!
//! [`FlatRing`] is a thin shim over the original `ring::{dense, sparse,
//! masked}` arena entry points: every method delegates verbatim, so the
//! flat topology is **bit-identical to the pre-refactor behaviour** by
//! construction (the golden-reference tests in
//! `rust/tests/parallel_equivalence.rs` keep pinning those entry points
//! directly, and `rust/tests/topology_equivalence.rs` pins this shim to
//! them).

use super::{TopoKind, Topology};
use crate::net::RingNet;
use crate::ring::{self, Arena, Executor, ReduceReport};
use crate::sparse::{BitMask, SparseVec};

/// Single unidirectional ring over all N nodes: node `i` sends to
/// `(i+1) % N` in every round (DESIGN.md §3, §10).
#[derive(Debug, Clone, Copy)]
pub struct FlatRing {
    n: usize,
}

impl FlatRing {
    /// A flat ring over `n >= 2` nodes.
    pub fn new(n: usize) -> Self {
        assert!(n >= 2, "a ring needs at least 2 nodes");
        FlatRing { n }
    }
}

impl Topology for FlatRing {
    fn kind(&self) -> TopoKind {
        TopoKind::Flat
    }

    fn nodes(&self) -> usize {
        self.n
    }

    fn reduce_hops(&self) -> usize {
        self.n - 1
    }

    fn dense(
        &self,
        net: &mut RingNet,
        bufs: &mut [Vec<f32>],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.n);
        ring::dense::allreduce_in(net, bufs, exec, arena)
    }

    fn dense_bytes_only(
        &self,
        net: &mut RingNet,
        coords: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.n);
        let before = snapshot(net);
        let t0 = net.clock();
        ring::dense::rounds_bytes_only(net, coords, arena);
        report(net, &before, t0, Vec::new())
    }

    fn sparse(
        &self,
        net: &mut RingNet,
        inputs: &[SparseVec],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (Vec<f32>, ReduceReport) {
        assert_eq!(net.n_nodes(), self.n);
        ring::sparse::allreduce_in(net, inputs, exec, arena)
    }

    fn sparse_support(
        &self,
        net: &mut RingNet,
        supports: &[BitMask],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.n);
        ring::sparse::allreduce_support_in(net, supports, exec, arena)
    }

    fn masked(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        values: &[&[f32]],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (BitMask, Vec<f32>, ReduceReport) {
        assert_eq!(net.n_nodes(), self.n);
        ring::masked::allreduce_in(net, masks, values, exec, arena)
    }

    fn masked_bytes_only(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        arena: &mut Arena,
    ) -> (BitMask, ReduceReport) {
        assert_eq!(net.n_nodes(), self.n);
        ring::masked::allreduce_bytes_only_in(net, masks, arena)
    }

    fn spread_bytes(
        &self,
        net: &mut RingNet,
        blob_bytes: u64,
        k: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.n);
        let n = self.n;
        let k = k.min(n);
        let before = snapshot(net);
        let t0 = net.clock();
        {
            let Arena {
                grows,
                mk_blobs,
                ag_sends,
                ..
            } = arena;
            let blobs = (0..n).map(|i| if i < k { blob_bytes } else { 0 });
            Arena::allgather_into(net, grows, mk_blobs, ag_sends, blobs);
        }
        report(net, &before, t0, Vec::new())
    }
}

/// Shared "delta since snapshot" report assembly for the accounting-only
/// topology paths (the exact paths build theirs inline, like the ring
/// schedules always have).
pub(super) fn report(
    net: &RingNet,
    before: &[u64],
    t0: f64,
    density_per_hop: Vec<f64>,
) -> ReduceReport {
    ReduceReport {
        bytes_per_node: (0..net.n_nodes())
            .map(|i| net.node_tx_bytes(i) - before[i])
            .collect(),
        seconds: net.clock() - t0,
        density_per_hop,
    }
}

/// Per-node tx snapshot taken before a schedule starts.
pub(super) fn snapshot(net: &RingNet) -> Vec<u64> {
    (0..net.n_nodes()).map(|i| net.node_tx_bytes(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::gigabit_ethernet(), 1.0)
    }

    #[test]
    fn flat_dense_delegates_bit_for_bit() {
        let n = 5;
        let len = 777;
        let mut rng = crate::util::rng::Rng::new(3);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let exec = Executor::sequential();
        let mut net_a = net(n);
        let mut bufs_a = base.clone();
        let rep_a = ring::dense::allreduce(&mut net_a, &mut bufs_a);
        let topo = FlatRing::new(n);
        let mut net_b = net(n);
        let mut bufs_b = base;
        let rep_b = topo.dense(&mut net_b, &mut bufs_b, &exec, &mut Arena::for_nodes(n));
        assert_eq!(rep_a.bytes_per_node, rep_b.bytes_per_node);
        assert_eq!(rep_a.seconds.to_bits(), rep_b.seconds.to_bits());
        for (a, b) in bufs_a.iter().zip(&bufs_b) {
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn flat_spread_matches_ring_allgather() {
        let n = 6;
        let blob = 1234u64;
        let mut net_a = net(n);
        let t_a = net_a.allgather(&[blob, blob, blob, 0, 0, 0]);
        let topo = FlatRing::new(n);
        let mut net_b = net(n);
        let rep = topo.spread_bytes(&mut net_b, blob, 3, &mut Arena::for_nodes(n));
        assert_eq!(net_a.total_bytes(), rep.total_bytes());
        assert_eq!(t_a.to_bits(), rep.seconds.to_bits());
    }

    #[test]
    fn flat_dense_bytes_only_reports_delta() {
        let n = 4;
        let len = 1000;
        let topo = FlatRing::new(n);
        let mut nw = net(n);
        let rep = topo.dense_bytes_only(&mut nw, len, &mut Arena::for_nodes(n));
        assert_eq!(rep.total_bytes(), 2 * (n as u64 - 1) * (len as u64) * 4);
        assert_eq!(rep.seconds.to_bits(), nw.clock().to_bits());
    }
}
