//! Binomial-tree allreduce topology (DESIGN.md §10).
//!
//! The dense baseline DGC-style schemes assume: a reduce up a binomial
//! tree rooted at node 0 followed by a broadcast back down. With
//! `R = ceil(log2 N)` rounds each way the wall-clock is logarithmic in
//! N, but every travelling payload is the **full** vector — per-link
//! bytes do not shrink with N the way the ring's chunked rotation does,
//! which is exactly the trade the cross-topology sweeps measure:
//!
//! ```text
//! round r (reduce):    i  ──full payload──▶  i - 2^r      for every i
//!                      with i ≡ 2^r (mod 2^(r+1))
//! round r (broadcast): j  ──full payload──▶  j + 2^r      for every j
//!                      with j ≡ 0 (mod 2^(r+1)), j + 2^r < N
//! ```
//!
//! For sparse payloads the accumulated vector *densifies up the tree*
//! (each merge unions two subtrees' supports), giving DGC-style
//! schemes a different densification trajectory than the ring —
//! `ReduceReport::density_per_hop` records the mean density of the
//! live accumulators after each reduce round. The net-free
//! [`dense_plan`] / [`spread_plan`] round generators are shared with
//! `net::cost::CostModel` for bit-exact prediction (DESIGN.md §10).

use std::sync::atomic::AtomicU64;

use super::flat::{report, snapshot};
use super::{ceil_log2, compact_to_support, or_masks, TopoKind, Topology};
use crate::net::RingNet;
use crate::ring::{Arena, Executor, ReduceReport};
use crate::sparse::{wire_bytes, BitMask, SparseVec, WireFormat};

/// Binomial-tree reduce + broadcast rooted at node 0 (DESIGN.md §10).
#[derive(Debug, Clone, Copy)]
pub struct TreeAllreduce {
    n: usize,
    rounds: usize,
}

impl TreeAllreduce {
    /// A binomial tree over `n >= 2` nodes.
    pub fn new(n: usize) -> Self {
        assert!(n >= 2, "a topology needs at least 2 nodes");
        TreeAllreduce {
            n,
            rounds: ceil_log2(n),
        }
    }

    /// Is `i` a sender in reduce round `r`?
    #[inline]
    fn up_sender(i: usize, r: usize) -> bool {
        i % (2 << r) == (1 << r)
    }

    /// Is `i` a receiver in reduce round `r` (its partner `i + 2^r`
    /// exists)?
    #[inline]
    fn up_receiver(i: usize, r: usize, n: usize) -> bool {
        i % (2 << r) == 0 && i + (1 << r) < n
    }

    /// Is `i` a sender in broadcast round `r`?
    #[inline]
    fn down_sender(i: usize, r: usize, n: usize) -> bool {
        i % (2 << r) == 0 && i + (1 << r) < n
    }
}

impl Topology for TreeAllreduce {
    fn kind(&self) -> TopoKind {
        TopoKind::Tree
    }

    fn nodes(&self) -> usize {
        self.n
    }

    fn reduce_hops(&self) -> usize {
        self.rounds
    }

    fn dense(
        &self,
        net: &mut RingNet,
        bufs: &mut [Vec<f32>],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        let Arena {
            grows,
            dense_staging,
            dense_sends,
            ..
        } = arena;
        dense_core(net, self.n, self.rounds, bufs, exec, grows, dense_staging, dense_sends)
    }

    fn dense_bytes_only(
        &self,
        net: &mut RingNet,
        coords: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.n);
        let Arena {
            grows, dense_sends, ..
        } = arena;
        let before = snapshot(net);
        let t0 = net.clock();
        let cap = dense_sends.capacity();
        dense_plan(self.n, coords, dense_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, dense_sends.capacity() != cap);
        report(net, &before, t0, Vec::new())
    }

    fn sparse(
        &self,
        net: &mut RingNet,
        inputs: &[SparseVec],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (Vec<f32>, ReduceReport) {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert_eq!(inputs.len(), n);
        let len = inputs[0].len;
        assert!(inputs.iter().all(|s| s.len == len));

        let Arena {
            grows,
            sp_held,
            sp_next,
            sp_sends,
            ..
        } = arena;
        let grows: &AtomicU64 = grows;
        Arena::slots(grows, sp_held, n, || SparseVec::empty(0));
        Arena::slots(grows, sp_next, n, || SparseVec::empty(0));

        let before = snapshot(net);
        let t0 = net.clock();
        let mut density_per_hop = Vec::with_capacity(self.rounds);

        // Reduce: accumulated sparse vectors merge (union + add) up the
        // tree; the sender's payload is its whole accumulated subtree.
        exec.map_mut(&mut sp_held[..n], |i, h| {
            Arena::note(grows, h.assign_window(&inputs[i], &(0..len)));
        });
        let (mut held, mut next) = (sp_held, sp_next);
        for r in 0..self.rounds {
            Arena::refill(
                grows,
                sp_sends,
                (0..n).map(|i| {
                    if Self::up_sender(i, r) {
                        held[i].wire_bytes()
                    } else {
                        0
                    }
                }),
            );
            net.round(sp_sends);
            {
                let held_ref: &[SparseVec] = held;
                exec.map_mut(&mut next[..n], |i, nx| {
                    if Self::up_receiver(i, r, n) {
                        let src = i + (1 << r);
                        Arena::note(grows, held_ref[src].merge_add_into(&held_ref[i], nx));
                    } else if Self::up_sender(i, r) {
                        nx.clear_to(len); // payload delivered upward
                    } else {
                        let hlen = held_ref[i].len;
                        Arena::note(grows, nx.assign_window(&held_ref[i], &(0..hlen)));
                    }
                });
            }
            std::mem::swap(&mut held, &mut next);
            // Mean density of the live accumulators (nodes still holding
            // a partial: indices ≡ 0 mod 2^(r+1)).
            let (mut dsum, mut live) = (0.0f64, 0usize);
            for i in (0..n).filter(|i| i % (2 << r) == 0) {
                dsum += held[i].density();
                live += 1;
            }
            density_per_hop.push(dsum / live.max(1) as f64);
        }

        // Broadcast accounting: the root's full reduced sparse vector
        // travels back down the tree.
        let result = held[0].to_dense();
        let root_bytes = held[0].wire_bytes();
        for r in (0..self.rounds).rev() {
            Arena::refill(
                grows,
                sp_sends,
                (0..n).map(|i| {
                    if Self::down_sender(i, r, n) {
                        root_bytes
                    } else {
                        0
                    }
                }),
            );
            net.round(sp_sends);
        }

        (result, report(net, &before, t0, density_per_hop))
    }

    fn sparse_support(
        &self,
        net: &mut RingNet,
        supports: &[BitMask],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert_eq!(supports.len(), n);
        let len = supports[0].len();
        assert!(supports.iter().all(|s| s.len() == len));

        let Arena {
            grows,
            su_held,
            su_next,
            su_sends,
            ..
        } = arena;
        let grows: &AtomicU64 = grows;
        Arena::slots(grows, su_held, n, Vec::new);
        Arena::slots(grows, su_next, n, Vec::new);

        let before = snapshot(net);
        let t0 = net.clock();
        let mut density_per_hop = Vec::with_capacity(self.rounds);
        let seg_bytes = |words: &[u64]| -> u64 {
            let nnz = BitMask::popcount_words(words);
            wire_bytes(WireFormat::cheapest(len, nnz), len, nnz)
        };

        exec.map_mut(&mut su_held[..n], |i, h| {
            Arena::note(
                grows,
                Arena::refill_slice(h, supports[i].word_slice(0..len)),
            );
        });
        let (mut held, mut next) = (su_held, su_next);
        for r in 0..self.rounds {
            Arena::refill(
                grows,
                su_sends,
                (0..n).map(|i| {
                    if Self::up_sender(i, r) {
                        seg_bytes(&held[i])
                    } else {
                        0
                    }
                }),
            );
            net.round(su_sends);
            {
                let held_ref: &[Vec<u64>] = held;
                exec.map_mut(&mut next[..n], |i, nx| {
                    if Self::up_receiver(i, r, n) {
                        let src = i + (1 << r);
                        Arena::note(grows, Arena::refill_slice(nx, &held_ref[i]));
                        for (w, o) in nx.iter_mut().zip(&held_ref[src]) {
                            *w |= o;
                        }
                    } else if Self::up_sender(i, r) {
                        nx.clear();
                    } else {
                        Arena::note(grows, Arena::refill_slice(nx, &held_ref[i]));
                    }
                });
            }
            std::mem::swap(&mut held, &mut next);
            let (mut nnz, mut live) = (0usize, 0usize);
            for i in (0..n).filter(|i| i % (2 << r) == 0) {
                nnz += BitMask::popcount_words(&held[i]);
                live += 1;
            }
            density_per_hop.push(nnz as f64 / (live * len).max(1) as f64);
        }

        let root_bytes = seg_bytes(&held[0]);
        for r in (0..self.rounds).rev() {
            Arena::refill(
                grows,
                su_sends,
                (0..n).map(|i| {
                    if Self::down_sender(i, r, n) {
                        root_bytes
                    } else {
                        0
                    }
                }),
            );
            net.round(su_sends);
        }

        report(net, &before, t0, density_per_hop)
    }

    fn masked(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        values: &[&[f32]],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (BitMask, Vec<f32>, ReduceReport) {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert_eq!(values.len(), n);
        assert!(!masks.is_empty(), "need at least one mask broadcaster");
        let len = masks[0].len();
        assert!(values.iter().all(|v| v.len() == len));

        let mask_bytes = masks[0].wire_bytes();
        let k = masks.len().min(n);
        let before = snapshot(net);
        let t0 = net.clock();

        {
            let Arena {
                grows, ag_sends, ..
            } = &mut *arena;
            let cap = ag_sends.capacity();
            spread_plan(n, mask_bytes, k, ag_sends, |s| {
                net.round(s);
            });
            Arena::note(grows, ag_sends.capacity() != cap);
        }
        let shared = or_masks(masks, len);

        let Arena {
            grows,
            mk_support,
            mk_compact,
            dense_staging,
            dense_sends,
            ..
        } = arena;
        let grows: &AtomicU64 = grows;
        compact_to_support(&shared, values, exec, grows, mk_support, mk_compact);
        dense_core(
            net,
            n,
            self.rounds,
            &mut mk_compact[..n],
            exec,
            grows,
            dense_staging,
            dense_sends,
        );

        let rep = report(
            net,
            &before,
            t0,
            vec![shared.density(); self.rounds],
        );
        (shared, mk_compact[0].clone(), rep)
    }

    fn masked_bytes_only(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        arena: &mut Arena,
    ) -> (BitMask, ReduceReport) {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert!(!masks.is_empty());
        let len = masks[0].len();
        let mask_bytes = masks[0].wire_bytes();
        let k = masks.len().min(n);
        let before = snapshot(net);
        let t0 = net.clock();
        let Arena {
            grows,
            ag_sends,
            dense_sends,
            ..
        } = arena;
        let cap = ag_sends.capacity();
        spread_plan(n, mask_bytes, k, ag_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, ag_sends.capacity() != cap);
        let shared = or_masks(masks, len);
        let cap = dense_sends.capacity();
        dense_plan(n, shared.count(), dense_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, dense_sends.capacity() != cap);
        let rep = report(
            net,
            &before,
            t0,
            vec![shared.density(); self.rounds],
        );
        (shared, rep)
    }

    fn spread_bytes(
        &self,
        net: &mut RingNet,
        blob_bytes: u64,
        k: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        let Arena {
            grows, ag_sends, ..
        } = arena;
        let before = snapshot(net);
        let t0 = net.clock();
        let cap = ag_sends.capacity();
        spread_plan(n, blob_bytes, k, ag_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, ag_sends.capacity() != cap);
        report(net, &before, t0, Vec::new())
    }
}

/// The exact binomial dense schedule over explicit scratch parts (the
/// masked schedule runs it on compacted values while holding its own
/// arena fields).
#[allow(clippy::too_many_arguments)]
fn dense_core(
    net: &mut RingNet,
    n: usize,
    rounds: usize,
    bufs: &mut [Vec<f32>],
    exec: &Executor,
    grows: &AtomicU64,
    staging: &mut Vec<Vec<f32>>,
    sends: &mut Vec<u64>,
) -> ReduceReport {
    assert_eq!(net.n_nodes(), n);
    assert_eq!(bufs.len(), n, "one buffer per node");
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    if len == 0 {
        return ReduceReport {
            bytes_per_node: vec![0; n],
            ..Default::default()
        };
    }
    Arena::slots(grows, staging, n, Vec::new);
    let before = snapshot(net);
    let t0 = net.clock();
    let payload = (len * 4) as u64;

    // Reduce up the tree: each sender ships its full accumulated buffer.
    for r in 0..rounds {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                if TreeAllreduce::up_sender(i, r) {
                    payload
                } else {
                    0
                }
            }),
        );
        net.round(sends);
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                if TreeAllreduce::up_sender(i, r) {
                    Arena::note(grows, Arena::refill_slice(stage, &bufs_src[i][..]));
                }
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            if TreeAllreduce::up_receiver(dst, r, n) {
                let src = dst + (1 << r);
                for (b, s) in buf.iter_mut().zip(&staged[src]) {
                    *b += s;
                }
            }
        });
    }

    // Broadcast the root's fully reduced buffer back down.
    for r in (0..rounds).rev() {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                if TreeAllreduce::down_sender(i, r, n) {
                    payload
                } else {
                    0
                }
            }),
        );
        net.round(sends);
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                if TreeAllreduce::down_sender(i, r, n) {
                    Arena::note(grows, Arena::refill_slice(stage, &bufs_src[i][..]));
                }
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            let s1 = 1usize << r;
            if dst % (2 << r) == s1 {
                buf.copy_from_slice(&staged[dst - s1]);
            }
        });
    }

    ReduceReport {
        bytes_per_node: (0..n)
            .map(|i| net.node_tx_bytes(i) - before[i])
            .collect(),
        seconds: net.clock() - t0,
        density_per_hop: Vec::new(),
    }
}

/// Net-free round plan of the binomial dense schedule (shared with
/// `CostModel::topo_dense_*` — DESIGN.md §10). Emits nothing for
/// `len == 0`, matching the exact path's early return.
pub(crate) fn dense_plan(
    n: usize,
    len: usize,
    sends: &mut Vec<u64>,
    mut round: impl FnMut(&[u64]),
) {
    if len == 0 {
        return;
    }
    let rounds = ceil_log2(n);
    let payload = (len * 4) as u64;
    for r in 0..rounds {
        sends.clear();
        sends.extend((0..n).map(|i| {
            if TreeAllreduce::up_sender(i, r) {
                payload
            } else {
                0
            }
        }));
        round(sends);
    }
    for r in (0..rounds).rev() {
        sends.clear();
        sends.extend((0..n).map(|i| {
            if TreeAllreduce::down_sender(i, r, n) {
                payload
            } else {
                0
            }
        }));
        round(sends);
    }
}

/// Net-free round plan of the binomial blob spread: nodes `0..k` hold
/// one `blob`-byte blob each; gather to the root (payload = the blobs
/// of the sender's subtree `[i, i + 2^r)`), then broadcast the full set
/// down.
pub(crate) fn spread_plan(
    n: usize,
    blob: u64,
    k: usize,
    sends: &mut Vec<u64>,
    mut round: impl FnMut(&[u64]),
) {
    let rounds = ceil_log2(n);
    let k = k.min(n);
    let total = blob * k as u64;
    for r in 0..rounds {
        let s1 = 1usize << r;
        sends.clear();
        sends.extend((0..n).map(|i| {
            if TreeAllreduce::up_sender(i, r) {
                blob * ((i + s1).min(k).saturating_sub(i)) as u64
            } else {
                0
            }
        }));
        round(sends);
    }
    for r in (0..rounds).rev() {
        sends.clear();
        sends.extend((0..n).map(|i| {
            if TreeAllreduce::down_sender(i, r, n) {
                total
            } else {
                0
            }
        }));
        round(sends);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    #[test]
    fn dense_reduces_to_sum() {
        for n in [2usize, 3, 5, 8, 9] {
            let len = 23;
            let base: Vec<Vec<f32>> = (0..n)
                .map(|i| (0..len).map(|j| (i * len + j) as f32).collect())
                .collect();
            let mut expect = vec![0.0f32; len];
            for b in &base {
                for (e, &v) in expect.iter_mut().zip(b) {
                    *e += v;
                }
            }
            let topo = TreeAllreduce::new(n);
            let mut nw = net(n);
            let mut bufs = base;
            topo.dense(
                &mut nw,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            for (node, b) in bufs.iter().enumerate() {
                assert_eq!(b, &expect, "n={n} node={node}");
            }
        }
    }

    #[test]
    fn dense_round_count_is_logarithmic() {
        let (n, len) = (8usize, 100usize);
        let topo = TreeAllreduce::new(n);
        let mut nw = net(n);
        let mut bufs = vec![vec![1.0f32; len]; n];
        topo.dense(
            &mut nw,
            &mut bufs,
            &Executor::sequential(),
            &mut Arena::for_nodes(n),
        );
        assert_eq!(nw.rounds(), 2 * 3); // 2 * ceil(log2 8)
        // Total bytes: every non-root sends the payload up once, and
        // every non-root receives it once on the way down.
        assert_eq!(nw.total_bytes(), 2 * (n as u64 - 1) * (len as u64 * 4));
    }

    #[test]
    fn dense_bytes_only_matches_exact_accounting() {
        for (n, len) in [(5usize, 77usize), (8, 1000), (2, 3)] {
            let topo = TreeAllreduce::new(n);
            let mut net_a = net(n);
            let mut bufs = vec![vec![1.0f32; len]; n];
            let rep = topo.dense(
                &mut net_a,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_b = net(n);
            let rep_b = topo.dense_bytes_only(&mut net_b, len, &mut Arena::for_nodes(n));
            assert_eq!(rep.bytes_per_node, rep_b.bytes_per_node, "n={n}");
            assert_eq!(rep.seconds.to_bits(), rep_b.seconds.to_bits());
        }
    }

    #[test]
    fn sparse_densifies_up_the_tree() {
        let (n, len) = (8usize, 4000usize);
        let mut rng = crate::util::rng::Rng::new(9);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| {
                let mut dense = vec![0.0f32; len];
                for _ in 0..40 {
                    dense[rng.below(len)] = 1.0;
                }
                SparseVec::from_dense(&dense)
            })
            .collect();
        let topo = TreeAllreduce::new(n);
        let mut nw = net(n);
        let (result, rep) = topo.sparse(
            &mut nw,
            &inputs,
            &Executor::sequential(),
            &mut Arena::for_nodes(n),
        );
        assert_eq!(rep.density_per_hop.len(), 3);
        assert!(
            rep.density_per_hop[2] > rep.density_per_hop[0],
            "{:?}",
            rep.density_per_hop
        );
        let mut expect = vec![0.0f32; len];
        for s in &inputs {
            s.scatter_add(&mut expect);
        }
        assert_eq!(result, expect);
    }

    #[test]
    fn spread_bytes_gather_and_broadcast() {
        // n=4, blob=10, k=4: up r0 senders 1,3 send 10 each; up r1
        // sender 2 sends 20; down r1: 0 sends 40; down r0: 0,2 send 40.
        let topo = TreeAllreduce::new(4);
        let mut nw = net(4);
        let rep = topo.spread_bytes(&mut nw, 10, 4, &mut Arena::for_nodes(4));
        assert_eq!(rep.total_bytes(), 10 + 10 + 20 + 40 + 80);
    }
}
