//! Hierarchical (two-level) ring topology — NCCL-style grouped
//! allreduce (DESIGN.md §10).
//!
//! Nodes are split into contiguous groups of `group` nodes (the last
//! group may be smaller). Every schedule runs four phases:
//!
//! ```text
//! 1. intra-group ring reduce-scatter   (m-1 rounds, chunked per group)
//! 2. gather owned chunks to the leader (m-1 one-sender subrounds)
//! 3. inter-group ring over the leaders (2(G-1) rounds, G-chunked)
//! 4. intra-group chain broadcast       (m-1 rounds of the full payload)
//! ```
//!
//! With `group = 1` every node is a leader and only phase 3 runs — the
//! scheme degenerates to the flat ring, bit for bit (pinned in
//! `rust/tests/topology_equivalence.rs`). The closed-form cost of each
//! phase and its derivation live in DESIGN.md §10; the net-free
//! [`dense_plan`] / [`spread_plan`] round generators are shared with
//! `net::cost::CostModel`, so the closed-form predictions match the
//! simulated clock and byte counters to the last bit by construction.

use std::ops::Range;
use std::sync::atomic::AtomicU64;

use super::flat::{report, snapshot};
use super::{chunk_size, compact_to_support, or_masks, TopoKind, Topology};
use crate::net::RingNet;
use crate::ring::{chunk_ranges_aligned_into, chunk_ranges_into};
use crate::ring::{Arena, Executor, ReduceReport};
use crate::sparse::{wire_bytes, BitMask, SparseVec, WireFormat};

/// Two-level hierarchy: rings inside fixed-size node groups, a ring of
/// group leaders across groups (DESIGN.md §10).
#[derive(Debug, Clone, Copy)]
pub struct HierarchicalRing {
    geom: Geom,
    group: usize,
}

/// Group geometry: `n` nodes in contiguous groups of `g` (the last
/// group holds the remainder). Group `k` spans `[k·g, k·g + m_k)` with
/// `m_k = g` except possibly the last; its leader is node `k·g`.
#[derive(Debug, Clone, Copy)]
struct Geom {
    n: usize,
    g: usize,
    gcount: usize,
}

impl Geom {
    fn new(n: usize, group: usize) -> Self {
        assert!(n >= 2, "a topology needs at least 2 nodes");
        assert!(group >= 1, "hier group size must be >= 1");
        let g = group.min(n);
        Geom {
            n,
            g,
            gcount: n.div_ceil(g),
        }
    }

    /// First node of group `k`.
    fn start(&self, k: usize) -> usize {
        k * self.g
    }

    /// Size of group `k`.
    fn m(&self, k: usize) -> usize {
        if k + 1 == self.gcount {
            self.n - self.start(k)
        } else {
            self.g
        }
    }

    /// Largest group size (group 0 is always full).
    fn max_m(&self) -> usize {
        self.g
    }

    /// Size of the (possibly ragged) last group.
    fn m_last(&self) -> usize {
        self.n - (self.gcount - 1) * self.g
    }

    /// (group, position-in-group, group size) of node `i`.
    fn kpm(&self, i: usize) -> (usize, usize, usize) {
        let k = i / self.g;
        (k, i % self.g, self.m(k))
    }
}

/// Pick the chunk table for a group of size `m`: full-size groups share
/// one partition, the ragged last group has its own.
fn chunks_for<'a>(
    ca: &'a [Range<usize>],
    cb: &'a [Range<usize>],
    g: usize,
    m: usize,
) -> &'a [Range<usize>] {
    if m == g {
        ca
    } else {
        cb
    }
}

impl HierarchicalRing {
    /// A hierarchy over `n >= 2` nodes in groups of `group >= 1`
    /// (clamped to `n`; the last group holds the remainder).
    pub fn new(n: usize, group: usize) -> Self {
        HierarchicalRing {
            geom: Geom::new(n, group),
            group,
        }
    }
}

impl Topology for HierarchicalRing {
    fn kind(&self) -> TopoKind {
        TopoKind::Hier { group: self.group }
    }

    fn nodes(&self) -> usize {
        self.geom.n
    }

    fn reduce_hops(&self) -> usize {
        (self.geom.max_m() - 1) + (self.geom.gcount - 1)
    }

    fn dense(
        &self,
        net: &mut RingNet,
        bufs: &mut [Vec<f32>],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        let Arena {
            grows,
            dense_staging,
            dense_sends,
            tp_chunks_a,
            tp_chunks_b,
            tp_chunks_c,
            ..
        } = arena;
        dense_core(
            net,
            self.geom,
            bufs,
            exec,
            grows,
            dense_staging,
            dense_sends,
            tp_chunks_a,
            tp_chunks_b,
            tp_chunks_c,
        )
    }

    fn dense_bytes_only(
        &self,
        net: &mut RingNet,
        coords: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.geom.n);
        let Arena {
            grows, dense_sends, ..
        } = arena;
        let before = snapshot(net);
        let t0 = net.clock();
        let cap = dense_sends.capacity();
        dense_plan(self.geom.n, self.group, coords, dense_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, dense_sends.capacity() != cap);
        report(net, &before, t0, Vec::new())
    }

    fn sparse(
        &self,
        net: &mut RingNet,
        inputs: &[SparseVec],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (Vec<f32>, ReduceReport) {
        let geom = self.geom;
        let (n, g, gc) = (geom.n, geom.g, geom.gcount);
        assert_eq!(net.n_nodes(), n);
        assert_eq!(inputs.len(), n);
        let len = inputs[0].len;
        assert!(inputs.iter().all(|s| s.len == len));

        let Arena {
            grows,
            sp_held,
            sp_next,
            sp_segs,
            sp_sends,
            tp_chunks_a,
            tp_chunks_b,
            tp_chunks_c,
            tp_sums,
            tp_lheld,
            tp_lnext,
            ..
        } = arena;
        let grows: &AtomicU64 = grows;
        fill_chunks(grows, geom, len, false, tp_chunks_a, tp_chunks_b, tp_chunks_c);
        let ca: &[Range<usize>] = tp_chunks_a;
        let cb: &[Range<usize>] = tp_chunks_b;
        let cc: &[Range<usize>] = tp_chunks_c;
        Arena::slots(grows, sp_held, n, || SparseVec::empty(0));
        Arena::slots(grows, sp_next, n, || SparseVec::empty(0));
        Arena::slots(grows, sp_segs, n, || SparseVec::empty(0));
        Arena::slots(grows, tp_sums, gc, || SparseVec::empty(0));
        Arena::slots(grows, tp_lheld, gc, || SparseVec::empty(0));
        Arena::slots(grows, tp_lnext, gc, || SparseVec::empty(0));

        let before = snapshot(net);
        let t0 = net.clock();
        let mut density_per_hop = Vec::with_capacity(self.reduce_hops());

        // Phase 1 — intra-group ring reduce-scatter on sparse segments.
        exec.map_mut(&mut sp_held[..n], |i, h| {
            let (_, p, m) = geom.kpm(i);
            Arena::note(grows, h.assign_window(&inputs[i], &chunks_for(ca, cb, g, m)[p]));
        });
        let (mut held, mut next) = (sp_held, sp_next);
        for r in 0..geom.max_m() - 1 {
            Arena::refill(
                grows,
                sp_sends,
                (0..n).map(|i| {
                    let (_, _, m) = geom.kpm(i);
                    if r < m - 1 {
                        held[i].wire_bytes()
                    } else {
                        0
                    }
                }),
            );
            net.round(sp_sends);
            {
                let held_ref: &[SparseVec] = held;
                exec.map_mut2(&mut next[..n], &mut sp_segs[..n], |dst, nx, seg| {
                    let (k, p, m) = geom.kpm(dst);
                    if r < m - 1 {
                        let src = geom.start(k) + (p + m - 1) % m;
                        let c = (p + m - (r + 1)) % m;
                        Arena::note(
                            grows,
                            seg.assign_window(&inputs[dst], &chunks_for(ca, cb, g, m)[c]),
                        );
                        Arena::note(grows, held_ref[src].merge_add_into(seg, nx));
                    } else {
                        // This group is done (or a singleton): its owned
                        // segment just rides along unchanged.
                        let hlen = held_ref[dst].len;
                        Arena::note(grows, nx.assign_window(&held_ref[dst], &(0..hlen)));
                    }
                });
            }
            std::mem::swap(&mut held, &mut next);
            let d = held[..n].iter().map(|s| s.density()).sum::<f64>() / n as f64;
            density_per_hop.push(d);
        }

        // Phase 2 — gather owned segments to the leaders (accounting),
        // then assemble per-group sparse sums on the coordinator.
        for j in 1..geom.max_m() {
            Arena::refill(
                grows,
                sp_sends,
                (0..n).map(|i| {
                    let (_, p, m) = geom.kpm(i);
                    if p == j && j < m {
                        held[i].wire_bytes()
                    } else {
                        0
                    }
                }),
            );
            net.round(sp_sends);
        }
        for k in 0..gc {
            let (start, m) = (geom.start(k), geom.m(k));
            let chunks = chunks_for(ca, cb, g, m);
            let sum = &mut tp_sums[k];
            let caps = (sum.idx.capacity(), sum.val.capacity());
            sum.clear_to(len);
            for (c, range) in chunks.iter().enumerate() {
                let holder = start + (c + m - 1) % m;
                for (&i2, &v) in held[holder].idx.iter().zip(&held[holder].val) {
                    sum.idx.push((range.start + i2 as usize) as u32);
                    sum.val.push(v);
                }
            }
            Arena::note(grows, caps != (sum.idx.capacity(), sum.val.capacity()));
        }

        // Phase 3 — inter-group ring over the leaders (scatter-reduce).
        let sums: &[SparseVec] = tp_sums;
        let (mut lheld, mut lnext) = (tp_lheld, tp_lnext);
        if gc >= 2 {
            exec.map_mut(&mut lheld[..gc], |k, h| {
                Arena::note(grows, h.assign_window(&sums[k], &cc[k]));
            });
            for r in 0..gc - 1 {
                Arena::refill(
                    grows,
                    sp_sends,
                    (0..n).map(|i| {
                        let (k, p, _) = geom.kpm(i);
                        if p == 0 {
                            lheld[k].wire_bytes()
                        } else {
                            0
                        }
                    }),
                );
                net.round(sp_sends);
                {
                    let lheld_ref: &[SparseVec] = lheld;
                    exec.map_mut2(&mut lnext[..gc], &mut sp_segs[..gc], |kd, nx, seg| {
                        let src = (kd + gc - 1) % gc;
                        let c = (kd + gc - (r + 1)) % gc;
                        Arena::note(grows, seg.assign_window(&sums[kd], &cc[c]));
                        Arena::note(grows, lheld_ref[src].merge_add_into(seg, nx));
                    });
                }
                std::mem::swap(&mut lheld, &mut lnext);
                let d = lheld[..gc].iter().map(|s| s.density()).sum::<f64>() / gc as f64;
                density_per_hop.push(d);
            }
        }

        // Assemble the global result + leader allgather accounting at
        // the final densities (every leader must end with every chunk).
        let mut result = vec![0.0f32; len];
        let global_nnz;
        if gc >= 2 {
            global_nnz = lheld[..gc].iter().map(|s| s.nnz()).sum::<usize>();
            for (k, h) in lheld[..gc].iter().enumerate() {
                let range = cc[(k + 1) % gc].clone();
                for (&i2, &v) in h.idx.iter().zip(&h.val) {
                    result[range.start + i2 as usize] += v;
                }
            }
            for r in 0..gc - 1 {
                Arena::refill(
                    grows,
                    sp_sends,
                    (0..n).map(|i| {
                        let (k, p, _) = geom.kpm(i);
                        if p != 0 {
                            return 0;
                        }
                        // The fully-reduced chunk c travels in sparse
                        // format; its holder's exact encoding prices it.
                        let c = (k + 1 + gc - r) % gc;
                        lheld[(c + gc - 1) % gc].wire_bytes()
                    }),
                );
                net.round(sp_sends);
            }
        } else {
            global_nnz = sums[0].nnz();
            sums[0].scatter_add(&mut result);
        }

        // Phase 4 — intra-group chain broadcast of the global sparse sum.
        let bcast = wire_bytes(WireFormat::cheapest(len, global_nnz), len, global_nnz);
        for r in 0..geom.max_m() - 1 {
            Arena::refill(
                grows,
                sp_sends,
                (0..n).map(|i| {
                    let (_, p, m) = geom.kpm(i);
                    if p == r && r + 1 < m {
                        bcast
                    } else {
                        0
                    }
                }),
            );
            net.round(sp_sends);
        }

        (result, report(net, &before, t0, density_per_hop))
    }

    fn sparse_support(
        &self,
        net: &mut RingNet,
        supports: &[BitMask],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        let geom = self.geom;
        let (n, g, gc) = (geom.n, geom.g, geom.gcount);
        assert_eq!(net.n_nodes(), n);
        assert_eq!(supports.len(), n);
        let len = supports[0].len();
        assert!(supports.iter().all(|s| s.len() == len));

        let Arena {
            grows,
            su_held,
            su_next,
            su_sends,
            tp_chunks_a,
            tp_chunks_b,
            tp_chunks_c,
            tp_wsums,
            tp_wheld,
            tp_wnext,
            ..
        } = arena;
        let grows: &AtomicU64 = grows;
        fill_chunks(grows, geom, len, true, tp_chunks_a, tp_chunks_b, tp_chunks_c);
        let ca: &[Range<usize>] = tp_chunks_a;
        let cb: &[Range<usize>] = tp_chunks_b;
        let cc: &[Range<usize>] = tp_chunks_c;
        Arena::slots(grows, su_held, n, Vec::new);
        Arena::slots(grows, su_next, n, Vec::new);
        Arena::slots(grows, tp_wsums, gc, Vec::new);
        Arena::slots(grows, tp_wheld, gc, Vec::new);
        Arena::slots(grows, tp_wnext, gc, Vec::new);

        let before = snapshot(net);
        let t0 = net.clock();
        let mut density_per_hop = Vec::with_capacity(self.reduce_hops());
        let seg_bytes = |words: &[u64], chunk_len: usize| -> u64 {
            let nnz = BitMask::popcount_words(words);
            wire_bytes(WireFormat::cheapest(chunk_len, nnz), chunk_len, nnz)
        };

        // Phase 1 — intra-group reduce-scatter on support word blocks.
        exec.map_mut(&mut su_held[..n], |i, h| {
            let (_, p, m) = geom.kpm(i);
            let chunk = chunks_for(ca, cb, g, m)[p].clone();
            Arena::note(grows, Arena::refill_slice(h, supports[i].word_slice(chunk)));
        });
        let (mut held, mut next) = (su_held, su_next);
        for r in 0..geom.max_m() - 1 {
            Arena::refill(
                grows,
                su_sends,
                (0..n).map(|i| {
                    let (_, p, m) = geom.kpm(i);
                    if r < m - 1 {
                        let c = (p + m - r) % m;
                        seg_bytes(&held[i], chunks_for(ca, cb, g, m)[c].len())
                    } else {
                        0
                    }
                }),
            );
            net.round(su_sends);
            {
                let held_ref: &[Vec<u64>] = held;
                exec.map_mut(&mut next[..n], |dst, nx| {
                    let (k, p, m) = geom.kpm(dst);
                    if r < m - 1 {
                        let src = geom.start(k) + (p + m - 1) % m;
                        let c = (p + m - (r + 1)) % m;
                        let own = supports[dst].word_slice(chunks_for(ca, cb, g, m)[c].clone());
                        Arena::note(grows, Arena::refill_slice(nx, &held_ref[src]));
                        for (w, o) in nx.iter_mut().zip(own) {
                            *w |= o;
                        }
                    } else {
                        Arena::note(grows, Arena::refill_slice(nx, &held_ref[dst]));
                    }
                });
            }
            std::mem::swap(&mut held, &mut next);
            let (mut nnz, mut tot) = (0usize, 0usize);
            for (i, h) in held[..n].iter().enumerate() {
                let (_, p, m) = geom.kpm(i);
                let c = if r < m - 1 {
                    (p + m - (r + 1)) % m
                } else {
                    (p + 1) % m
                };
                nnz += BitMask::popcount_words(h);
                tot += chunks_for(ca, cb, g, m)[c].len();
            }
            density_per_hop.push(nnz as f64 / tot.max(1) as f64);
        }

        // Phase 2 — gather to leaders + per-group word-union assembly.
        for j in 1..geom.max_m() {
            Arena::refill(
                grows,
                su_sends,
                (0..n).map(|i| {
                    let (_, p, m) = geom.kpm(i);
                    if p == j && j < m {
                        let c = (p + 1) % m;
                        seg_bytes(&held[i], chunks_for(ca, cb, g, m)[c].len())
                    } else {
                        0
                    }
                }),
            );
            net.round(su_sends);
        }
        for k in 0..gc {
            let (start, m) = (geom.start(k), geom.m(k));
            let chunks = chunks_for(ca, cb, g, m);
            let sum = &mut tp_wsums[k];
            let cap = sum.capacity();
            sum.clear();
            for (c, _range) in chunks.iter().enumerate() {
                let holder = start + (c + m - 1) % m;
                sum.extend_from_slice(&held[holder]);
            }
            Arena::note(grows, sum.capacity() != cap);
        }

        // Phase 3 — inter-group ring over leaders' word windows.
        let wsums: &[Vec<u64>] = tp_wsums;
        let word_window = |words: &[u64], range: &Range<usize>| -> &[u64] {
            if range.is_empty() {
                // Degenerate trailing chunks of the aligned partition are
                // `len..len` — same guard as `BitMask::word_slice`.
                return &[];
            }
            &words[range.start / 64..range.end.div_ceil(64)]
        };
        let (mut lheld, mut lnext) = (tp_wheld, tp_wnext);
        if gc >= 2 {
            exec.map_mut(&mut lheld[..gc], |k, h| {
                Arena::note(grows, Arena::refill_slice(h, word_window(&wsums[k], &cc[k])));
            });
            for r in 0..gc - 1 {
                Arena::refill(
                    grows,
                    su_sends,
                    (0..n).map(|i| {
                        let (k, p, _) = geom.kpm(i);
                        if p == 0 {
                            let c = (k + gc - r) % gc;
                            seg_bytes(&lheld[k], cc[c].len())
                        } else {
                            0
                        }
                    }),
                );
                net.round(su_sends);
                {
                    let lheld_ref: &[Vec<u64>] = lheld;
                    exec.map_mut(&mut lnext[..gc], |kd, nx| {
                        let src = (kd + gc - 1) % gc;
                        let c = (kd + gc - (r + 1)) % gc;
                        let own = word_window(&wsums[kd], &cc[c]);
                        Arena::note(grows, Arena::refill_slice(nx, &lheld_ref[src]));
                        for (w, o) in nx.iter_mut().zip(own) {
                            *w |= o;
                        }
                    });
                }
                std::mem::swap(&mut lheld, &mut lnext);
                let (mut nnz, mut tot) = (0usize, 0usize);
                for (k, h) in lheld[..gc].iter().enumerate() {
                    let c = (k + gc - (r + 1)) % gc;
                    nnz += BitMask::popcount_words(h);
                    tot += cc[c].len();
                }
                density_per_hop.push(nnz as f64 / tot.max(1) as f64);
            }
            // Leader allgather accounting at the final densities.
            for r in 0..gc - 1 {
                Arena::refill(
                    grows,
                    su_sends,
                    (0..n).map(|i| {
                        let (k, p, _) = geom.kpm(i);
                        if p != 0 {
                            return 0;
                        }
                        let c = (k + 1 + gc - r) % gc;
                        let holder = (c + gc - 1) % gc;
                        seg_bytes(&lheld[holder], cc[c].len())
                    }),
                );
                net.round(su_sends);
            }
        }

        // Phase 4 — chain broadcast of the global support union.
        let global_nnz = if gc >= 2 {
            lheld[..gc]
                .iter()
                .map(|h| BitMask::popcount_words(h))
                .sum::<usize>()
        } else {
            BitMask::popcount_words(&wsums[0])
        };
        let bcast = wire_bytes(WireFormat::cheapest(len, global_nnz), len, global_nnz);
        for r in 0..geom.max_m() - 1 {
            Arena::refill(
                grows,
                su_sends,
                (0..n).map(|i| {
                    let (_, p, m) = geom.kpm(i);
                    if p == r && r + 1 < m {
                        bcast
                    } else {
                        0
                    }
                }),
            );
            net.round(su_sends);
        }

        report(net, &before, t0, density_per_hop)
    }

    fn masked(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        values: &[&[f32]],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (BitMask, Vec<f32>, ReduceReport) {
        let geom = self.geom;
        let n = geom.n;
        assert_eq!(net.n_nodes(), n);
        assert_eq!(values.len(), n);
        assert!(!masks.is_empty(), "need at least one mask broadcaster");
        let len = masks[0].len();
        assert!(values.iter().all(|v| v.len() == len));

        let mask_bytes = masks[0].wire_bytes();
        let k = masks.len().min(n);
        let before = snapshot(net);
        let t0 = net.clock();

        // Mask spread: gather to leaders, leader ring, chain broadcast.
        {
            let Arena {
                grows, ag_sends, ..
            } = &mut *arena;
            let cap = ag_sends.capacity();
            spread_plan(n, self.group, mask_bytes, k, ag_sends, |s| {
                net.round(s);
            });
            Arena::note(grows, ag_sends.capacity() != cap);
        }
        let shared = or_masks(masks, len);

        // Compact every node's values to the shared support, then run
        // the hierarchical dense schedule over the compacted vectors.
        let Arena {
            grows,
            mk_support,
            mk_compact,
            dense_staging,
            dense_sends,
            tp_chunks_a,
            tp_chunks_b,
            tp_chunks_c,
            ..
        } = arena;
        let grows: &AtomicU64 = grows;
        compact_to_support(&shared, values, exec, grows, mk_support, mk_compact);
        dense_core(
            net,
            geom,
            &mut mk_compact[..n],
            exec,
            grows,
            dense_staging,
            dense_sends,
            tp_chunks_a,
            tp_chunks_b,
            tp_chunks_c,
        );

        let rep = report(
            net,
            &before,
            t0,
            vec![shared.density(); self.reduce_hops()],
        );
        (shared, mk_compact[0].clone(), rep)
    }

    fn masked_bytes_only(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        arena: &mut Arena,
    ) -> (BitMask, ReduceReport) {
        let n = self.geom.n;
        assert_eq!(net.n_nodes(), n);
        assert!(!masks.is_empty());
        let len = masks[0].len();
        let mask_bytes = masks[0].wire_bytes();
        let k = masks.len().min(n);
        let before = snapshot(net);
        let t0 = net.clock();
        let Arena {
            grows,
            ag_sends,
            dense_sends,
            ..
        } = arena;
        let cap = ag_sends.capacity();
        spread_plan(n, self.group, mask_bytes, k, ag_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, ag_sends.capacity() != cap);
        let shared = or_masks(masks, len);
        let cap = dense_sends.capacity();
        dense_plan(n, self.group, shared.count(), dense_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, dense_sends.capacity() != cap);
        let rep = report(
            net,
            &before,
            t0,
            vec![shared.density(); self.reduce_hops()],
        );
        (shared, rep)
    }

    fn spread_bytes(
        &self,
        net: &mut RingNet,
        blob_bytes: u64,
        k: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        let n = self.geom.n;
        assert_eq!(net.n_nodes(), n);
        let Arena {
            grows, ag_sends, ..
        } = arena;
        let before = snapshot(net);
        let t0 = net.clock();
        let cap = ag_sends.capacity();
        spread_plan(n, self.group, blob_bytes, k, ag_sends, |s| {
            net.round(s);
        });
        Arena::note(grows, ag_sends.capacity() != cap);
        report(net, &before, t0, Vec::new())
    }
}

/// Refill the three chunk tables for `len` coordinates: intra-group
/// full-size (`ca`), intra-group ragged-last (`cb`), inter-group leader
/// (`cc`). `aligned` selects the word-aligned partition the support-only
/// path requires.
fn fill_chunks(
    grows: &AtomicU64,
    geom: Geom,
    len: usize,
    aligned: bool,
    ca: &mut Vec<Range<usize>>,
    cb: &mut Vec<Range<usize>>,
    cc: &mut Vec<Range<usize>>,
) {
    let fill = |out: &mut Vec<Range<usize>>, m: usize| -> bool {
        let cap = out.capacity();
        if aligned {
            chunk_ranges_aligned_into(len, m, out);
        } else {
            chunk_ranges_into(len, m, out);
        }
        out.capacity() != cap
    };
    Arena::note(grows, fill(ca, geom.max_m()));
    Arena::note(grows, fill(cb, geom.m_last()));
    Arena::note(grows, fill(cc, geom.gcount));
}

/// The exact hierarchical dense schedule over explicit scratch parts
/// (so the masked schedule can run it while holding its own arena
/// fields — the same split the flat `dense::allreduce_parts` uses).
#[allow(clippy::too_many_arguments)]
fn dense_core(
    net: &mut RingNet,
    geom: Geom,
    bufs: &mut [Vec<f32>],
    exec: &Executor,
    grows: &AtomicU64,
    staging: &mut Vec<Vec<f32>>,
    sends: &mut Vec<u64>,
    ca: &mut Vec<Range<usize>>,
    cb: &mut Vec<Range<usize>>,
    cc: &mut Vec<Range<usize>>,
) -> ReduceReport {
    let (n, g, gc) = (geom.n, geom.g, geom.gcount);
    assert_eq!(net.n_nodes(), n);
    assert_eq!(bufs.len(), n, "one buffer per node");
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    if len == 0 {
        return ReduceReport {
            bytes_per_node: vec![0; n],
            ..Default::default()
        };
    }

    fill_chunks(grows, geom, len, false, ca, cb, cc);
    let ca: &[Range<usize>] = ca;
    let cb: &[Range<usize>] = cb;
    let cc: &[Range<usize>] = cc;
    Arena::slots(grows, staging, n, Vec::new);
    let before = snapshot(net);
    let t0 = net.clock();

    // Phase 1 — intra-group ring reduce-scatter: within each group of
    // size m, position p sends chunk (p - r) mod m to p+1, which
    // accumulates it (the flat scatter-reduce, group-local).
    for r in 0..geom.max_m() - 1 {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                let (_, p, m) = geom.kpm(i);
                if r < m - 1 {
                    (chunks_for(ca, cb, g, m)[(p + m - r) % m].len() * 4) as u64
                } else {
                    0
                }
            }),
        );
        net.round(sends);
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                let (_, p, m) = geom.kpm(i);
                if r < m - 1 {
                    let c = (p + m - r) % m;
                    Arena::note(
                        grows,
                        Arena::refill_slice(
                            stage,
                            &bufs_src[i][chunks_for(ca, cb, g, m)[c].clone()],
                        ),
                    );
                }
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            let (k, p, m) = geom.kpm(dst);
            if r < m - 1 {
                let src_pos = (p + m - 1) % m;
                let src = geom.start(k) + src_pos;
                let c = (src_pos + m - r) % m;
                let range = chunks_for(ca, cb, g, m)[c].clone();
                for (k2, idx) in range.enumerate() {
                    buf[idx] += staged[src][k2];
                }
            }
        });
    }

    // Phase 2 — gather: member j of each group sends its owned chunk
    // ((j+1) mod m) to the leader, one member per subround (the leader's
    // ingress link serializes the gather).
    for j in 1..geom.max_m() {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                let (_, p, m) = geom.kpm(i);
                if p == j && j < m {
                    (chunks_for(ca, cb, g, m)[(j + 1) % m].len() * 4) as u64
                } else {
                    0
                }
            }),
        );
        net.round(sends);
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                let (_, p, m) = geom.kpm(i);
                if p == j && j < m {
                    let c = (j + 1) % m;
                    Arena::note(
                        grows,
                        Arena::refill_slice(
                            stage,
                            &bufs_src[i][chunks_for(ca, cb, g, m)[c].clone()],
                        ),
                    );
                }
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            let (k, p, m) = geom.kpm(dst);
            if p == 0 && j < m {
                let c = (j + 1) % m;
                let range = chunks_for(ca, cb, g, m)[c].clone();
                for (k2, idx) in range.enumerate() {
                    buf[idx] = staged[geom.start(k) + j][k2];
                }
            }
        });
    }

    // Phase 3 — inter-group ring over the leaders: the flat dense
    // schedule restricted to the G leader nodes over a G-chunking.
    if gc >= 2 {
        for r in 0..gc - 1 {
            Arena::refill(
                grows,
                sends,
                (0..n).map(|i| {
                    let (k, p, _) = geom.kpm(i);
                    if p == 0 {
                        (cc[(k + gc - r) % gc].len() * 4) as u64
                    } else {
                        0
                    }
                }),
            );
            net.round(sends);
            {
                let bufs_src: &[Vec<f32>] = bufs;
                exec.map_mut(&mut staging[..n], |i, stage| {
                    let (k, p, _) = geom.kpm(i);
                    if p == 0 {
                        let c = (k + gc - r) % gc;
                        Arena::note(
                            grows,
                            Arena::refill_slice(stage, &bufs_src[i][cc[c].clone()]),
                        );
                    }
                });
            }
            let staged: &[Vec<f32>] = staging;
            exec.map_mut(bufs, |dst, buf| {
                let (kd, p, _) = geom.kpm(dst);
                if p == 0 {
                    let ks = (kd + gc - 1) % gc;
                    let c = (ks + gc - r) % gc;
                    let range = cc[c].clone();
                    for (k2, idx) in range.enumerate() {
                        buf[idx] += staged[geom.start(ks)][k2];
                    }
                }
            });
        }
        for r in 0..gc - 1 {
            Arena::refill(
                grows,
                sends,
                (0..n).map(|i| {
                    let (k, p, _) = geom.kpm(i);
                    if p == 0 {
                        (cc[(k + 1 + gc - r) % gc].len() * 4) as u64
                    } else {
                        0
                    }
                }),
            );
            net.round(sends);
            {
                let bufs_src: &[Vec<f32>] = bufs;
                exec.map_mut(&mut staging[..n], |i, stage| {
                    let (k, p, _) = geom.kpm(i);
                    if p == 0 {
                        let c = (k + 1 + gc - r) % gc;
                        Arena::note(
                            grows,
                            Arena::refill_slice(stage, &bufs_src[i][cc[c].clone()]),
                        );
                    }
                });
            }
            let staged: &[Vec<f32>] = staging;
            exec.map_mut(bufs, |dst, buf| {
                let (kd, p, _) = geom.kpm(dst);
                if p == 0 {
                    let ks = (kd + gc - 1) % gc;
                    let c = (ks + 1 + gc - r) % gc;
                    let range = cc[c].clone();
                    for (k2, idx) in range.enumerate() {
                        buf[idx] = staged[geom.start(ks)][k2];
                    }
                }
            });
        }
    }

    // Phase 4 — intra-group chain broadcast: position r forwards the
    // full reduced vector to position r+1.
    for r in 0..geom.max_m() - 1 {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                let (_, p, m) = geom.kpm(i);
                if p == r && r + 1 < m {
                    (len * 4) as u64
                } else {
                    0
                }
            }),
        );
        net.round(sends);
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                let (_, p, m) = geom.kpm(i);
                if p == r && r + 1 < m {
                    Arena::note(grows, Arena::refill_slice(stage, &bufs_src[i][..]));
                }
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            let (k, p, _) = geom.kpm(dst);
            if p == r + 1 {
                buf.copy_from_slice(&staged[geom.start(k) + r]);
            }
        });
    }

    ReduceReport {
        bytes_per_node: (0..n)
            .map(|i| net.node_tx_bytes(i) - before[i])
            .collect(),
        seconds: net.clock() - t0,
        density_per_hop: Vec::new(),
    }
}

/// Net-free round plan of the hierarchical dense schedule: emits every
/// round's per-node send vector in simulation order. `dense_bytes_only`
/// drives `RingNet::round` with it and `CostModel::topo_dense_*`
/// accumulates cost from it, so prediction and simulation agree to the
/// last bit by construction (DESIGN.md §10). Emits nothing for
/// `len == 0`, matching the exact path's early return.
pub(crate) fn dense_plan(
    n: usize,
    group: usize,
    len: usize,
    sends: &mut Vec<u64>,
    mut round: impl FnMut(&[u64]),
) {
    let geom = Geom::new(n, group);
    let gc = geom.gcount;
    if len == 0 {
        return;
    }
    let cs = |m: usize, c: usize| (chunk_size(len, m, c) * 4) as u64;
    for r in 0..geom.max_m() - 1 {
        refill(sends, 0..n, |i| {
            let (_, p, m) = geom.kpm(i);
            if r < m - 1 {
                cs(m, (p + m - r) % m)
            } else {
                0
            }
        });
        round(sends);
    }
    for j in 1..geom.max_m() {
        refill(sends, 0..n, |i| {
            let (_, p, m) = geom.kpm(i);
            if p == j && j < m {
                cs(m, (j + 1) % m)
            } else {
                0
            }
        });
        round(sends);
    }
    if gc >= 2 {
        for r in 0..gc - 1 {
            refill(sends, 0..n, |i| {
                let (k, p, _) = geom.kpm(i);
                if p == 0 {
                    cs(gc, (k + gc - r) % gc)
                } else {
                    0
                }
            });
            round(sends);
        }
        for r in 0..gc - 1 {
            refill(sends, 0..n, |i| {
                let (k, p, _) = geom.kpm(i);
                if p == 0 {
                    cs(gc, (k + 1 + gc - r) % gc)
                } else {
                    0
                }
            });
            round(sends);
        }
    }
    for r in 0..geom.max_m() - 1 {
        refill(sends, 0..n, |i| {
            let (_, p, m) = geom.kpm(i);
            if p == r && r + 1 < m {
                (len * 4) as u64
            } else {
                0
            }
        });
        round(sends);
    }
}

/// Net-free round plan of the hierarchical blob spread: nodes `0..k`
/// hold one `blob`-byte blob each; gather to leaders, ring the group
/// aggregates across leaders, chain-broadcast the full set.
pub(crate) fn spread_plan(
    n: usize,
    group: usize,
    blob: u64,
    k: usize,
    sends: &mut Vec<u64>,
    mut round: impl FnMut(&[u64]),
) {
    let geom = Geom::new(n, group);
    let gc = geom.gcount;
    let k = k.min(n);
    // Blob bytes group `q` holds after the gather: its members in 0..k.
    let group_total = |q: usize| -> u64 {
        let start = geom.start(q);
        let end = start + geom.m(q);
        blob * (end.min(k).saturating_sub(start)) as u64
    };
    let total: u64 = blob * k as u64;
    for j in 1..geom.max_m() {
        refill(sends, 0..n, |i| {
            let (_, p, m) = geom.kpm(i);
            if p == j && j < m && i < k {
                blob
            } else {
                0
            }
        });
        round(sends);
    }
    if gc >= 2 {
        for r in 0..gc - 1 {
            refill(sends, 0..n, |i| {
                let (q, p, _) = geom.kpm(i);
                if p == 0 {
                    group_total((q + gc - r) % gc)
                } else {
                    0
                }
            });
            round(sends);
        }
    }
    for r in 0..geom.max_m() - 1 {
        refill(sends, 0..n, |i| {
            let (_, p, m) = geom.kpm(i);
            if p == r && r + 1 < m {
                total
            } else {
                0
            }
        });
        round(sends);
    }
}

/// Refill `sends` from a per-node closure, reusing capacity.
fn refill(sends: &mut Vec<u64>, nodes: std::ops::Range<usize>, f: impl Fn(usize) -> u64) {
    sends.clear();
    sends.extend(nodes.map(f));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    #[test]
    fn geometry_partitions_exactly() {
        let g = Geom::new(10, 4);
        assert_eq!(g.gcount, 3);
        assert_eq!((g.m(0), g.m(1), g.m(2)), (4, 4, 2));
        assert_eq!(g.m_last(), 2);
        assert_eq!(g.kpm(9), (2, 1, 2));
        let g = Geom::new(8, 16); // group > n clamps to one group
        assert_eq!(g.gcount, 1);
        assert_eq!(g.m(0), 8);
    }

    #[test]
    fn dense_reduces_to_sum() {
        for (n, group) in [(6usize, 2usize), (7, 3), (8, 8), (5, 1)] {
            let len = 37;
            let base: Vec<Vec<f32>> = (0..n)
                .map(|i| (0..len).map(|j| (i * len + j) as f32).collect())
                .collect();
            let mut expect = vec![0.0f32; len];
            for b in &base {
                for (e, &v) in expect.iter_mut().zip(b) {
                    *e += v;
                }
            }
            let topo = HierarchicalRing::new(n, group);
            let mut nw = net(n);
            let mut bufs = base;
            topo.dense(
                &mut nw,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            for (node, b) in bufs.iter().enumerate() {
                for (j, (&x, &e)) in b.iter().zip(&expect).enumerate() {
                    assert_eq!(x, e, "n={n} g={group} node={node} coord={j}");
                }
            }
        }
    }

    #[test]
    fn dense_bytes_only_matches_exact_accounting() {
        for (n, group, len) in [(6usize, 2usize, 500usize), (9, 4, 1234), (8, 3, 64)] {
            let topo = HierarchicalRing::new(n, group);
            let mut net_a = net(n);
            let mut bufs = vec![vec![1.0f32; len]; n];
            let rep = topo.dense(
                &mut net_a,
                &mut bufs,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            let mut net_b = net(n);
            let rep_b = topo.dense_bytes_only(&mut net_b, len, &mut Arena::for_nodes(n));
            assert_eq!(rep.bytes_per_node, rep_b.bytes_per_node, "n={n} g={group}");
            assert_eq!(rep.seconds.to_bits(), rep_b.seconds.to_bits());
            assert_eq!(net_a.rounds(), net_b.rounds());
        }
    }

    #[test]
    fn sparse_result_matches_direct_sum() {
        let (n, group, len) = (7usize, 3usize, 90usize);
        let mut rng = crate::util::rng::Rng::new(5);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| {
                let mut dense = vec![0.0f32; len];
                for v in dense.iter_mut() {
                    if rng.uniform() < 0.2 {
                        *v = (rng.below(9) as f32) - 4.0; // exact integers
                    }
                }
                SparseVec::from_dense(&dense)
            })
            .collect();
        let mut expect = vec![0.0f32; len];
        for s in &inputs {
            s.scatter_add(&mut expect);
        }
        let topo = HierarchicalRing::new(n, group);
        let mut nw = net(n);
        let (got, rep) = topo.sparse(
            &mut nw,
            &inputs,
            &Executor::sequential(),
            &mut Arena::for_nodes(n),
        );
        assert_eq!(got, expect);
        assert_eq!(rep.density_per_hop.len(), topo.reduce_hops());
    }

    #[test]
    fn spread_total_bytes_account_every_link() {
        // 3 blobs of 100 B on an 8-node, group-4 hierarchy: gather moves
        // each non-leader blob once, the leader ring moves each group
        // aggregate G-1 times, broadcast moves the full 300 B set m-1
        // times per group.
        let (n, group, blob, k) = (8usize, 4usize, 100u64, 3usize);
        let topo = HierarchicalRing::new(n, group);
        let mut nw = net(n);
        let rep = topo.spread_bytes(&mut nw, blob, k, &mut Arena::for_nodes(n));
        // gather: blobs at nodes 1,2 (leaders 0 and 4 keep theirs) = 200;
        // leader ring: group totals 300 and 0, each crossing G-1=1 link = 300;
        // broadcast: 300 B x (4-1) senders x 2 groups = 1800.
        assert_eq!(rep.total_bytes(), 200 + 300 + 1800);
    }
}
