//! Layer-pipelined allreduce wrapper (DESIGN.md §11).
//!
//! Every base topology is strictly phase-serialized: the whole model is
//! scored/compacted, *then* the whole blob goes through its wire
//! rounds. DGC (1712.01887) and RedSync (1808.04357) both observe that
//! the real wall-clock win is overlapping selection with transmission.
//! [`PipelineRing`] models exactly that: the payload splits into
//! `chunks` contiguous pieces (fixed-size; pick `chunks` ≈ the layer
//! count for per-layer pipelining) and chunk `l+1`'s compression prep
//! runs while chunk `l` is in its ring rounds:
//!
//! ```text
//! prep:  |p0|p1|p2|p3|                      (one compute resource)
//! wire:      |--w0--|--w1--|--w2--|--w3--|  (rounds serialize on the net)
//!        makespan = max_l ( Σ_{j<=l} p_j  +  Σ_{j>=l} w_j )
//! ```
//!
//! Wire rounds still serialize (chunks share the same links), so the
//! win is hiding the selection pass: serial cost `P + W` becomes
//! `≈ P/k + W` when wire-bound, at the price of `(k-1)` extra rounds'
//! latency per phase. Break-even: pipelining pays off iff the hidden
//! prep per chunk exceeds the added round latency (DESIGN.md §11 gives
//! the closed form; `CostModel::pipelined_masked_seconds` predicts the
//! makespan bit-exactly against this simulation).
//!
//! Contract (mirrors §10): values are **bit-identical to the wrapped
//! topology** on exactly-representable payloads (per-chunk sums add the
//! same node values per coordinate; pinned in
//! `rust/tests/topology_equivalence.rs`), executor-parallel per-chunk
//! with the §4 bit-identical guarantee, arena-threaded with zero
//! steady-state allocation. The per-node-support schedules
//! ([`Topology::sparse`], [`Topology::sparse_support`]) and opaque blob
//! spreads delegate to the wrapped topology unchanged — their payloads
//! are data-dependent blobs with no prep stage to overlap.
//!
//! Prep cost is only modeled where a compression stage exists: the
//! masked (Algorithm 1) schedules. Dense pipelining carries no prep —
//! `pipeline:<k>` on the Baseline only adds round latency, which the
//! sweeps in EXPERIMENTS.md §8 make visible deliberately. Base
//! topologies never price prep (their selection pass runs outside the
//! virtual clock), so compare `pipeline:<k>` against `pipeline:1`,
//! the serial reference with identical prep accounting.

use super::flat::{report, snapshot};
use super::{
    chunk_size, hier_dense_plan, hier_spread_plan, or_masks, tree_dense_plan, tree_spread_plan,
    PipeInner, TopoKind, Topology,
};
use crate::net::RingNet;
use crate::ring::{Arena, Executor, ReduceReport};
use crate::sparse::{BitMask, SparseVec};

/// Virtual seconds of fused compression prep (score + select + compact,
/// `compress::fuse`) per coordinate: calibrated to ~1 G coords/s — one
/// single-pass sweep over f32 data at memory-bandwidth-bound throughput
/// on one core (the BENCH_step fused-kernel ns/op magnitude).
pub const PREP_SECONDS_PER_COORD: f64 = 1e-9;

/// Prep time of one `coords`-coordinate pipeline chunk.
pub fn prep_seconds(coords: usize) -> f64 {
    coords as f64 * PREP_SECONDS_PER_COORD
}

/// Per-chunk support counts of `mask` under the balanced `chunks`-way
/// partition — the data the pipelined cost model needs
/// (`CostModel::pipelined_masked_*`). One pass over the set bits.
pub fn chunk_supports(mask: &BitMask, chunks: usize) -> Vec<usize> {
    assert!(chunks >= 1);
    let len = mask.len();
    let mut out = vec![0usize; chunks];
    let mut ci = 0usize;
    let mut end = chunk_size(len, chunks, 0);
    for i in mask.iter_set() {
        while i >= end {
            ci += 1;
            end += chunk_size(len, chunks, ci);
        }
        out[ci] += 1;
    }
    out
}

/// Layer-pipelined wrapper over a base topology (DESIGN.md §11):
/// `pipeline:<chunks>[:<inner>]`.
#[derive(Debug)]
pub struct PipelineRing {
    n: usize,
    chunks: usize,
    inner_base: PipeInner,
    inner: Box<dyn Topology>,
}

impl PipelineRing {
    /// A `chunks`-stage pipeline over `inner` for `n >= 2` nodes.
    pub fn new(n: usize, chunks: usize, inner: PipeInner) -> Self {
        assert!(n >= 2, "a topology needs at least 2 nodes");
        assert!(chunks >= 1, "pipeline chunk count must be >= 1");
        PipelineRing {
            n,
            chunks,
            inner_base: inner,
            inner: inner.kind().build(n),
        }
    }

    /// Accounting-only dense rounds of the wrapped topology — the same
    /// arena buffers and round sequences its own `dense_bytes_only`
    /// drives, without assembling a per-call report.
    fn dense_rounds_only(&self, net: &mut RingNet, coords: usize, arena: &mut Arena) {
        match self.inner_base {
            PipeInner::Flat => crate::ring::dense::rounds_bytes_only(net, coords, arena),
            PipeInner::Hier { group } => {
                let Arena {
                    grows, dense_sends, ..
                } = arena;
                let cap = dense_sends.capacity();
                hier_dense_plan(self.n, group, coords, dense_sends, |s| {
                    net.round(s);
                });
                Arena::note(grows, dense_sends.capacity() != cap);
            }
            PipeInner::Tree => {
                let Arena {
                    grows, dense_sends, ..
                } = arena;
                let cap = dense_sends.capacity();
                tree_dense_plan(self.n, coords, dense_sends, |s| {
                    net.round(s);
                });
                Arena::note(grows, dense_sends.capacity() != cap);
            }
        }
    }

    /// Accounting-only blob spread of the wrapped topology (mask chunks
    /// are opaque blobs — the combine is pure data, §10).
    fn spread_rounds_only(&self, net: &mut RingNet, blob: u64, k: usize, arena: &mut Arena) {
        let n = self.n;
        match self.inner_base {
            PipeInner::Flat => {
                let Arena {
                    grows,
                    mk_blobs,
                    ag_sends,
                    ..
                } = arena;
                let blobs = (0..n).map(|i| if i < k { blob } else { 0 });
                Arena::allgather_into(net, grows, mk_blobs, ag_sends, blobs);
            }
            PipeInner::Hier { group } => {
                let Arena {
                    grows, ag_sends, ..
                } = arena;
                let cap = ag_sends.capacity();
                hier_spread_plan(n, group, blob, k, ag_sends, |s| {
                    net.round(s);
                });
                Arena::note(grows, ag_sends.capacity() != cap);
            }
            PipeInner::Tree => {
                let Arena {
                    grows, ag_sends, ..
                } = arena;
                let cap = ag_sends.capacity();
                tree_spread_plan(n, blob, k, ag_sends, |s| {
                    net.round(s);
                });
                Arena::note(grows, ag_sends.capacity() != cap);
            }
        }
    }
}

impl Topology for PipelineRing {
    fn kind(&self) -> TopoKind {
        TopoKind::Pipeline {
            chunks: self.chunks,
            inner: self.inner_base,
        }
    }

    fn nodes(&self) -> usize {
        self.n
    }

    fn reduce_hops(&self) -> usize {
        self.inner.reduce_hops()
    }

    fn dense(
        &self,
        net: &mut RingNet,
        bufs: &mut [Vec<f32>],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert_eq!(bufs.len(), n, "one buffer per node");
        let len = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == len));
        let before = snapshot(net);
        let t0 = net.clock();
        // Per-chunk staging is taken out of the arena so the inner
        // schedule can borrow the rest of it per chunk.
        let mut work = std::mem::take(&mut arena.pl_bufs);
        Arena::slots(&arena.grows, &mut work, n, Vec::new);
        let mut start = 0usize;
        for ci in 0..self.chunks {
            let clen = chunk_size(len, self.chunks, ci);
            let range = start..start + clen;
            start = range.end;
            if clen == 0 {
                continue;
            }
            {
                let grows = &arena.grows;
                let bufs_src: &[Vec<f32>] = bufs;
                exec.map_mut(&mut work[..n], |i, wv| {
                    Arena::note(
                        grows,
                        Arena::refill_slice(wv, &bufs_src[i][range.clone()]),
                    );
                });
            }
            self.inner.dense(net, &mut work[..n], exec, arena);
            let staged: &[Vec<f32>] = &work;
            exec.map_mut(bufs, |i, buf| {
                buf[range.clone()].copy_from_slice(&staged[i]);
            });
        }
        arena.pl_bufs = work;
        report(net, &before, t0, Vec::new())
    }

    fn dense_bytes_only(
        &self,
        net: &mut RingNet,
        coords: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        assert_eq!(net.n_nodes(), self.n);
        let before = snapshot(net);
        let t0 = net.clock();
        for ci in 0..self.chunks {
            let clen = chunk_size(coords, self.chunks, ci);
            if clen == 0 {
                continue;
            }
            self.dense_rounds_only(net, clen, arena);
        }
        report(net, &before, t0, Vec::new())
    }

    fn sparse(
        &self,
        net: &mut RingNet,
        inputs: &[SparseVec],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (Vec<f32>, ReduceReport) {
        // Per-node-support payloads are data-dependent blobs with no
        // prep stage to overlap — delegated verbatim (mod docs).
        self.inner.sparse(net, inputs, exec, arena)
    }

    fn sparse_support(
        &self,
        net: &mut RingNet,
        supports: &[BitMask],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport {
        self.inner.sparse_support(net, supports, exec, arena)
    }

    fn masked(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        values: &[&[f32]],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (BitMask, Vec<f32>, ReduceReport) {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert_eq!(values.len(), n);
        assert!(!masks.is_empty(), "need at least one mask broadcaster");
        let len = masks[0].len();
        assert!(values.iter().all(|v| v.len() == len));
        let k = masks.len().min(n);
        let before = snapshot(net);
        let t0 = net.clock();
        let shared = or_masks(masks, len);
        let mut summed: Vec<f32> = Vec::with_capacity(shared.count());
        let mut work = std::mem::take(&mut arena.pl_bufs);
        let mut support = std::mem::take(&mut arena.mk_support);
        Arena::slots(&arena.grows, &mut work, n, Vec::new);
        let mut set_iter = shared.iter_set().peekable();
        let mut prep_done = 0.0f64;
        let mut start = 0usize;
        for ci in 0..self.chunks {
            let clen = chunk_size(len, self.chunks, ci);
            let end = start + clen;
            start = end;
            // Chunk ci's scoring/compaction overlaps the previous
            // chunk's wire rounds: the wire may not start before this
            // chunk's prep finishes on the (single) compute resource.
            prep_done += prep_seconds(clen);
            let target = t0 + prep_done;
            if net.clock() < target {
                net.advance(target - net.clock());
            }
            if clen == 0 {
                continue;
            }
            // Chunk mask spread (its bit-slice travels as one blob).
            self.spread_rounds_only(net, (clen.div_ceil(8)) as u64, k, arena);
            {
                let grows = &arena.grows;
                Arena::refill(
                    grows,
                    &mut support,
                    std::iter::from_fn(|| set_iter.next_if(|&i| i < end)),
                );
            }
            if support.is_empty() {
                continue;
            }
            // Compact every node's values to this chunk's support and
            // run the wrapped dense schedule over the compacted pieces.
            {
                let grows = &arena.grows;
                let support_ref: &[usize] = &support;
                exec.map_mut(&mut work[..n], |node, wv| {
                    let cap = wv.capacity();
                    wv.clear();
                    wv.extend(support_ref.iter().map(|&i| values[node][i]));
                    Arena::note(grows, wv.capacity() != cap);
                });
            }
            self.inner.dense(net, &mut work[..n], exec, arena);
            summed.extend_from_slice(&work[0]);
        }
        arena.pl_bufs = work;
        arena.mk_support = support;
        let rep = report(net, &before, t0, vec![shared.density(); self.reduce_hops()]);
        (shared, summed, rep)
    }

    fn masked_bytes_only(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        arena: &mut Arena,
    ) -> (BitMask, ReduceReport) {
        let n = self.n;
        assert_eq!(net.n_nodes(), n);
        assert!(!masks.is_empty());
        let len = masks[0].len();
        let k = masks.len().min(n);
        let before = snapshot(net);
        let t0 = net.clock();
        let shared = or_masks(masks, len);
        let mut set_iter = shared.iter_set().peekable();
        let mut prep_done = 0.0f64;
        let mut start = 0usize;
        for ci in 0..self.chunks {
            let clen = chunk_size(len, self.chunks, ci);
            let end = start + clen;
            start = end;
            prep_done += prep_seconds(clen);
            let target = t0 + prep_done;
            if net.clock() < target {
                net.advance(target - net.clock());
            }
            if clen == 0 {
                continue;
            }
            self.spread_rounds_only(net, (clen.div_ceil(8)) as u64, k, arena);
            let mut sup = 0usize;
            while set_iter.next_if(|&i| i < end).is_some() {
                sup += 1;
            }
            if sup == 0 {
                continue;
            }
            self.dense_rounds_only(net, sup, arena);
        }
        let rep = report(net, &before, t0, vec![shared.density(); self.reduce_hops()]);
        (shared, rep)
    }

    fn spread_bytes(
        &self,
        net: &mut RingNet,
        blob_bytes: u64,
        k: usize,
        arena: &mut Arena,
    ) -> ReduceReport {
        // Opaque blobs cannot chunk — delegated verbatim (mod docs).
        self.inner.spread_bytes(net, blob_bytes, k, arena)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;
    use crate::util::rng::Rng;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::gigabit_ethernet(), 1.0)
    }

    fn int_bufs(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..len).map(|_| rng.below(17) as f32 - 8.0).collect())
            .collect()
    }

    #[test]
    fn chunk_supports_partitions_the_count() {
        let len = 1000;
        let mut rng = Rng::new(9);
        let mut mask = BitMask::zeros(len);
        for _ in 0..120 {
            mask.set(rng.below(len));
        }
        for chunks in [1usize, 3, 7, 64] {
            let sups = chunk_supports(&mask, chunks);
            assert_eq!(sups.len(), chunks);
            assert_eq!(sups.iter().sum::<usize>(), mask.count(), "chunks={chunks}");
        }
        // Hand-checked bucketing: bits 0 and 999 land in the outer chunks.
        let mut m2 = BitMask::zeros(len);
        m2.set(0);
        m2.set(999);
        let sups = chunk_supports(&m2, 4);
        assert_eq!(sups, vec![1, 0, 0, 1]);
    }

    #[test]
    fn pipeline_dense_sums_match_wrapped_topology_bitwise() {
        let (n, len) = (6usize, 1003usize);
        let mut rng = Rng::new(21);
        let base = int_bufs(&mut rng, n, len);
        for inner in [PipeInner::Flat, PipeInner::Hier { group: 2 }, PipeInner::Tree] {
            let wrapped = inner.kind().build(n);
            let mut net_w = net(n);
            let mut bufs_w = base.clone();
            wrapped.dense(
                &mut net_w,
                &mut bufs_w,
                &Executor::sequential(),
                &mut Arena::for_nodes(n),
            );
            for chunks in [1usize, 4] {
                let pipe = PipelineRing::new(n, chunks, inner);
                let mut net_p = net(n);
                let mut bufs_p = base.clone();
                pipe.dense(
                    &mut net_p,
                    &mut bufs_p,
                    &Executor::sequential(),
                    &mut Arena::for_nodes(n),
                );
                for (w, p) in bufs_w.iter().zip(&bufs_p) {
                    let wb: Vec<u32> = w.iter().map(|v| v.to_bits()).collect();
                    let pb: Vec<u32> = p.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(wb, pb, "inner={inner:?} chunks={chunks}");
                }
            }
        }
    }

    #[test]
    fn masked_bytes_only_matches_exact_accounting() {
        let (n, len) = (5usize, 4000usize);
        let mut rng = Rng::new(31);
        let mut mask = BitMask::zeros(len);
        for _ in 0..150 {
            mask.set(rng.below(len));
        }
        let values = int_bufs(&mut rng, n, len);
        let refs: Vec<&[f32]> = values.iter().map(|v| v.as_slice()).collect();
        for inner in [PipeInner::Flat, PipeInner::Hier { group: 2 }, PipeInner::Tree] {
            for chunks in [1usize, 3, 8] {
                let pipe = PipelineRing::new(n, chunks, inner);
                let mut net_a = net(n);
                let (shared_a, _, rep_a) = pipe.masked(
                    &mut net_a,
                    &[&mask],
                    &refs,
                    &Executor::sequential(),
                    &mut Arena::for_nodes(n),
                );
                let mut net_b = net(n);
                let (shared_b, rep_b) =
                    pipe.masked_bytes_only(&mut net_b, &[&mask], &mut Arena::for_nodes(n));
                assert_eq!(shared_a, shared_b, "inner={inner:?} chunks={chunks}");
                assert_eq!(
                    rep_a.bytes_per_node, rep_b.bytes_per_node,
                    "inner={inner:?} chunks={chunks}"
                );
                assert_eq!(
                    rep_a.seconds.to_bits(),
                    rep_b.seconds.to_bits(),
                    "inner={inner:?} chunks={chunks}"
                );
                assert_eq!(net_a.rounds(), net_b.rounds());
            }
        }
    }

    #[test]
    fn serial_pipeline_pays_full_prep_upfront() {
        // chunks=1 is the phase-serialized reference: its makespan is
        // the wrapped topology's wire time plus the whole prep pass.
        let (n, len) = (4usize, 50_000usize);
        let mut rng = Rng::new(41);
        let mut mask = BitMask::zeros(len);
        for _ in 0..500 {
            mask.set(rng.below(len));
        }
        let flat = TopoKind::Flat.build(n);
        let mut net_f = net(n);
        let (_, rep_f) = flat.masked_bytes_only(&mut net_f, &[&mask], &mut Arena::for_nodes(n));
        let pipe = PipelineRing::new(n, 1, PipeInner::Flat);
        let mut net_p = net(n);
        let (_, rep_p) = pipe.masked_bytes_only(&mut net_p, &[&mask], &mut Arena::for_nodes(n));
        assert_eq!(rep_f.total_bytes(), rep_p.total_bytes());
        let gap = rep_p.seconds - rep_f.seconds;
        let prep = prep_seconds(len);
        assert!(
            (gap - prep).abs() < 1e-12,
            "serial pipeline should add exactly the prep pass: {gap} vs {prep}"
        );
    }
}
