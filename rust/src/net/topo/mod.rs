//! Topology subsystem — pluggable allreduce communication patterns
//! (DESIGN.md §10).
//!
//! The paper's scaling claim ("breaks the restriction as the node
//! increase") was only testable on a single flat ring; RedSync and DGC
//! (PAPERS.md) both show that the *communication pattern* — flat ring
//! vs. hierarchical rings vs. tree — changes which compression schemes
//! survive at scale. This module extracts the transport behind every
//! schedule into a [`Topology`] trait with three implementations:
//!
//! * [`FlatRing`] — the original single unidirectional ring
//!   (bit-identical to the pre-refactor `ring::*` entry points, which
//!   it delegates to).
//! * [`HierarchicalRing`] — the NCCL-style two-level scheme:
//!   intra-group ring reduce-scatter → gather to group leaders →
//!   inter-group ring over the leaders → intra-group chain broadcast.
//! * [`TreeAllreduce`] — binomial-tree reduce + broadcast, the dense
//!   baseline DGC-style schemes assume.
//! * [`PipelineRing`] — the layer-pipelined wrapper over any of the
//!   above (`pipeline:<chunks>[:<inner>]`, DESIGN.md §11): payload
//!   chunks flow through the inner topology back-to-back while the
//!   virtual clock overlaps each chunk's compression prep with the
//!   previous chunk's wire rounds.
//!
//! All topologies run on the same [`RingNet`] virtual network: a
//! "round" is one synchronous phase in which node `i` transmits
//! `sends[i]` bytes to *some* peer; the round lasts as long as its
//! slowest transfer and the per-node egress counters absorb the bytes.
//! The contract every implementation obeys — determinism, disjoint
//! mutation, coordinator-ordered reduction, per-node tx accounting —
//! is written out in DESIGN.md §10 and enforced bit-exactly by
//! `rust/tests/topology_equivalence.rs`.

mod flat;
mod hier;
pub mod pipeline;
mod tree;

pub use flat::FlatRing;
pub use hier::HierarchicalRing;
pub use pipeline::PipelineRing;
pub use tree::TreeAllreduce;

pub(crate) use hier::{dense_plan as hier_dense_plan, spread_plan as hier_spread_plan};
pub(crate) use tree::{dense_plan as tree_dense_plan, spread_plan as tree_spread_plan};

use super::RingNet;
use crate::ring::{Arena, Executor, ReduceReport};
use crate::sparse::{BitMask, SparseVec};

/// Which topology to run a reduce over — the `--topology` /
/// `RINGIWP_TOPOLOGY` knob (DESIGN.md §10, §11). [`TopoKind::build`]
/// turns a kind into a live [`Topology`] for a given node count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TopoKind {
    /// Single unidirectional ring over all N nodes (the paper's
    /// testbed; the pre-refactor behaviour, bit-identical).
    #[default]
    Flat,
    /// Two-level hierarchy: rings inside fixed-size groups, a ring of
    /// group leaders across groups.
    Hier {
        /// Nodes per group (contiguous blocks; the last group may be
        /// smaller when `group` does not divide N).
        group: usize,
    },
    /// Binomial-tree reduce to node 0 + broadcast back out.
    Tree,
    /// Layer-pipelined wrapper (`pipeline:<chunks>[:<inner>]`,
    /// DESIGN.md §11): splits the payload into `chunks` pieces and
    /// overlaps per-chunk compression prep with the previous chunk's
    /// wire rounds on the inner topology.
    Pipeline {
        /// Number of pipeline chunks (1 = the serial, phase-ordered
        /// reference with the same prep accounting).
        chunks: usize,
        /// The wrapped base topology the chunk rounds run on.
        inner: PipeInner,
    },
}

/// Base (non-pipelined) topology inside a [`TopoKind::Pipeline`]
/// wrapper — pipelines do not nest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeInner {
    /// Flat single ring.
    Flat,
    /// Two-level hierarchy with the given group size.
    Hier {
        /// Nodes per group, as in [`TopoKind::Hier`].
        group: usize,
    },
    /// Binomial tree.
    Tree,
}

impl PipeInner {
    /// The equivalent standalone [`TopoKind`].
    pub fn kind(self) -> TopoKind {
        match self {
            PipeInner::Flat => TopoKind::Flat,
            PipeInner::Hier { group } => TopoKind::Hier { group },
            PipeInner::Tree => TopoKind::Tree,
        }
    }

    /// Downcast a base kind; `None` for [`TopoKind::Pipeline`] (no
    /// nesting).
    pub fn from_kind(kind: TopoKind) -> Option<Self> {
        match kind {
            TopoKind::Flat => Some(PipeInner::Flat),
            TopoKind::Hier { group } => Some(PipeInner::Hier { group }),
            TopoKind::Tree => Some(PipeInner::Tree),
            TopoKind::Pipeline { .. } => None,
        }
    }
}

impl TopoKind {
    /// Parse `flat | hier:<group_size> | tree |
    /// pipeline:<chunks>[:<inner>]` (the CLI / env grammar; the inner
    /// spec defaults to `flat`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        if s == "flat" {
            return Ok(TopoKind::Flat);
        }
        if s == "tree" {
            return Ok(TopoKind::Tree);
        }
        if let Some(g) = s.strip_prefix("hier:") {
            let group: usize = g
                .parse()
                .map_err(|_| anyhow::anyhow!("hier:<group_size> expects an integer, got `{g}`"))?;
            anyhow::ensure!(group >= 1, "hier group size must be >= 1");
            return Ok(TopoKind::Hier { group });
        }
        if let Some(rest) = s.strip_prefix("pipeline:") {
            let (c, inner_s) = match rest.split_once(':') {
                Some((c, inner_s)) => (c, inner_s),
                None => (rest, "flat"),
            };
            let chunks: usize = c.parse().map_err(|_| {
                anyhow::anyhow!("pipeline:<chunks> expects an integer, got `{c}`")
            })?;
            anyhow::ensure!(chunks >= 1, "pipeline chunk count must be >= 1");
            let inner = PipeInner::from_kind(TopoKind::parse(inner_s)?)
                .ok_or_else(|| anyhow::anyhow!("pipeline topologies cannot nest"))?;
            return Ok(TopoKind::Pipeline { chunks, inner });
        }
        anyhow::bail!(
            "unknown topology `{s}` (flat | hier:<group_size> | tree | \
             pipeline:<chunks>[:<inner>])"
        )
    }

    /// Canonical name, re-parseable by [`TopoKind::parse`]
    /// (`flat`, `hier:4`, `tree`, `pipeline:8:flat`).
    pub fn name(&self) -> String {
        match self {
            TopoKind::Flat => "flat".to_string(),
            TopoKind::Hier { group } => format!("hier:{group}"),
            TopoKind::Tree => "tree".to_string(),
            TopoKind::Pipeline { chunks, inner } => {
                format!("pipeline:{chunks}:{}", inner.kind().name())
            }
        }
    }

    /// Reject configurations no topology can run.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            TopoKind::Hier { group } => {
                anyhow::ensure!(*group >= 1, "hier group size must be >= 1");
            }
            TopoKind::Pipeline { chunks, inner } => {
                anyhow::ensure!(*chunks >= 1, "pipeline chunk count must be >= 1");
                inner.kind().validate()?;
            }
            _ => {}
        }
        Ok(())
    }

    /// Environment default: `RINGIWP_TOPOLOGY`, else [`TopoKind::Flat`]
    /// (mirrors `RINGIWP_PARALLELISM` for the experiment harnesses).
    /// A set-but-malformed value panics with the parse error rather
    /// than silently measuring the wrong topology — the same strictness
    /// as the `--topology` flag.
    pub fn from_env() -> Self {
        match std::env::var("RINGIWP_TOPOLOGY") {
            Ok(s) => TopoKind::parse(&s)
                .unwrap_or_else(|e| panic!("RINGIWP_TOPOLOGY={s}: {e}")),
            Err(_) => TopoKind::Flat,
        }
    }

    /// Build the live topology for an `n`-node network (`n >= 2`).
    pub fn build(&self, n: usize) -> Box<dyn Topology> {
        assert!(n >= 2, "a topology needs at least 2 nodes");
        match *self {
            TopoKind::Flat => Box::new(FlatRing::new(n)),
            TopoKind::Hier { group } => Box::new(HierarchicalRing::new(n, group)),
            TopoKind::Tree => Box::new(TreeAllreduce::new(n)),
            TopoKind::Pipeline { chunks, inner } => {
                Box::new(PipelineRing::new(n, chunks, inner))
            }
        }
    }
}

impl std::fmt::Display for TopoKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// One allreduce communication pattern over the virtual network
/// (DESIGN.md §10). Every method:
///
/// * drives the net in synchronous rounds only (`RingNet::round`), so
///   byte and virtual-time accounting stay exact;
/// * mutates per-node state disjointly inside executor regions and
///   performs all cross-node reductions on the coordinating thread in
///   node order, so results are **bit-identical at any `parallelism`**
///   (the DESIGN.md §4 contract, re-stated per topology in §10);
/// * threads its scratch through the caller's [`Arena`], so warmed
///   steady-state loops allocate nothing (DESIGN.md §9).
pub trait Topology: std::fmt::Debug + Send + Sync {
    /// The kind this topology was built from.
    fn kind(&self) -> TopoKind;

    /// Node count the topology was built for (must match the net's).
    fn nodes(&self) -> usize;

    /// Number of reduce-phase hops — the length of
    /// `ReduceReport::density_per_hop` this topology produces
    /// (flat: `N-1`; hier: `(m_max-1) + (G-1)`; tree: `ceil(log2 N)`).
    fn reduce_hops(&self) -> usize;

    /// Dense allreduce: on return every `bufs[i]` holds the element-wise
    /// **sum** across nodes (callers divide by N to average).
    fn dense(
        &self,
        net: &mut RingNet,
        bufs: &mut [Vec<f32>],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport;

    /// Accounting-only dense allreduce: models the exact round sequence
    /// of [`Topology::dense`] on the net without moving values. Byte and
    /// time totals are identical to the exact path over the same
    /// coordinate count — and to the closed-form
    /// `CostModel::topo_dense_*` predictions, bit for bit.
    fn dense_bytes_only(
        &self,
        net: &mut RingNet,
        coords: usize,
        arena: &mut Arena,
    ) -> ReduceReport;

    /// Sparse allreduce of per-node supports (DGC-style). Returns the
    /// summed dense result plus accounting; travelling payloads stay in
    /// sparse wire format, so `density_per_hop` records the
    /// densification trajectory of this topology.
    fn sparse(
        &self,
        net: &mut RingNet,
        inputs: &[SparseVec],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (Vec<f32>, ReduceReport);

    /// Support-only sparse allreduce — the large-model fast path: only
    /// bit-mask supports travel, wire bytes are modelled from each
    /// payload's nnz with the shared codec chooser.
    fn sparse_support(
        &self,
        net: &mut RingNet,
        supports: &[BitMask],
        exec: &Executor,
        arena: &mut Arena,
    ) -> ReduceReport;

    /// Algorithm 1's shared-mask allreduce: spread the `masks` blobs to
    /// every node, OR them into the shared mask, then run the dense
    /// schedule over the values compacted to the shared support.
    fn masked(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        values: &[&[f32]],
        exec: &Executor,
        arena: &mut Arena,
    ) -> (BitMask, Vec<f32>, ReduceReport);

    /// Accounting-only [`Topology::masked`]: mask spread + compacted
    /// dense rounds modelled on the net without moving values.
    fn masked_bytes_only(
        &self,
        net: &mut RingNet,
        masks: &[&BitMask],
        arena: &mut Arena,
    ) -> (BitMask, ReduceReport);

    /// Blob spread (allgather-equivalent) accounting: nodes `0..k` each
    /// hold a `blob_bytes` blob that must reach every node (TernGrad
    /// quantized gradients, Algorithm 1's broadcaster masks). Flat uses
    /// the N-1-round ring rotation; hier gathers to leaders, rings the
    /// leaders, and chain-broadcasts; tree gathers to the root and
    /// broadcasts down.
    fn spread_bytes(
        &self,
        net: &mut RingNet,
        blob_bytes: u64,
        k: usize,
        arena: &mut Arena,
    ) -> ReduceReport;
}

/// OR-combine broadcaster masks into the shared mask (identical on
/// every node and on every topology — the combine is pure data, only
/// the *distribution* of the blobs is topology-specific).
pub(crate) fn or_masks(masks: &[&BitMask], len: usize) -> BitMask {
    let mut shared = BitMask::zeros(len);
    for m in masks {
        assert_eq!(m.len(), len);
        shared.or_assign(m);
    }
    shared
}

/// Compact every node's values to the shared support into the arena's
/// per-node compaction slots (Algorithm 1's phase 3 — shared by the
/// hierarchical and tree masked schedules; the flat shim keeps using
/// `ring::masked`'s own copy verbatim for bit-identity).
pub(crate) fn compact_to_support(
    shared: &BitMask,
    values: &[&[f32]],
    exec: &Executor,
    grows: &std::sync::atomic::AtomicU64,
    mk_support: &mut Vec<usize>,
    mk_compact: &mut Vec<Vec<f32>>,
) {
    let n = values.len();
    Arena::refill(grows, mk_support, shared.iter_set());
    Arena::slots(grows, mk_compact, n, Vec::new);
    let support: &[usize] = mk_support;
    exec.map_mut(&mut mk_compact[..n], |node, c| {
        let cap = c.capacity();
        c.clear();
        c.extend(support.iter().map(|&i| values[node][i]));
        Arena::note(grows, c.capacity() != cap);
    });
}

/// `ceil(log2 n)` — binomial-tree round count for `n >= 1`.
pub(crate) fn ceil_log2(n: usize) -> usize {
    assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

/// Size of chunk `i` of the balanced `chunk_ranges(len, m)` partition,
/// without materializing the table (the net-free cost-model plans use
/// this; `chunk_ranges` assigns `len/m + 1` to the first `len % m`
/// chunks and `len/m` to the rest).
pub(crate) fn chunk_size(len: usize, m: usize, i: usize) -> usize {
    len / m + usize::from(i < len % m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_roundtrips() {
        for (s, k) in [
            ("flat", TopoKind::Flat),
            ("tree", TopoKind::Tree),
            ("hier:4", TopoKind::Hier { group: 4 }),
            ("hier:1", TopoKind::Hier { group: 1 }),
            (
                "pipeline:4",
                TopoKind::Pipeline {
                    chunks: 4,
                    inner: PipeInner::Flat,
                },
            ),
            (
                "pipeline:2:hier:3",
                TopoKind::Pipeline {
                    chunks: 2,
                    inner: PipeInner::Hier { group: 3 },
                },
            ),
            (
                "pipeline:8:tree",
                TopoKind::Pipeline {
                    chunks: 8,
                    inner: PipeInner::Tree,
                },
            ),
        ] {
            let parsed = TopoKind::parse(s).unwrap();
            assert_eq!(parsed, k);
            assert_eq!(TopoKind::parse(&parsed.name()).unwrap(), parsed);
        }
        assert!(TopoKind::parse("ring").is_err());
        assert!(TopoKind::parse("hier:").is_err());
        assert!(TopoKind::parse("hier:0").is_err());
        assert!(TopoKind::parse("hier:x").is_err());
        assert!(TopoKind::parse("pipeline:0").is_err());
        assert!(TopoKind::parse("pipeline:x").is_err());
        assert!(TopoKind::parse("pipeline:2:pipeline:2:flat").is_err());
    }

    #[test]
    fn build_produces_matching_kind() {
        for kind in [
            TopoKind::Flat,
            TopoKind::Hier { group: 3 },
            TopoKind::Tree,
            TopoKind::Pipeline {
                chunks: 4,
                inner: PipeInner::Hier { group: 3 },
            },
        ] {
            let t = kind.build(8);
            assert_eq!(t.kind(), kind);
            assert_eq!(t.nodes(), 8);
            assert!(t.reduce_hops() >= 1);
        }
    }

    #[test]
    fn reduce_hop_counts() {
        assert_eq!(TopoKind::Flat.build(8).reduce_hops(), 7);
        assert_eq!(TopoKind::Tree.build(8).reduce_hops(), 3);
        assert_eq!(TopoKind::Tree.build(9).reduce_hops(), 4);
        // hier: (m_max - 1) + (G - 1) = (4-1) + (2-1) = 4.
        assert_eq!(TopoKind::Hier { group: 4 }.build(8).reduce_hops(), 4);
        // group 1: every node is a leader -> pure flat ring hop count.
        assert_eq!(TopoKind::Hier { group: 1 }.build(8).reduce_hops(), 7);
    }

    #[test]
    fn ceil_log2_values() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(96), 7);
    }

    #[test]
    fn chunk_size_matches_chunk_ranges() {
        for (len, m) in [(10usize, 3usize), (9, 3), (2, 4), (0, 5), (6000, 7)] {
            let r = crate::ring::chunk_ranges(len, m);
            for (i, c) in r.iter().enumerate() {
                assert_eq!(chunk_size(len, m, i), c.len(), "len={len} m={m} i={i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn build_rejects_degenerate() {
        let _ = TopoKind::Flat.build(1);
    }
}
