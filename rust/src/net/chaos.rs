//! Deterministic seeded fault injection for elastic rings (DESIGN.md
//! §15).
//!
//! A [`ChaosPlan`] is a *schedule*: a sorted list of membership and
//! link events ([`ChaosEvent`]) the engines replay at fixed step
//! indices, plus the [`RecoveryMode`] governing what happens to a
//! crashed node's pending residual state. Plans come from three
//! equivalent sources — a grammar string (`--chaos` /
//! `RINGIWP_CHAOS`), a seed (`--chaos-seed N` →
//! [`ChaosPlan::generate`]), or code — and the grammar round-trips
//! through [`std::fmt::Display`], so `ringiwp chaos --seed N` can print
//! the exact plan it ran.
//!
//! Everything here is pure data + SplitMix64 draws: the same seed
//! yields the same plan on every run, machine, and transport, which is
//! what makes the chaos suites goldenable (same seed ⇒ bit-identical
//! report streams).
//!
//! Grammar (comma-separated tokens, steps are absolute step indices):
//!
//! ```text
//!   mode=handoff | mode=rescale      recovery mode (default handoff)
//!   crash@<step>:<node>              node leaves before this step runs
//!   slow@<step>:<node>:<factor>      node's link degrades by ×factor
//!   heal@<step>                      all links reset to the base link
//!   join@<step>                      one node joins before this step
//! ```
//!
//! Node indices refer to the membership *at that step* — after all
//! earlier crashes and joins have been applied (ring positions shift
//! down on a crash, exactly like the engine's survivor re-ring).
//!
//! Since DESIGN.md §16 the grammar also accepts the *wire-fault*
//! tokens of [`net::wire::fault`](crate::net::wire::fault)
//! (`flip@<frame>:<edge>`, `trunc@…`, `drop@…`, `dup@…`,
//! `delay@<frame>:<edge>:<ms>`, `reset@…`, plus `attempts=` /
//! `seed=`) inline, collected into [`ChaosPlan::wire`] — so one
//! `--chaos` string can schedule membership churn *and* byte-level
//! frame corruption. Wire faults only apply on socket transports; the
//! sim oracle ignores them (its results are the bit-exact target the
//! recovered wire run must reproduce).

use super::link::LinkSpec;
use super::wire::FaultPlan;
use crate::util::rng::Rng;
use std::fmt;

/// One scheduled fault or membership event. `step` is the engine step
/// index the event fires *before* (the step then runs on the post-event
/// ring).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChaosEvent {
    /// Node `node` crashes: it leaves the ring and survivors re-ring.
    Crash {
        /// Step the crash precedes.
        step: usize,
        /// Ring position of the crashing node at that step.
        node: usize,
    },
    /// Node `node`'s link degrades (straggler / congested hop):
    /// bandwidth divides by `factor`, latency multiplies by `factor`.
    Slow {
        /// Step the degradation precedes.
        step: usize,
        /// Ring position of the degraded node at that step.
        node: usize,
        /// Degradation factor (> 1 slows the hop down).
        factor: f64,
    },
    /// All links reset to the base link (partition heals).
    Heal {
        /// Step the heal precedes.
        step: usize,
    },
    /// One fresh node joins at the end of the ring (warm-up re-entry).
    Join {
        /// Step the join precedes.
        step: usize,
    },
}

impl ChaosEvent {
    /// The step index this event fires before.
    pub fn step(&self) -> usize {
        match *self {
            ChaosEvent::Crash { step, .. }
            | ChaosEvent::Slow { step, .. }
            | ChaosEvent::Heal { step }
            | ChaosEvent::Join { step } => step,
        }
    }
}

impl fmt::Display for ChaosEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ChaosEvent::Crash { step, node } => write!(f, "crash@{step}:{node}"),
            ChaosEvent::Slow { step, node, factor } => {
                write!(f, "slow@{step}:{node}:{factor}")
            }
            ChaosEvent::Heal { step } => write!(f, "heal@{step}"),
            ChaosEvent::Join { step } => write!(f, "join@{step}"),
        }
    }
}

/// What happens to a crashed node's pending residual state (DESIGN.md
/// §15): DGC-style residual accumulation makes membership stateful —
/// the departing node's unsent residuals are pending gradient mass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryMode {
    /// Hand the departing store to the next surviving neighbor (merge):
    /// total pending mass is conserved exactly (modulo f32 addition).
    #[default]
    Handoff,
    /// Drop the departing store and rescale every survivor's pending
    /// state by N/(N−1), preserving the *expected* gradient sum.
    DropRescale,
}

impl RecoveryMode {
    /// Parse a mode name (`handoff` | `rescale`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "handoff" => Some(RecoveryMode::Handoff),
            "rescale" | "drop-rescale" => Some(RecoveryMode::DropRescale),
            _ => None,
        }
    }

    /// Canonical grammar name.
    pub fn name(self) -> &'static str {
        match self {
            RecoveryMode::Handoff => "handoff",
            RecoveryMode::DropRescale => "rescale",
        }
    }
}

impl fmt::Display for RecoveryMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A deterministic fault-injection schedule: events sorted by step
/// (stable within a step) plus the recovery mode.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChaosPlan {
    /// Scheduled events, sorted by [`ChaosEvent::step`].
    pub events: Vec<ChaosEvent>,
    /// Recovery protocol for crashed nodes' residual state.
    pub mode: RecoveryMode,
    /// Byte-level wire faults riding along (socket transports only;
    /// empty by default so membership-only plans are unchanged).
    pub wire: FaultPlan,
}

impl ChaosPlan {
    /// The empty (no-fault) plan — engines running it are bit-identical
    /// to engines with no plan at all (pinned by
    /// `chaos_equivalence.rs`).
    pub fn none() -> Self {
        ChaosPlan::default()
    }

    /// True when the plan schedules nothing (no membership events and
    /// no wire faults).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.wire.is_empty()
    }

    /// Largest step any event fires before (0 for an empty plan).
    pub fn max_step(&self) -> usize {
        self.events.iter().map(|e| e.step()).max().unwrap_or(0)
    }

    /// Parse the grammar (module docs). Events are stably sorted by
    /// step, so `parse(plan.to_string()) == plan` for any valid plan.
    /// Wire-fault tokens (`flip@…`, `attempts=…`, …) are routed to the
    /// embedded [`FaultPlan`] grammar.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut plan = ChaosPlan::default();
        let mut wire_toks: Vec<&str> = Vec::new();
        for raw in s.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            if let Some(m) = tok.strip_prefix("mode=") {
                plan.mode = RecoveryMode::parse(m)
                    .ok_or_else(|| format!("chaos: unknown mode '{m}' (handoff|rescale)"))?;
                continue;
            }
            let is_wire = tok.starts_with("attempts=")
                || tok.starts_with("seed=")
                || matches!(
                    tok.split('@').next(),
                    Some("flip" | "trunc" | "drop" | "dup" | "delay" | "reset")
                );
            if is_wire {
                wire_toks.push(tok);
                continue;
            }
            let (kind, rest) = tok
                .split_once('@')
                .ok_or_else(|| format!("chaos: bad token '{tok}' (want kind@args)"))?;
            let fields: Vec<&str> = rest.split(':').collect();
            let num = |i: usize| -> Result<usize, String> {
                fields
                    .get(i)
                    .and_then(|f| f.parse::<usize>().ok())
                    .ok_or_else(|| format!("chaos: bad field {i} in '{tok}'"))
            };
            let ev = match (kind, fields.len()) {
                ("crash", 2) => ChaosEvent::Crash {
                    step: num(0)?,
                    node: num(1)?,
                },
                ("slow", 3) => ChaosEvent::Slow {
                    step: num(0)?,
                    node: num(1)?,
                    factor: fields[2]
                        .parse::<f64>()
                        .ok()
                        .filter(|f| f.is_finite() && *f >= 1.0)
                        .ok_or_else(|| format!("chaos: bad factor in '{tok}' (want ≥ 1)"))?,
                },
                ("heal", 1) => ChaosEvent::Heal { step: num(0)? },
                ("join", 1) => ChaosEvent::Join { step: num(0)? },
                _ => return Err(format!("chaos: unknown event '{tok}'")),
            };
            plan.events.push(ev);
        }
        plan.events.sort_by_key(|e| e.step());
        if !wire_toks.is_empty() {
            plan.wire = FaultPlan::parse(&wire_toks.join(","))?;
        }
        Ok(plan)
    }

    /// Seeded schedule over `steps` engine steps starting from `nodes`
    /// ring members: a mix of crashes (membership floor 3 survivors),
    /// stragglers (integral factors, so the grammar round-trips
    /// exactly), heals, and joins (at most 2 above the starting size).
    /// Same `(seed, nodes, steps)` ⇒ the same plan, always.
    pub fn generate(seed: u64, nodes: usize, steps: usize) -> Self {
        assert!(nodes >= 2, "chaos: need at least 2 nodes");
        let mut rng = Rng::new(seed ^ 0xC4A0_55ED);
        let mut n = nodes;
        let mut events = Vec::new();
        // Step 0 stays clean: every run gets one fault-free baseline
        // step before the schedule starts firing.
        for step in 1..steps {
            let roll = rng.uniform();
            if roll < 0.20 {
                if n > 3 {
                    events.push(ChaosEvent::Crash {
                        step,
                        node: rng.below(n),
                    });
                    n -= 1;
                }
            } else if roll < 0.45 {
                events.push(ChaosEvent::Slow {
                    step,
                    node: rng.below(n),
                    factor: (2 + rng.below(9)) as f64,
                });
            } else if roll < 0.55 {
                events.push(ChaosEvent::Heal { step });
            } else if roll < 0.70 && n < nodes + 2 {
                events.push(ChaosEvent::Join { step });
                n += 1;
            }
        }
        ChaosPlan {
            events,
            mode: RecoveryMode::default(),
            // Wire faults ride along from a decorrelated stream
            // (appended after the membership rolls, so adding them
            // left every pre-§16 generated schedule byte-identical).
            // Frame indices stay small relative to a step's traffic so
            // the scheduled faults actually fire early in the run.
            wire: FaultPlan::generate(seed, nodes, (steps as u64).max(2) * 4),
        }
    }

    /// Plan from the `RINGIWP_CHAOS` grammar env var, if set. A bad
    /// grammar panics with the parse error — a silently ignored chaos
    /// plan would report fault-free results as fault-tolerant ones.
    pub fn from_env() -> Option<Self> {
        std::env::var("RINGIWP_CHAOS")
            .ok()
            .filter(|s| !s.trim().is_empty())
            .map(|s| Self::parse(&s).unwrap_or_else(|e| panic!("RINGIWP_CHAOS: {e}")))
    }

    /// Check the schedule against a starting ring size: every event's
    /// node index must exist in the membership at its step, and a crash
    /// must leave at least 2 survivors (the smallest ring the engines
    /// support — `remove_node` refuses below 3 members).
    pub fn validate(&self, nodes: usize) -> Result<(), String> {
        let mut n = nodes;
        for ev in &self.events {
            match *ev {
                ChaosEvent::Crash { step, node } => {
                    if n <= 2 {
                        return Err(format!(
                            "chaos: crash@{step} would leave fewer than 2 nodes"
                        ));
                    }
                    if node >= n {
                        return Err(format!(
                            "chaos: crash@{step}:{node} out of range (membership {n})"
                        ));
                    }
                    n -= 1;
                }
                ChaosEvent::Slow { step, node, .. } => {
                    if node >= n {
                        return Err(format!(
                            "chaos: slow@{step}:{node} out of range (membership {n})"
                        ));
                    }
                }
                ChaosEvent::Heal { .. } => {}
                ChaosEvent::Join { .. } => n += 1,
            }
        }
        self.wire.validate()
    }

    /// Events firing before `step`, in schedule order.
    pub fn events_at(&self, step: usize) -> impl Iterator<Item = &ChaosEvent> + '_ {
        self.events.iter().filter(move |e| e.step() == step)
    }
}

impl fmt::Display for ChaosPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "mode={}", self.mode)?;
        for ev in &self.events {
            write!(f, ",{ev}")?;
        }
        if !self.wire.is_empty() {
            write!(f, ",{}", self.wire)?;
        }
        Ok(())
    }
}

/// A link degraded by `factor`: bandwidth divides, latency multiplies.
/// Factor 1 returns the base link unchanged.
pub fn degrade(base: LinkSpec, factor: f64) -> LinkSpec {
    assert!(factor >= 1.0, "chaos: degrade factor must be ≥ 1");
    LinkSpec::new(base.bandwidth_bps / factor, base.latency_s * factor)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_roundtrips_through_display() {
        let s = "mode=rescale,crash@3:1,slow@4:0:2.5,heal@6,join@7";
        let plan = ChaosPlan::parse(s).unwrap();
        assert_eq!(plan.mode, RecoveryMode::DropRescale);
        assert_eq!(plan.events.len(), 4);
        assert_eq!(ChaosPlan::parse(&plan.to_string()).unwrap(), plan);
    }

    #[test]
    fn parse_sorts_events_stably_by_step() {
        let plan = ChaosPlan::parse("heal@5,crash@2:0,slow@5:1:3").unwrap();
        assert_eq!(plan.events[0], ChaosEvent::Crash { step: 2, node: 0 });
        // Same-step order is the listed order (heal before slow).
        assert_eq!(plan.events[1], ChaosEvent::Heal { step: 5 });
        assert_eq!(
            plan.events[2],
            ChaosEvent::Slow {
                step: 5,
                node: 1,
                factor: 3.0
            }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "crash@3",          // missing node
            "slow@1:0",         // missing factor
            "slow@1:0:0.5",     // factor below 1
            "mode=fancy",       // unknown mode
            "reboot@4",         // unknown event
            "crash@x:1",        // non-numeric step
        ] {
            assert!(ChaosPlan::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn generate_is_deterministic_and_valid() {
        for seed in [0u64, 1, 7, 42, 12345] {
            let a = ChaosPlan::generate(seed, 5, 12);
            let b = ChaosPlan::generate(seed, 5, 12);
            assert_eq!(a, b, "seed {seed} not deterministic");
            a.validate(5).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            // The generated grammar round-trips (integral slow factors).
            assert_eq!(ChaosPlan::parse(&a.to_string()).unwrap(), a);
        }
        assert_ne!(
            ChaosPlan::generate(1, 5, 12),
            ChaosPlan::generate(2, 5, 12),
            "different seeds should differ"
        );
    }

    #[test]
    fn generate_leaves_step_zero_clean() {
        for seed in 0..20u64 {
            let plan = ChaosPlan::generate(seed, 5, 10);
            assert!(plan.events_at(0).next().is_none(), "seed {seed}");
        }
    }

    #[test]
    fn validate_tracks_membership() {
        // 4 nodes: one crash ok (→3), a second refused (would leave 2
        // pre-crash members, below the engine floor).
        assert!(ChaosPlan::parse("crash@1:3").unwrap().validate(4).is_ok());
        assert!(ChaosPlan::parse("crash@1:3,crash@2:2")
            .unwrap()
            .validate(4)
            .is_err());
        // A join lifts the membership back over the floor.
        assert!(ChaosPlan::parse("crash@1:3,join@2,crash@3:2")
            .unwrap()
            .validate(4)
            .is_ok());
        // Node index must exist at its step.
        assert!(ChaosPlan::parse("crash@1:0,slow@2:3:2")
            .unwrap()
            .validate(4)
            .is_err());
    }

    #[test]
    fn degrade_scales_both_axes() {
        let base = LinkSpec::new(1000.0, 0.1);
        let d = degrade(base, 4.0);
        assert_eq!(d.bandwidth_bps, 250.0);
        assert_eq!(d.latency_s, 0.4);
        // ×1 is the identity.
        let id = degrade(base, 1.0);
        assert_eq!(id.bandwidth_bps, base.bandwidth_bps);
        assert_eq!(id.latency_s, base.latency_s);
    }

    #[test]
    fn events_at_filters_by_step() {
        let plan = ChaosPlan::parse("crash@2:0,slow@2:1:2,heal@4").unwrap();
        assert_eq!(plan.events_at(2).count(), 2);
        assert_eq!(plan.events_at(3).count(), 0);
        assert_eq!(plan.events_at(4).count(), 1);
        assert_eq!(plan.max_step(), 4);
        assert!(!plan.is_empty());
        assert!(ChaosPlan::none().is_empty());
    }
}
