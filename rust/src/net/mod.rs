//! Virtual-time network simulator.
//!
//! The paper's testbed is a 96-node gigabit-Ethernet ring without
//! Infiniband; its bandwidth results (Figs. 7/8, the motivation for the
//! whole method) are statements about *bytes on the wire over time*.
//! This module accounts those bytes exactly under a virtual clock:
//!
//! * every directed link has a bandwidth (bytes/s) and latency (s),
//! * communication proceeds in synchronous ring *rounds* (the natural
//!   granularity of ring all-reduce: everyone sends one chunk to their
//!   successor); a round lasts as long as its slowest transfer — the
//!   paper's "the limit of the system is determined only by the slowest
//!   connection",
//! * per-node transmit traces are bucketed over virtual time to produce
//!   the KB/s plots of Figs. 7/8.
//!
//! The virtual network is also the bit-exact oracle for the real
//! socket transport (`net::wire`, DESIGN.md §13): `WireEngine` runs
//! the same accounting through this module while moving actual frames
//! over Unix domain sockets or loopback TCP.

pub mod chaos;
pub mod cost;
pub mod link;
pub mod topo;
pub mod trace;
pub mod tuner;
pub mod wire;

pub use chaos::{ChaosEvent, ChaosPlan, RecoveryMode};
pub use cost::CostModel;
pub use link::LinkSpec;
pub use topo::{PipeInner, TopoKind, Topology};
pub use trace::{DecisionRow, DecisionTrace, Trace};
pub use tuner::{Decision, Observation, Strategy, Tuner, TunerMode, WirePick};
pub use wire::{
    FaultKind, FaultPlan, RecoveryCounters, RecoveryStats, RingOpts, TransportKind, WireError,
    WireRing,
};

use std::sync::atomic::{AtomicU64, Ordering};

/// A unidirectional ring of `n` nodes with homogeneous links.
/// Node `i` transmits to `(i+1) % n`.
///
/// Byte accounting is **thread-safe**: the per-node transmit counters
/// are atomics behind [`RingNet::record_tx`] (`&self`), so per-node
/// totals stay exact and order-independent (u64 addition commutes) no
/// matter which thread attributes them. Today every schedule drives
/// them from the coordinating thread via [`RingNet::round`] — the
/// parallel executor (`ring::exec`, DESIGN.md §4) keeps all `round`
/// calls sequential — but the counters are the seam the ROADMAP's
/// async-transport direction plugs into without changing accounting
/// semantics. The clock and bucketed trace advance only under
/// `&mut self`.
#[derive(Debug)]
pub struct RingNet {
    n: usize,
    spec: LinkSpec,
    /// Per-hop link parameters (entry `i` = node `i`'s outgoing edge),
    /// the heterogeneous-link seam of ROADMAP item 3. `None` means
    /// every hop uses `spec` — bit-for-bit today's uniform behavior
    /// (and a uniform `Some` table is equally bit-identical, which the
    /// wire handshake relies on).
    links: Option<Vec<LinkSpec>>,
    clock: f64,
    /// Cumulative bytes sent on each node's outgoing link (atomic so
    /// concurrent per-node senders can account without a lock).
    tx_bytes: Vec<AtomicU64>,
    /// Per-node transmit trace (virtual-time bucketed).
    trace: Trace,
    rounds: u64,
}

impl Clone for RingNet {
    fn clone(&self) -> Self {
        RingNet {
            n: self.n,
            spec: self.spec,
            links: self.links.clone(),
            clock: self.clock,
            tx_bytes: self
                .tx_bytes
                .iter()
                .map(|b| AtomicU64::new(b.load(Ordering::Relaxed)))
                .collect(),
            trace: self.trace.clone(),
            rounds: self.rounds,
        }
    }
}

impl RingNet {
    /// Build an `n`-node ring with homogeneous `spec` links; transmit
    /// traces are bucketed every `trace_bucket_s` virtual seconds.
    pub fn new(n: usize, spec: LinkSpec, trace_bucket_s: f64) -> Self {
        assert!(n >= 2, "a ring needs at least 2 nodes");
        RingNet {
            n,
            spec,
            links: None,
            clock: 0.0,
            tx_bytes: (0..n).map(|_| AtomicU64::new(0)).collect(),
            trace: Trace::new(n, trace_bucket_s),
            rounds: 0,
        }
    }

    /// Build a ring with an explicit per-hop link table (entry `i` =
    /// node `i`'s outgoing edge). `links[0]` doubles as the headline
    /// `spec` for reporting.
    pub fn with_links(links: Vec<LinkSpec>, trace_bucket_s: f64) -> Self {
        let mut net = Self::new(links.len(), links[0], trace_bucket_s);
        net.links = Some(links);
        net
    }

    /// Install a per-hop link table (e.g. from the wire handshake,
    /// DESIGN.md §13). Must cover every hop.
    pub fn set_links(&mut self, links: Vec<LinkSpec>) {
        assert_eq!(links.len(), self.n, "one link per ring hop");
        self.links = Some(links);
    }

    /// Link parameters of node `node`'s outgoing edge.
    #[inline]
    pub fn link_of(&self, node: usize) -> &LinkSpec {
        match &self.links {
            Some(ls) => &ls[node],
            None => &self.spec,
        }
    }

    /// Ring size.
    pub fn n_nodes(&self) -> usize {
        self.n
    }

    /// Current virtual time in seconds.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Number of synchronous ring rounds executed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// The headline link parameters of this ring (the uniform link,
    /// or hop 0 when a per-hop table is installed).
    pub fn spec(&self) -> &LinkSpec {
        &self.spec
    }

    /// Attribute `bytes` to `node`'s outgoing link counter. Safe to call
    /// from executor worker threads concurrently (`&self`, atomic add);
    /// the caller remains responsible for advancing the clock/trace on
    /// the coordinating thread ([`RingNet::round`] does both).
    #[inline]
    pub fn record_tx(&self, node: usize, bytes: u64) {
        self.tx_bytes[node].fetch_add(bytes, Ordering::Relaxed);
    }

    /// One synchronous ring round: node `i` sends `bytes[i]` to its
    /// successor. Advances the clock by the slowest transfer and records
    /// traffic. Returns the round duration in virtual seconds.
    pub fn round(&mut self, bytes: &[u64]) -> f64 {
        assert_eq!(bytes.len(), self.n);
        let dur = bytes
            .iter()
            .enumerate()
            .map(|(i, &b)| self.link_of(i).transfer_time(b))
            .fold(0.0f64, f64::max);
        for (i, &b) in bytes.iter().enumerate() {
            if b > 0 {
                self.record_tx(i, b);
                // Spread the bytes over this node's actual transfer window.
                self.trace
                    .add(self.clock, self.link_of(i).transfer_time(b), i, b);
            }
        }
        self.clock += dur;
        self.rounds += 1;
        dur
    }

    /// Uniform round: every node sends the same byte count.
    pub fn uniform_round(&mut self, bytes_per_node: u64) -> f64 {
        let v = vec![bytes_per_node; self.n];
        self.round(&v)
    }

    /// Ring AllGather of per-node blobs: N-1 rounds; in round r node i
    /// forwards the blob originated by node (i - r). Returns total time.
    /// (This is Algorithm 1's mask AllGather when blobs are bitmask bytes.)
    pub fn allgather(&mut self, blob_bytes: &[u64]) -> f64 {
        self.allgather_with(blob_bytes, &mut Vec::new())
    }

    /// [`RingNet::allgather`] with a caller-owned per-round send buffer
    /// (arena reuse: the steady-state engines allgather every step and
    /// the per-round rotation table is their only residual allocation).
    pub fn allgather_with(&mut self, blob_bytes: &[u64], sends: &mut Vec<u64>) -> f64 {
        assert_eq!(blob_bytes.len(), self.n);
        let mut total = 0.0;
        for r in 0..self.n - 1 {
            sends.clear();
            sends.extend((0..self.n).map(|i| blob_bytes[(i + self.n - r) % self.n]));
            total += self.round(sends);
        }
        total
    }

    /// Advance the clock without traffic (e.g. compute phase) so traces
    /// show idle gaps like the paper's I/O plots between steps.
    pub fn advance(&mut self, seconds: f64) {
        assert!(seconds >= 0.0);
        self.clock += seconds;
    }

    /// Total bytes transmitted by one node.
    pub fn node_tx_bytes(&self, node: usize) -> u64 {
        self.tx_bytes[node].load(Ordering::Relaxed)
    }

    /// Total bytes across all links.
    pub fn total_bytes(&self) -> u64 {
        self.tx_bytes
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum()
    }

    /// The per-node transmit trace accumulated so far.
    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Reset counters/clock but keep topology (between experiment arms).
    pub fn reset(&mut self) {
        self.clock = 0.0;
        self.rounds = 0;
        self.tx_bytes
            .iter()
            .for_each(|b| b.store(0, Ordering::Relaxed));
        self.trace.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gigabit() -> LinkSpec {
        LinkSpec::gigabit_ethernet()
    }

    #[test]
    fn round_time_is_slowest_link() {
        let mut net = RingNet::new(4, LinkSpec::new(1000.0, 0.0), 1.0);
        let dur = net.round(&[100, 500, 1000, 0]);
        assert!((dur - 1.0).abs() < 1e-9); // 1000 bytes / 1000 Bps
        assert!((net.clock() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_adds_to_transfers() {
        let mut net = RingNet::new(2, LinkSpec::new(1000.0, 0.5), 1.0);
        let dur = net.round(&[1000, 1000]);
        assert!((dur - 1.5).abs() < 1e-9);
    }

    #[test]
    fn byte_accounting() {
        let mut net = RingNet::new(3, gigabit(), 1.0);
        net.round(&[10, 20, 30]);
        net.round(&[1, 2, 3]);
        assert_eq!(net.node_tx_bytes(0), 11);
        assert_eq!(net.node_tx_bytes(2), 33);
        assert_eq!(net.total_bytes(), 66);
        assert_eq!(net.rounds(), 2);
    }

    #[test]
    fn allgather_moves_each_blob_n_minus_1_times() {
        let mut net = RingNet::new(4, gigabit(), 1.0);
        net.allgather(&[100, 200, 300, 400]);
        // Every blob crosses N-1 links: total = 3 * (100+200+300+400).
        assert_eq!(net.total_bytes(), 3 * 1000);
        assert_eq!(net.rounds(), 3);
    }

    #[test]
    fn allgather_with_reuses_buffer_and_matches() {
        let mut net_a = RingNet::new(5, gigabit(), 1.0);
        let t_a = net_a.allgather(&[10, 0, 30, 0, 50]);
        let mut net_b = RingNet::new(5, gigabit(), 1.0);
        let mut sends = Vec::new();
        let t_b = net_b.allgather_with(&[10, 0, 30, 0, 50], &mut sends);
        assert_eq!(t_a.to_bits(), t_b.to_bits());
        assert_eq!(net_a.total_bytes(), net_b.total_bytes());
        let cap = sends.capacity();
        net_b.allgather_with(&[10, 0, 30, 0, 50], &mut sends);
        assert_eq!(sends.capacity(), cap, "send buffer must be reused");
    }

    #[test]
    fn reset_clears_state() {
        let mut net = RingNet::new(2, gigabit(), 1.0);
        net.uniform_round(1_000_000);
        net.reset();
        assert_eq!(net.total_bytes(), 0);
        assert_eq!(net.clock(), 0.0);
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn rejects_degenerate_ring() {
        let _ = RingNet::new(1, gigabit(), 1.0);
    }

    #[test]
    fn uniform_link_table_is_bit_identical_to_global_link() {
        let spec = gigabit();
        let mut plain = RingNet::new(4, spec, 1.0);
        let mut tabled = RingNet::with_links(vec![spec; 4], 1.0);
        let mut a = 0.0f64;
        let mut b = 0.0f64;
        for bytes in [[10u64, 2000, 0, 77], [5, 5, 5, 5]] {
            a += plain.round(&bytes);
            b += tabled.round(&bytes);
        }
        a += plain.allgather(&[100, 200, 300, 400]);
        b += tabled.allgather(&[100, 200, 300, 400]);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(plain.clock().to_bits(), tabled.clock().to_bits());
        assert_eq!(plain.total_bytes(), tabled.total_bytes());
    }

    #[test]
    fn heterogeneous_links_slow_their_own_hop() {
        // Hop 1 is 10x slower: a round where node 1 sends dominates.
        let fast = LinkSpec::new(1000.0, 0.0);
        let slow = LinkSpec::new(100.0, 0.0);
        let mut net = RingNet::with_links(vec![fast, slow, fast], 1.0);
        let dur = net.round(&[100, 100, 100]);
        assert!((dur - 1.0).abs() < 1e-9, "{dur}"); // 100 B / 100 Bps
        assert_eq!(net.link_of(1).bandwidth_bps, 100.0);
        let mut uniform = RingNet::new(3, fast, 1.0);
        uniform.set_links(vec![fast, slow, fast]);
        assert_eq!(uniform.round(&[100, 100, 100]).to_bits(), dur.to_bits());
    }

    #[test]
    #[should_panic(expected = "one link per ring hop")]
    fn set_links_rejects_wrong_arity() {
        let mut net = RingNet::new(3, gigabit(), 1.0);
        net.set_links(vec![gigabit(); 2]);
    }

    #[test]
    fn record_tx_is_thread_safe_and_exact() {
        let net = RingNet::new(4, gigabit(), 1.0);
        std::thread::scope(|s| {
            for t in 0..4 {
                let net = &net;
                s.spawn(move || {
                    for _ in 0..1000 {
                        net.record_tx(t, 3);
                    }
                });
            }
        });
        for node in 0..4 {
            assert_eq!(net.node_tx_bytes(node), 3000);
        }
        assert_eq!(net.total_bytes(), 12_000);
    }
}
