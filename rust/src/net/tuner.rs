//! Online protocol autotuner (DESIGN.md §14).
//!
//! Every IWP step already measures the two quantities the closed-form
//! [`CostModel`] needs — the shared-support size (`support_nnz`) and
//! the payload length — and PR 2–6 pinned the model bit-exact against
//! simulation for every topology × wire format. The tuner closes the
//! loop: each step it prices the whole strategy grid (wire format ×
//! topology × pipeline chunk count) against the *observed* support and
//! switches the live strategy to the argmin predicted wire-seconds,
//! with hysteresis to avoid thrashing and a decision trace
//! ([`DecisionTrace`](super::trace::DecisionTrace)) recording every
//! considered candidate for offline audit.
//!
//! The candidate objective is the **prep-inclusive makespan**: the
//! fused selection pass (`pipeline::prep_seconds`) runs every step no
//! matter which strategy wins — the tuner's own observation depends on
//! it — so every candidate is priced as one prep pass plus its wire
//! rounds. Pipelined masked candidates overlap the prep with earlier
//! chunks' rounds ([`CostModel::pipelined_masked_seconds`]); the
//! non-pipelined formats (dense / sparse-allgather / `+tern` /
//! `+q:<bits>`) pay it up front. `masked` over `pipeline:1:<base>` *is* the serial
//! prep-then-rounds reference, so the grid needs no separate
//! un-pipelined masked rows.
//!
//! Predictions equal the engine's measured `wire_seconds` on a fresh
//! clock (the cross-validation contract of DESIGN.md §10–§11); mid-run
//! the clock delta can differ from the prediction in the last ulp
//! because f64 addition does not reassociate across a moving origin.
//! Every *decision* is a pure function of the observation, so picks
//! are deterministic across `--parallelism` and transports.

use super::topo::{pipeline, PipeInner, Topology};
use super::trace::{DecisionRow, DecisionTrace};
use super::{CostModel, LinkSpec, TopoKind};
use crate::compress::quant::QuantWidth;
use crate::sparse::BitMask;

/// How the tuner participates in a run (`--tuner`, `RINGIWP_TUNER`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TunerMode {
    /// No tuner: the configured static strategy runs (the default).
    #[default]
    Off,
    /// Decide *and* execute: each step runs the argmin strategy.
    On,
    /// Decide but do not act: the static strategy executes
    /// (bit-identical to [`TunerMode::Off`]) while the decision trace
    /// records what the tuner *would* have picked — the audit mode.
    LogOnly,
}

impl TunerMode {
    /// Parse `off | on | log-only`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim() {
            "off" => Ok(TunerMode::Off),
            "on" => Ok(TunerMode::On),
            "log-only" => Ok(TunerMode::LogOnly),
            other => anyhow::bail!(
                "unknown tuner mode '{other}' (expected off | on | log-only)"
            ),
        }
    }

    /// Canonical name (round-trips through [`TunerMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            TunerMode::Off => "off",
            TunerMode::On => "on",
            TunerMode::LogOnly => "log-only",
        }
    }

    /// Read `RINGIWP_TUNER` (experiment harnesses); unset means
    /// [`TunerMode::Off`], malformed values panic with the parse error.
    pub fn from_env() -> Self {
        match std::env::var("RINGIWP_TUNER") {
            Ok(s) => {
                TunerMode::parse(&s).unwrap_or_else(|e| panic!("RINGIWP_TUNER={s}: {e}"))
            }
            Err(_) => TunerMode::Off,
        }
    }
}

/// The wire format axis of the strategy grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WirePick {
    /// Algorithm 1: spread the `k` broadcaster masks, then dense rounds
    /// over the compacted shared support (always via the pipelined
    /// wrapper; chunk count 1 is the serial reference).
    Masked,
    /// Full dense allreduce — wins when the support densifies.
    Dense,
    /// Sparse allgather (RedSync-style): spread the masks, then every
    /// node's compacted f32 payload travels *whole* (`4·nnz` bytes) and
    /// receivers sum locally — no reduce rounds, wins at tiny supports
    /// on latency-dominated links.
    Gather,
    /// The `+tern` stage: masks, then whole ternary-quantized blobs
    /// (ternary is not closed under addition, DESIGN.md §12).
    Tern,
    /// The `+q:<bits>` stage at the given width: masks, then whole
    /// [`QBlob`](crate::compress::quant::QBlob)-encoded payloads
    /// (DESIGN.md §17). The grid carries bf16/f16/q8/q4 rows —
    /// `QuantWidth::Q2` is the `+tern` row's semantics, so it never
    /// appears here twice.
    Quant(QuantWidth),
}

impl WirePick {
    /// Canonical short name (quant rows use the width's name, e.g.
    /// `q8`).
    pub fn name(&self) -> &'static str {
        match self {
            WirePick::Masked => "masked",
            WirePick::Dense => "dense",
            WirePick::Gather => "gather",
            WirePick::Tern => "tern",
            WirePick::Quant(w) => w.name(),
        }
    }
}

/// One candidate in the tuner's grid: a wire format over a topology
/// (masked candidates carry a `pipeline:<chunks>:<inner>` kind; the
/// other formats a base kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Strategy {
    /// Wire format.
    pub wire: WirePick,
    /// Topology the format runs over.
    pub topo: TopoKind,
}

impl Strategy {
    /// Canonical name, e.g. `masked/pipeline:4:flat` or `dense/tree`.
    pub fn name(&self) -> String {
        format!("{}/{}", self.wire.name(), self.topo.name())
    }
}

/// What the compressor observed this step — everything a prediction
/// needs. Pure data: building one has no network side effects, so
/// observations (and therefore decisions) are transport-independent.
#[derive(Debug)]
pub struct Observation<'a> {
    /// Payload length in f32 coordinates.
    pub coords: usize,
    /// Number of broadcaster masks spread (Algorithm 1's `k`).
    pub k: usize,
    /// The shared support this step (OR of the broadcaster masks).
    pub shared: &'a BitMask,
}

/// The outcome of one [`Tuner::decide`] call.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    /// Index into the candidate grid ([`Tuner::strategy`]).
    pub index: usize,
    /// Predicted prep-inclusive wire-seconds of the pick.
    pub predicted_s: f64,
    /// True when hysteresis kept the incumbent (including re-picking
    /// it on a tie); false on the first step and on every switch.
    pub held: bool,
}

/// The online strategy selector. Owns the closed-form model, the
/// candidate grid, prebuilt [`Topology`] instances for every candidate
/// (so a pick is executable without per-step construction), and the
/// decision trace. Not part of the zero-alloc steady-state contract:
/// pricing the grid allocates small per-step vectors.
pub struct Tuner {
    mode: TunerMode,
    model: CostModel,
    candidates: Vec<Strategy>,
    topos: Vec<Box<dyn Topology>>,
    /// Relative improvement a challenger must show to displace the
    /// incumbent: switch only if `pred[argmin] < pred[incumbent] *
    /// (1 - margin)`. At the default `0.0` the rule is *strict
    /// improvement*, so the pick's prediction still equals the grid
    /// minimum bit-for-bit (holding is only possible on exact ties).
    margin: f64,
    incumbent: Option<usize>,
    step: usize,
    switches: usize,
    trace: DecisionTrace,
}

impl Tuner {
    /// Tuner for an `n`-node ring over homogeneous `link`s, with the
    /// default candidate grid and hysteresis margin 0.
    pub fn new(mode: TunerMode, nodes: usize, link: LinkSpec) -> Self {
        let candidates = Self::default_candidates(nodes);
        let topos = candidates.iter().map(|s| s.topo.build(nodes)).collect();
        Tuner {
            mode,
            model: CostModel::new(nodes, link),
            candidates,
            topos,
            margin: 0.0,
            incumbent: None,
            step: 0,
            switches: 0,
            trace: DecisionTrace::new(),
        }
    }

    /// Override the hysteresis margin (see the field doc). Margins
    /// above 0 trade per-step optimality for fewer switches; the
    /// never-worse guarantee is margin-0 only.
    pub fn with_margin(mut self, margin: f64) -> Self {
        assert!((0.0..1.0).contains(&margin), "margin in [0, 1)");
        self.margin = margin;
        self
    }

    /// Re-price the grid against a per-hop link table (one [`LinkSpec`]
    /// per ring hop, node order) — heterogeneous rings, e.g. a chaos
    /// straggler (DESIGN.md §15). Every candidate is priced under the
    /// same table, so a degraded hop penalizes round-heavy schedules
    /// the most and can flip the pick. The incumbent and trace are
    /// kept: hysteresis describes the observation stream, not the link
    /// model.
    pub fn set_links(&mut self, links: &[LinkSpec]) {
        self.model.set_links(links.to_vec());
    }

    /// The default grid: masked over `pipeline:<chunks>:<inner>` for
    /// chunks ∈ {1,2,4,8} × inner ∈ {flat, hier:g, tree} (12 rows;
    /// chunks=1 is the serial masked reference), plus dense / gather /
    /// tern / `+q:{16b,16,8,4}` over each base topology (21 rows; the
    /// quant rows price precision against bandwidth per DESIGN.md §17,
    /// and `+q:2` is the tern row). The hier group size is
    /// `min(4, nodes)` so the grid stays valid on tiny rings.
    pub fn default_candidates(nodes: usize) -> Vec<Strategy> {
        let group = 4.min(nodes);
        let inners = [PipeInner::Flat, PipeInner::Hier { group }, PipeInner::Tree];
        let mut out = Vec::new();
        for inner in inners {
            for chunks in [1usize, 2, 4, 8] {
                out.push(Strategy {
                    wire: WirePick::Masked,
                    topo: TopoKind::Pipeline { chunks, inner },
                });
            }
        }
        for inner in inners {
            let base = inner.kind();
            for wire in [
                WirePick::Dense,
                WirePick::Gather,
                WirePick::Tern,
                WirePick::Quant(QuantWidth::Bf16),
                WirePick::Quant(QuantWidth::F16),
                WirePick::Quant(QuantWidth::Q8),
                WirePick::Quant(QuantWidth::Q4),
            ] {
                out.push(Strategy { wire, topo: base });
            }
        }
        out
    }

    /// The mode this tuner was built with.
    pub fn mode(&self) -> TunerMode {
        self.mode
    }

    /// The candidate grid.
    pub fn candidates(&self) -> &[Strategy] {
        &self.candidates
    }

    /// Candidate `index` of the grid.
    pub fn strategy(&self, index: usize) -> &Strategy {
        &self.candidates[index]
    }

    /// The prebuilt topology instance executing candidate `index`.
    pub fn strategy_topo(&self, index: usize) -> &dyn Topology {
        &*self.topos[index]
    }

    /// Number of strategy switches so far (the first pick is not a
    /// switch).
    pub fn switches(&self) -> usize {
        self.switches
    }

    /// The decision trace accumulated so far.
    pub fn trace(&self) -> &DecisionTrace {
        &self.trace
    }

    /// Predicted prep-inclusive wire-seconds of candidate `index`
    /// under `obs` — a pure function of `(index, obs)`, identical f64
    /// operations every call, so recomputing it reproduces a logged
    /// decision bit-for-bit.
    pub fn predict(&self, index: usize, obs: &Observation) -> f64 {
        let s = &self.candidates[index];
        match s.wire {
            WirePick::Masked => {
                let TopoKind::Pipeline { chunks, inner } = s.topo else {
                    unreachable!("masked candidates are pipelined by construction")
                };
                let sups = pipeline::chunk_supports(obs.shared, chunks);
                self.model
                    .pipelined_masked_seconds(inner.kind(), chunks, obs.coords, obs.k, &sups)
            }
            WirePick::Dense => {
                pipeline::prep_seconds(obs.coords)
                    + self.model.topo_dense_seconds(s.topo, obs.coords)
            }
            WirePick::Gather => {
                pipeline::prep_seconds(obs.coords)
                    + self
                        .model
                        .masked_gather_seconds(s.topo, obs.coords, obs.k, obs.shared.count())
            }
            WirePick::Tern => {
                pipeline::prep_seconds(obs.coords)
                    + self
                        .model
                        .masked_tern_seconds(s.topo, obs.coords, obs.k, obs.shared.count())
            }
            WirePick::Quant(width) => {
                pipeline::prep_seconds(obs.coords)
                    + self.model.masked_q_seconds(
                        s.topo,
                        obs.coords,
                        obs.k,
                        obs.shared.count(),
                        width,
                    )
            }
        }
    }

    /// Price every candidate under `obs`, apply hysteresis against the
    /// incumbent, record the full considered list in the trace, and
    /// return the pick. Deterministic: ties break toward the lowest
    /// grid index, and the incumbent survives exact ties.
    pub fn decide(&mut self, obs: &Observation) -> Decision {
        assert_eq!(
            obs.shared.len(),
            obs.coords,
            "observation mask length must equal the payload length"
        );
        let preds: Vec<f64> = (0..self.candidates.len())
            .map(|i| self.predict(i, obs))
            .collect();
        let mut argmin = 0usize;
        for (i, &p) in preds.iter().enumerate() {
            if p < preds[argmin] {
                argmin = i;
            }
        }
        let (pick, held) = match self.incumbent {
            // Keep the incumbent unless the challenger strictly clears
            // the margin. At margin 0 this branch is reachable only on
            // an exact tie, so preds[pick] == preds[argmin] either way.
            Some(inc) if !(preds[argmin] < preds[inc] * (1.0 - self.margin)) => (inc, true),
            _ => (argmin, false),
        };
        if let Some(inc) = self.incumbent {
            if pick != inc {
                self.switches += 1;
            }
        }
        self.incumbent = Some(pick);
        let row = DecisionRow {
            step: self.step,
            density: obs.shared.density(),
            support_nnz: obs.shared.count(),
            pick: self.candidates[pick].name(),
            predicted_s: preds[pick],
            held,
            considered: self
                .candidates
                .iter()
                .zip(&preds)
                .map(|(s, &p)| (s.name(), p))
                .collect(),
        };
        log::debug!(
            "tuner step {}: {} predicted {:.3e}s (held={held}, nnz={})",
            row.step,
            row.pick,
            row.predicted_s,
            row.support_nnz
        );
        self.trace.push(row);
        self.step += 1;
        Decision {
            index: pick,
            predicted_s: preds[pick],
            held,
        }
    }
}

impl std::fmt::Debug for Tuner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tuner")
            .field("mode", &self.mode)
            .field("candidates", &self.candidates.len())
            .field("margin", &self.margin)
            .field("incumbent", &self.incumbent)
            .field("step", &self.step)
            .field("switches", &self.switches)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn obs_mask(len: usize, nnz: usize, seed: u64) -> BitMask {
        let mut rng = Rng::new(seed);
        let mut m = BitMask::zeros(len);
        for _ in 0..nnz {
            m.set(rng.below(len));
        }
        m
    }

    #[test]
    fn mode_parse_roundtrips_and_rejects() {
        for m in [TunerMode::Off, TunerMode::On, TunerMode::LogOnly] {
            assert_eq!(TunerMode::parse(m.name()).unwrap(), m);
        }
        assert!(TunerMode::parse("sometimes").is_err());
        assert_eq!(TunerMode::default(), TunerMode::Off);
    }

    #[test]
    fn default_grid_covers_the_strategy_space() {
        let c = Tuner::default_candidates(8);
        assert_eq!(c.len(), 33, "12 masked-pipelined + 21 base-format rows");
        assert_eq!(
            c.iter().filter(|s| s.wire == WirePick::Masked).count(),
            12
        );
        for wire in [
            WirePick::Dense,
            WirePick::Gather,
            WirePick::Tern,
            WirePick::Quant(QuantWidth::Bf16),
            WirePick::Quant(QuantWidth::F16),
            WirePick::Quant(QuantWidth::Q8),
            WirePick::Quant(QuantWidth::Q4),
        ] {
            assert_eq!(c.iter().filter(|s| s.wire == wire).count(), 3);
        }
        assert_eq!(
            c.iter()
                .filter(|s| matches!(s.wire, WirePick::Quant(_)))
                .count(),
            12,
            "four widths over three base topologies"
        );
        assert!(
            !c.iter()
                .any(|s| s.wire == WirePick::Quant(QuantWidth::Q2)),
            "the 2-bit width rides the tern row, never a duplicate"
        );
        // Names are unique (the trace keys on them).
        let mut names: Vec<String> = c.iter().map(|s| s.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), c.len());
        // Tiny rings clamp the hier group.
        assert!(Tuner::default_candidates(2)
            .iter()
            .all(|s| s.topo.validate().is_ok()));
    }

    #[test]
    fn pick_is_the_argmin_at_margin_zero() {
        let mut tuner = Tuner::new(TunerMode::On, 6, LinkSpec::gigabit_ethernet());
        for (step, nnz) in [(0usize, 40usize), (1, 400), (2, 3800), (3, 12)] {
            let mask = obs_mask(4000, nnz, 7 + step as u64);
            let obs = Observation {
                coords: 4000,
                k: 2,
                shared: &mask,
            };
            let d = tuner.decide(&obs);
            let min = (0..tuner.candidates().len())
                .map(|i| tuner.predict(i, &obs))
                .fold(f64::INFINITY, f64::min);
            assert_eq!(
                d.predicted_s.to_bits(),
                min.to_bits(),
                "step {step}: pick prediction must equal the grid minimum"
            );
        }
    }

    #[test]
    fn constant_stream_never_switches() {
        let mut tuner = Tuner::new(TunerMode::On, 5, LinkSpec::gigabit_ethernet());
        let mask = obs_mask(9000, 150, 3);
        for step in 0..12 {
            let d = tuner.decide(&Observation {
                coords: 9000,
                k: 3,
                shared: &mask,
            });
            assert_eq!(d.held, step > 0, "first pick is fresh, rest are held");
        }
        assert_eq!(tuner.switches(), 0);
        assert_eq!(tuner.trace().switches(), 0);
        assert_eq!(tuner.trace().len(), 12);
    }

    #[test]
    fn margin_damps_switching_but_keeps_first_pick() {
        // A wide margin holds the incumbent even when a challenger is
        // (slightly) better; the first pick is still the argmin.
        let mut free = Tuner::new(TunerMode::On, 6, LinkSpec::gigabit_ethernet());
        let mut held = Tuner::new(TunerMode::On, 6, LinkSpec::gigabit_ethernet())
            .with_margin(0.9);
        for nnz in [20usize, 30, 2500, 25, 2600] {
            let mask = obs_mask(5000, nnz, nnz as u64);
            let obs = Observation {
                coords: 5000,
                k: 2,
                shared: &mask,
            };
            free.decide(&obs);
            held.decide(&obs);
        }
        assert!(held.switches() <= free.switches());
        assert_eq!(
            held.trace().rows()[0].pick,
            free.trace().rows()[0].pick,
            "margin only affects steps after the first"
        );
    }

    #[test]
    fn straggler_hop_flips_the_pick() {
        // Per-hop pricing (DESIGN.md §15): a high-latency hop charges
        // every synchronous round, so round-heavy schedules (the flat
        // ring's 2(N-1) dense rounds) fall behind round-light ones and
        // the argmin moves off the uniform winner.
        let coords = 40_000;
        let link = LinkSpec::gigabit_ethernet();
        let mut full = BitMask::zeros(coords);
        for i in 0..coords {
            full.set(i);
        }
        let obs = Observation {
            coords,
            k: 3,
            shared: &full,
        };
        let mut uniform = Tuner::new(TunerMode::On, 6, link);
        let d_u = uniform.decide(&obs);
        let u_pick = *uniform.strategy(d_u.index);
        assert!(
            matches!(u_pick.topo, TopoKind::Flat),
            "uniform full-density argmin should be flat dense, got {}",
            u_pick.name()
        );
        let mut straggler = Tuner::new(TunerMode::On, 6, link);
        let mut ls = vec![link; 6];
        ls[2] = LinkSpec::new(link.bandwidth_bps, 0.5);
        straggler.set_links(&ls);
        let d_s = straggler.decide(&obs);
        let s_pick = *straggler.strategy(d_s.index);
        assert_ne!(
            u_pick.name(),
            s_pick.name(),
            "a 0.5 s straggler hop must flip the pick"
        );
        // The flip is real routing-around, not a tie: under the
        // straggler table the new pick beats the uniform winner by a
        // wide margin.
        assert!(
            d_s.predicted_s < straggler.predict(d_u.index, &obs) * 0.5,
            "pick {} at {:.3}s vs old winner at {:.3}s",
            s_pick.name(),
            d_s.predicted_s,
            straggler.predict(d_u.index, &obs)
        );
    }

    #[test]
    fn quant_rows_price_precision_against_bandwidth() {
        // DESIGN.md §17: on one topology the quant rows order purely by
        // blob bytes — tern (2-bit) < q4 < q8 < bf16 — and the two
        // 16-bit floats price bit-identically (same wire bytes, no
        // scales). This is the gradient the tuner trades against
        // accuracy; the ordering must never silently invert.
        let tuner = Tuner::new(TunerMode::On, 8, LinkSpec::gigabit_ethernet());
        let coords = 40_000;
        let idx = |wire: WirePick| {
            tuner
                .candidates()
                .iter()
                .position(|s| s.wire == wire && s.topo == TopoKind::Flat)
                .unwrap()
        };
        let mask = obs_mask(coords, 3000, 11);
        let obs = Observation {
            coords,
            k: 3,
            shared: &mask,
        };
        let p = |w| tuner.predict(idx(w), &obs);
        assert!(p(WirePick::Tern) < p(WirePick::Quant(QuantWidth::Q4)));
        assert!(p(WirePick::Quant(QuantWidth::Q4)) < p(WirePick::Quant(QuantWidth::Q8)));
        assert!(p(WirePick::Quant(QuantWidth::Q8)) < p(WirePick::Quant(QuantWidth::Bf16)));
        assert_eq!(
            p(WirePick::Quant(QuantWidth::Bf16)).to_bits(),
            p(WirePick::Quant(QuantWidth::F16)).to_bits(),
            "both 16-bit floats ship 2 bytes per value and no scales"
        );
        assert!(
            p(WirePick::Quant(QuantWidth::F16)) < p(WirePick::Gather),
            "halving the payload must beat whole-f32 gather at this support"
        );
    }

    #[test]
    fn crossovers_match_the_design_table() {
        // DESIGN.md §14 anchors, pinned through the tuner's own
        // predict(): (a) at full density the masked schedule is exactly
        // dense plus a mask spread, so dense/flat beats the serial
        // masked reference; (b) at a tiny support both mask-based
        // formats beat dense; (c) gather degrades past dense as the
        // support approaches the payload (4·nnz blobs spread whole).
        let tuner = Tuner::new(TunerMode::On, 8, LinkSpec::gigabit_ethernet());
        let coords = 40_000;
        let idx = |wire: WirePick, topo: TopoKind| {
            tuner
                .candidates()
                .iter()
                .position(|s| s.wire == wire && s.topo == topo)
                .unwrap()
        };
        let dense_flat = idx(WirePick::Dense, TopoKind::Flat);
        let masked_serial = idx(
            WirePick::Masked,
            TopoKind::Pipeline {
                chunks: 1,
                inner: PipeInner::Flat,
            },
        );
        let gather_flat = idx(WirePick::Gather, TopoKind::Flat);
        let mut full = BitMask::zeros(coords);
        for i in 0..coords {
            full.set(i);
        }
        let mut tiny = BitMask::zeros(coords);
        for i in 0..40 {
            tiny.set(i);
        }
        let obs_full = Observation {
            coords,
            k: 3,
            shared: &full,
        };
        let obs_tiny = Observation {
            coords,
            k: 3,
            shared: &tiny,
        };
        assert!(tuner.predict(dense_flat, &obs_full) < tuner.predict(masked_serial, &obs_full));
        assert!(tuner.predict(gather_flat, &obs_tiny) < tuner.predict(dense_flat, &obs_tiny));
        assert!(tuner.predict(gather_flat, &obs_full) > tuner.predict(dense_flat, &obs_full));
        assert!(tuner.predict(masked_serial, &obs_tiny) < tuner.predict(dense_flat, &obs_tiny));
    }
}
