//! Time-bucketed per-node transmit traces — the data behind the paper's
//! Networks-I/O plots (Figs. 7/8, KB/s over wall time) — plus the
//! autotuner's decision trace (DESIGN.md §14): one row per
//! [`Tuner::decide`](super::tuner::Tuner::decide) call, carrying the
//! observation, the pick, and every candidate's prediction, so a run's
//! strategy trajectory can be audited (and replayed) offline.

/// Bytes-per-bucket trace for every node.
#[derive(Debug, Clone)]
pub struct Trace {
    n_nodes: usize,
    bucket_s: f64,
    /// buckets[t][node] = bytes transmitted by `node` during bucket `t`.
    buckets: Vec<Vec<f64>>,
}

impl Trace {
    /// Empty trace for `n_nodes` with `bucket_s`-second buckets.
    pub fn new(n_nodes: usize, bucket_s: f64) -> Self {
        assert!(bucket_s > 0.0);
        Trace {
            n_nodes,
            bucket_s,
            buckets: Vec::new(),
        }
    }

    /// Bucket width in virtual seconds.
    pub fn bucket_seconds(&self) -> f64 {
        self.bucket_s
    }

    /// Number of materialized buckets.
    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    fn ensure(&mut self, bucket: usize) {
        while self.buckets.len() <= bucket {
            self.buckets.push(vec![0.0; self.n_nodes]);
        }
    }

    /// Record `bytes` transmitted by `node` over [start, start+dur),
    /// spread proportionally across the buckets the window overlaps.
    pub fn add(&mut self, start: f64, dur: f64, node: usize, bytes: u64) {
        assert!(node < self.n_nodes);
        if bytes == 0 {
            return;
        }
        let end = start + dur.max(1e-12);
        let rate = bytes as f64 / (end - start);
        // Integer bucket iteration: a float-stepping loop can stall when
        // `(b+1)*bucket_s` rounds to exactly the current position (seen in
        // production at t=2.1499999999999999, bucket_s=0.05 — infinite
        // loop). Indices always advance.
        let first = (start / self.bucket_s) as usize;
        let last = ((end / self.bucket_s).ceil() as usize).max(first + 1);
        self.ensure(last - 1);
        for b in first..last {
            let b_start = b as f64 * self.bucket_s;
            let b_end = b_start + self.bucket_s;
            let seg = end.min(b_end) - start.max(b_start);
            if seg > 0.0 {
                self.buckets[b][node] += rate * seg;
            }
        }
    }

    /// KB/s series for one node: (bucket_start_s, kb_per_s) rows —
    /// directly comparable to the paper's Fig. 7/8 axes.
    pub fn kbps_series(&self, node: usize) -> Vec<(f64, f64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                (i as f64 * self.bucket_s, b[node] / 1024.0 / self.bucket_s)
            })
            .collect()
    }

    /// Aggregate KB/s across all nodes.
    pub fn total_kbps_series(&self) -> Vec<(f64, f64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                (i as f64 * self.bucket_s, b.iter().sum::<f64>() / 1024.0 / self.bucket_s)
            })
            .collect()
    }

    /// Peak per-node KB/s (the "full load" level in Fig. 7).
    pub fn peak_kbps(&self, node: usize) -> f64 {
        self.kbps_series(node)
            .into_iter()
            .map(|(_, v)| v)
            .fold(0.0, f64::max)
    }

    /// Mean per-node KB/s over the non-empty prefix of the trace.
    pub fn mean_kbps(&self, node: usize) -> f64 {
        let s = self.kbps_series(node);
        if s.is_empty() {
            0.0
        } else {
            s.iter().map(|(_, v)| v).sum::<f64>() / s.len() as f64
        }
    }

    /// Drop all recorded buckets.
    pub fn clear(&mut self) {
        self.buckets.clear();
    }
}

/// One autotuner decision (DESIGN.md §14): what was observed, what was
/// picked, and what every candidate would have cost. `considered`
/// pairs candidate names with their predicted prep-inclusive
/// wire-seconds in grid order, so cumulative static-strategy costs can
/// be re-derived from the trace alone (the never-worse oracle test
/// does exactly that).
#[derive(Debug, Clone)]
pub struct DecisionRow {
    /// 0-based decision index (one per engine step).
    pub step: usize,
    /// Observed shared-support density (`nnz / coords`).
    pub density: f64,
    /// Observed shared-support size in coordinates.
    pub support_nnz: usize,
    /// Canonical name of the picked strategy, e.g. `masked/pipeline:4:flat`.
    pub pick: String,
    /// Predicted prep-inclusive wire-seconds of the pick.
    pub predicted_s: f64,
    /// True when hysteresis kept the incumbent.
    pub held: bool,
    /// `(strategy name, predicted seconds)` for every candidate.
    pub considered: Vec<(String, f64)>,
}

impl DecisionRow {
    /// One-line summary, the format `log-only` walkthroughs grep for
    /// (EXPERIMENTS.md §11).
    pub fn log_line(&self) -> String {
        format!(
            "step {:>4}  density {:.5}  nnz {:>8}  pick {:<28} predicted {:.6e}s{}",
            self.step,
            self.density,
            self.support_nnz,
            self.pick,
            self.predicted_s,
            if self.held { "  (held)" } else { "" }
        )
    }
}

/// Append-only log of autotuner decisions.
#[derive(Debug, Clone, Default)]
pub struct DecisionTrace {
    rows: Vec<DecisionRow>,
}

impl DecisionTrace {
    /// Empty trace.
    pub fn new() -> Self {
        DecisionTrace::default()
    }

    /// Append one decision.
    pub fn push(&mut self, row: DecisionRow) {
        self.rows.push(row);
    }

    /// All decisions in step order.
    pub fn rows(&self) -> &[DecisionRow] {
        &self.rows
    }

    /// Number of decisions recorded.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no decision has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The most recent decision.
    pub fn last(&self) -> Option<&DecisionRow> {
        self.rows.last()
    }

    /// Number of strategy changes between consecutive decisions — the
    /// quantity hysteresis bounds (0 on a constant observation stream).
    pub fn switches(&self) -> usize {
        self.rows
            .windows(2)
            .filter(|w| w[0].pick != w[1].pick)
            .count()
    }

    /// Sum of the picked strategies' predicted seconds — the tuner's
    /// cumulative cost, comparable against [`DecisionTrace::static_total`].
    pub fn picked_total(&self) -> f64 {
        self.rows.iter().map(|r| r.predicted_s).sum()
    }

    /// Cumulative predicted seconds had candidate `index` run every
    /// step — the static-strategy baseline re-derived from the trace.
    pub fn static_total(&self, index: usize) -> f64 {
        self.rows.iter().map(|r| r.considered[index].1).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_conserved_across_buckets() {
        let mut t = Trace::new(2, 1.0);
        t.add(0.5, 2.0, 0, 2000); // spans buckets 0,1,2
        let total: f64 = t.kbps_series(0).iter().map(|(_, v)| v * 1024.0).sum();
        assert!((total - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn proportional_spread() {
        let mut t = Trace::new(1, 1.0);
        t.add(0.0, 2.0, 0, 1000);
        let s = t.kbps_series(0);
        assert_eq!(s.len(), 2);
        assert!((s[0].1 - s[1].1).abs() < 1e-9); // even split
    }

    #[test]
    fn instantaneous_transfer_lands_in_one_bucket() {
        let mut t = Trace::new(1, 1.0);
        t.add(3.2, 0.0, 0, 500);
        let s = t.kbps_series(0);
        assert_eq!(s.len(), 4);
        assert!((s[3].1 * 1024.0 - 500.0).abs() < 1e-6);
        assert_eq!(s[0].1, 0.0);
    }

    #[test]
    fn peak_and_mean() {
        let mut t = Trace::new(1, 1.0);
        t.add(0.0, 1.0, 0, 1024); // 1 KB/s in bucket 0
        t.add(1.0, 1.0, 0, 3 * 1024); // 3 KB/s in bucket 1
        assert!((t.peak_kbps(0) - 3.0).abs() < 1e-9);
        assert!((t.mean_kbps(0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pathological_float_boundary_terminates() {
        // Regression: this exact (start, bucket) combination stalled the
        // old float-stepping implementation forever.
        let mut t = Trace::new(16, 0.05);
        t.add(2.1499999999999999, 0.0546875, 0, 6_389_258);
        let total: f64 = t.kbps_series(0).iter().map(|(_, v)| v * 1024.0 * 0.05).sum();
        assert!((total - 6_389_258.0).abs() / 6_389_258.0 < 1e-9);
    }

    #[test]
    fn zero_bytes_noop() {
        let mut t = Trace::new(1, 1.0);
        t.add(0.0, 1.0, 0, 0);
        assert_eq!(t.n_buckets(), 0);
    }

    fn decision(step: usize, pick: &str, picked: f64, other: f64) -> DecisionRow {
        DecisionRow {
            step,
            density: 0.01,
            support_nnz: 100,
            pick: pick.to_string(),
            predicted_s: picked,
            held: false,
            considered: vec![("a".into(), picked), ("b".into(), other)],
        }
    }

    #[test]
    fn decision_trace_counts_switches_and_totals() {
        let mut t = DecisionTrace::new();
        assert!(t.is_empty());
        t.push(decision(0, "a", 1.0, 4.0));
        t.push(decision(1, "a", 2.0, 5.0));
        t.push(decision(2, "b", 0.5, 6.0));
        assert_eq!(t.len(), 3);
        assert_eq!(t.switches(), 1);
        assert_eq!(t.last().unwrap().step, 2);
        assert!((t.picked_total() - 3.5).abs() < 1e-12);
        assert!((t.static_total(0) - 3.5).abs() < 1e-12);
        assert!((t.static_total(1) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn decision_log_line_mentions_the_pick() {
        let row = decision(7, "masked/pipeline:4:flat", 1e-3, 2e-3);
        let line = row.log_line();
        assert!(line.contains("masked/pipeline:4:flat"));
        assert!(line.contains("step"));
    }
}
