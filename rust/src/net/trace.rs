//! Time-bucketed per-node transmit traces — the data behind the paper's
//! Networks-I/O plots (Figs. 7/8, KB/s over wall time).

/// Bytes-per-bucket trace for every node.
#[derive(Debug, Clone)]
pub struct Trace {
    n_nodes: usize,
    bucket_s: f64,
    /// buckets[t][node] = bytes transmitted by `node` during bucket `t`.
    buckets: Vec<Vec<f64>>,
}

impl Trace {
    /// Empty trace for `n_nodes` with `bucket_s`-second buckets.
    pub fn new(n_nodes: usize, bucket_s: f64) -> Self {
        assert!(bucket_s > 0.0);
        Trace {
            n_nodes,
            bucket_s,
            buckets: Vec::new(),
        }
    }

    /// Bucket width in virtual seconds.
    pub fn bucket_seconds(&self) -> f64 {
        self.bucket_s
    }

    /// Number of materialized buckets.
    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    fn ensure(&mut self, bucket: usize) {
        while self.buckets.len() <= bucket {
            self.buckets.push(vec![0.0; self.n_nodes]);
        }
    }

    /// Record `bytes` transmitted by `node` over [start, start+dur),
    /// spread proportionally across the buckets the window overlaps.
    pub fn add(&mut self, start: f64, dur: f64, node: usize, bytes: u64) {
        assert!(node < self.n_nodes);
        if bytes == 0 {
            return;
        }
        let end = start + dur.max(1e-12);
        let rate = bytes as f64 / (end - start);
        // Integer bucket iteration: a float-stepping loop can stall when
        // `(b+1)*bucket_s` rounds to exactly the current position (seen in
        // production at t=2.1499999999999999, bucket_s=0.05 — infinite
        // loop). Indices always advance.
        let first = (start / self.bucket_s) as usize;
        let last = ((end / self.bucket_s).ceil() as usize).max(first + 1);
        self.ensure(last - 1);
        for b in first..last {
            let b_start = b as f64 * self.bucket_s;
            let b_end = b_start + self.bucket_s;
            let seg = end.min(b_end) - start.max(b_start);
            if seg > 0.0 {
                self.buckets[b][node] += rate * seg;
            }
        }
    }

    /// KB/s series for one node: (bucket_start_s, kb_per_s) rows —
    /// directly comparable to the paper's Fig. 7/8 axes.
    pub fn kbps_series(&self, node: usize) -> Vec<(f64, f64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                (i as f64 * self.bucket_s, b[node] / 1024.0 / self.bucket_s)
            })
            .collect()
    }

    /// Aggregate KB/s across all nodes.
    pub fn total_kbps_series(&self) -> Vec<(f64, f64)> {
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                (i as f64 * self.bucket_s, b.iter().sum::<f64>() / 1024.0 / self.bucket_s)
            })
            .collect()
    }

    /// Peak per-node KB/s (the "full load" level in Fig. 7).
    pub fn peak_kbps(&self, node: usize) -> f64 {
        self.kbps_series(node)
            .into_iter()
            .map(|(_, v)| v)
            .fold(0.0, f64::max)
    }

    /// Mean per-node KB/s over the non-empty prefix of the trace.
    pub fn mean_kbps(&self, node: usize) -> f64 {
        let s = self.kbps_series(node);
        if s.is_empty() {
            0.0
        } else {
            s.iter().map(|(_, v)| v).sum::<f64>() / s.len() as f64
        }
    }

    /// Drop all recorded buckets.
    pub fn clear(&mut self) {
        self.buckets.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_conserved_across_buckets() {
        let mut t = Trace::new(2, 1.0);
        t.add(0.5, 2.0, 0, 2000); // spans buckets 0,1,2
        let total: f64 = t.kbps_series(0).iter().map(|(_, v)| v * 1024.0).sum();
        assert!((total - 2000.0).abs() < 1e-6);
    }

    #[test]
    fn proportional_spread() {
        let mut t = Trace::new(1, 1.0);
        t.add(0.0, 2.0, 0, 1000);
        let s = t.kbps_series(0);
        assert_eq!(s.len(), 2);
        assert!((s[0].1 - s[1].1).abs() < 1e-9); // even split
    }

    #[test]
    fn instantaneous_transfer_lands_in_one_bucket() {
        let mut t = Trace::new(1, 1.0);
        t.add(3.2, 0.0, 0, 500);
        let s = t.kbps_series(0);
        assert_eq!(s.len(), 4);
        assert!((s[3].1 * 1024.0 - 500.0).abs() < 1e-6);
        assert_eq!(s[0].1, 0.0);
    }

    #[test]
    fn peak_and_mean() {
        let mut t = Trace::new(1, 1.0);
        t.add(0.0, 1.0, 0, 1024); // 1 KB/s in bucket 0
        t.add(1.0, 1.0, 0, 3 * 1024); // 3 KB/s in bucket 1
        assert!((t.peak_kbps(0) - 3.0).abs() < 1e-9);
        assert!((t.mean_kbps(0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pathological_float_boundary_terminates() {
        // Regression: this exact (start, bucket) combination stalled the
        // old float-stepping implementation forever.
        let mut t = Trace::new(16, 0.05);
        t.add(2.1499999999999999, 0.0546875, 0, 6_389_258);
        let total: f64 = t.kbps_series(0).iter().map(|(_, v)| v * 1024.0 * 0.05).sum();
        assert!((total - 6_389_258.0).abs() / 6_389_258.0 < 1e-9);
    }

    #[test]
    fn zero_bytes_noop() {
        let mut t = Trace::new(1, 1.0);
        t.add(0.0, 1.0, 0, 0);
        assert_eq!(t.n_buckets(), 0);
    }
}
