//! Socket plumbing and per-rank relay sessions (DESIGN.md §13).
//!
//! A wire ring is `n` rank sessions plus one coordinator. Rank `r`
//! owns three streams:
//!
//! * `ctl`  — full-duplex to the coordinator: injections arrive on the
//!   read side, delivered copies leave on the write side;
//! * `pred` — read half of ring edge `(r-1) mod n → r`;
//! * `succ` — write half of ring edge `r → (r+1) mod n`.
//!
//! Each session runs two threads ([`spawn_rank`]):
//!
//! * **uplink** reads frames off `ctl` and writes them to `succ` (a
//!   `Shutdown` with `ttl == 0` stops the thread instead);
//! * **relay** reads frames off `pred`; for data frames it writes a
//!   `ttl`-zeroed copy back to the coordinator over `ctl` and, while
//!   `ttl > 1`, forwards the frame to `succ` with `ttl - 1`. A
//!   `Shutdown` frame is forwarded (while `ttl > 1`) but never
//!   delivered, and stops the thread.
//!
//! `succ` is shared between the two threads behind a mutex; `ctl` is
//! split by `try_clone` so the directions never contend. A frame
//! injected at `origin` with `ttl = t` therefore traverses `t` real
//! ring edges and produces exactly `t` delivered copies — one from
//! each of ranks `origin+1 … origin+t (mod n)` — which the
//! coordinator collects in deterministic hop order and verifies
//! byte-identical (`net::wire::WireRing`).
//!
//! Two wirings share this module: in-process rings build their edges
//! from socket pairs ([`WireStream::pair`]), and external rings
//! rendezvous through a filesystem directory ([`serve_rank`] +
//! `WireRing::connect_external`): rank `r` listens at
//! `<dir>/rank-<r>.sock`, the coordinator at `<dir>/ctl.sock` (`.port`
//! files carrying a loopback TCP port replace `.sock` files under
//! `--transport tcp`).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::codec;
use super::frame::{Frame, Kind, WireError};
use super::TransportKind;

/// How long connect-with-retry waits for a peer to bind.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Coordinator-side read timeout: a hung rank surfaces as a typed
/// [`WireError::Io`] (`WouldBlock`/`TimedOut`) instead of a hung run.
pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// One connected stream of either transport flavor.
#[derive(Debug)]
pub enum WireStream {
    /// Unix domain socket.
    Unix(UnixStream),
    /// Loopback (or remote) TCP socket, `TCP_NODELAY` set.
    Tcp(TcpStream),
}

impl WireStream {
    /// Clone the underlying socket (independent file descriptor over
    /// the same connection — used to split ctl into read/write halves).
    pub fn try_clone(&self) -> Result<WireStream, WireError> {
        Ok(match self {
            WireStream::Unix(s) => WireStream::Unix(s.try_clone()?),
            WireStream::Tcp(s) => WireStream::Tcp(s.try_clone()?),
        })
    }

    /// Set (or clear) the blocking-read timeout.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<(), WireError> {
        match self {
            WireStream::Unix(s) => s.set_read_timeout(d)?,
            WireStream::Tcp(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }

    /// Connected socket pair for in-process rings. For TCP the pair is
    /// built through an ephemeral loopback listener.
    pub fn pair(kind: TransportKind) -> Result<(WireStream, WireStream), WireError> {
        match kind {
            TransportKind::Sim => Err(WireError::Corrupt(
                "transport `sim` has no socket pairs".into(),
            )),
            TransportKind::Uds => {
                let (a, b) = UnixStream::pair()?;
                Ok((WireStream::Unix(a), WireStream::Unix(b)))
            }
            TransportKind::Tcp => {
                let listener = TcpListener::bind("127.0.0.1:0")?;
                let addr = listener.local_addr()?;
                let a = TcpStream::connect(addr)?;
                let (b, _) = listener.accept()?;
                a.set_nodelay(true)?;
                b.set_nodelay(true)?;
                Ok((WireStream::Tcp(a), WireStream::Tcp(b)))
            }
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.read(buf),
            WireStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.write(buf),
            WireStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            WireStream::Unix(s) => s.flush(),
            WireStream::Tcp(s) => s.flush(),
        }
    }
}

/// Rendezvous listener for external (serve-mode) rings.
#[derive(Debug)]
pub enum WireListener {
    /// Filesystem Unix socket at `<dir>/<name>.sock`.
    Unix(UnixListener),
    /// Loopback TCP listener, its port advertised in `<dir>/<name>.port`.
    Tcp(TcpListener),
}

impl WireListener {
    /// Bind the rendezvous point `<dir>/<name>` for the given
    /// transport, replacing any stale socket/port file.
    pub fn bind(dir: &Path, name: &str, kind: TransportKind) -> Result<WireListener, WireError> {
        match kind {
            TransportKind::Sim => Err(WireError::Corrupt(
                "transport `sim` has no listeners".into(),
            )),
            TransportKind::Uds => {
                let path = sock_path(dir, name);
                let _ = std::fs::remove_file(&path);
                Ok(WireListener::Unix(UnixListener::bind(&path)?))
            }
            TransportKind::Tcp => {
                let listener = TcpListener::bind("127.0.0.1:0")?;
                let port = listener.local_addr()?.port();
                let path = port_path(dir, name);
                let tmp = path.with_extension("port.tmp");
                std::fs::write(&tmp, port.to_string())?;
                std::fs::rename(&tmp, &path)?;
                Ok(WireListener::Tcp(listener))
            }
        }
    }

    /// Accept one connection.
    pub fn accept(&self) -> Result<WireStream, WireError> {
        Ok(match self {
            WireListener::Unix(l) => WireStream::Unix(l.accept()?.0),
            WireListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                WireStream::Tcp(s)
            }
        })
    }
}

fn sock_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.sock"))
}

fn port_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.port"))
}

/// Connect to rendezvous point `<dir>/<name>`, retrying until the
/// peer binds or [`CONNECT_TIMEOUT`] expires.
pub fn connect_retry(dir: &Path, name: &str, kind: TransportKind) -> Result<WireStream, WireError> {
    let start = Instant::now();
    loop {
        let attempt: std::io::Result<WireStream> = match kind {
            TransportKind::Sim => {
                return Err(WireError::Corrupt("transport `sim` has no sockets".into()))
            }
            TransportKind::Uds => UnixStream::connect(sock_path(dir, name)).map(WireStream::Unix),
            TransportKind::Tcp => std::fs::read_to_string(port_path(dir, name)).and_then(|p| {
                let port: u16 = p.trim().parse().map_err(|_| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, "bad port file")
                })?;
                let s = TcpStream::connect(("127.0.0.1", port))?;
                s.set_nodelay(true)?;
                Ok(WireStream::Tcp(s))
            }),
        };
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) if start.elapsed() >= CONNECT_TIMEOUT => {
                return Err(WireError::Io(std::io::Error::new(
                    e.kind(),
                    format!("connecting to {name} in {}: {e}", dir.display()),
                )))
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Join handles for one rank session's two threads.
#[derive(Debug)]
pub struct RankSession {
    uplink: std::thread::JoinHandle<Result<(), WireError>>,
    relay: std::thread::JoinHandle<Result<(), WireError>>,
}

impl RankSession {
    /// Wait for both threads; first error wins.
    pub fn join(self) -> Result<(), WireError> {
        let u = self
            .uplink
            .join()
            .unwrap_or_else(|_| Err(WireError::Corrupt("uplink thread panicked".into())));
        let r = self
            .relay
            .join()
            .unwrap_or_else(|_| Err(WireError::Corrupt("relay thread panicked".into())));
        u?;
        r
    }
}

/// Spawn the uplink + relay threads for one rank session. `ctl` is
/// split internally; `succ` is shared behind a mutex.
pub fn spawn_rank(
    rank: u16,
    ctl: WireStream,
    pred: WireStream,
    succ: WireStream,
) -> Result<RankSession, WireError> {
    let mut ctl_r = ctl.try_clone()?; // uplink reads injections
    let mut ctl_w = ctl; // relay writes deliveries
    let succ = std::sync::Arc::new(Mutex::new(succ));

    let succ_up = succ.clone();
    let uplink = std::thread::Builder::new()
        .name(format!("riwp-uplink-{rank}"))
        .spawn(move || -> Result<(), WireError> {
            loop {
                let f = Frame::read_from(&mut ctl_r)?;
                if f.kind == Kind::Shutdown && f.ttl == 0 {
                    return Ok(());
                }
                let mut s = succ_up.lock().expect("succ mutex poisoned");
                f.write_to(&mut *s)?;
                s.flush()?;
            }
        })?;

    let mut pred = pred;
    let relay = std::thread::Builder::new()
        .name(format!("riwp-relay-{rank}"))
        .spawn(move || -> Result<(), WireError> {
            loop {
                let f = Frame::read_from(&mut pred)?;
                let forward = f.ttl > 1;
                if forward {
                    let fwd = Frame {
                        ttl: f.ttl - 1,
                        payload: f.payload.clone(),
                        ..f
                    };
                    let mut s = succ.lock().expect("succ mutex poisoned");
                    fwd.write_to(&mut *s)?;
                    s.flush()?;
                }
                if f.kind == Kind::Shutdown {
                    return Ok(());
                }
                // Deliver a ttl-normalized copy so every hop's copy of
                // the same injection is byte-identical at the
                // coordinator.
                let delivered = Frame { ttl: 0, ..f };
                delivered.write_to(&mut ctl_w)?;
                ctl_w.flush()?;
            }
        })?;

    Ok(RankSession { uplink, relay })
}

/// Run rank `rank` of an `n`-node external ring rendezvousing in
/// `dir`: handshake with the coordinator, wire the ring edges, then
/// relay until the coordinator shuts the session down. Loops over
/// sessions (re-connecting after each shutdown) unless `once` is set.
/// Returns the number of sessions served.
pub fn serve_rank(
    dir: &Path,
    rank: u16,
    n: u16,
    kind: TransportKind,
    once: bool,
) -> Result<u32, WireError> {
    assert!(n >= 2, "ring needs at least 2 ranks");
    assert!(rank < n, "rank {rank} out of range for n={n}");
    let listener = WireListener::bind(dir, &format!("rank-{rank}"), kind)?;
    let mut sessions = 0u32;
    loop {
        // Handshake: Hello(rank, n) → coordinator, HelloAck back.
        let mut ctl = connect_retry(dir, "ctl", kind)?;
        Frame::new(Kind::Hello, rank, 0, 0, codec::encode_hello(rank, n)).write_to(&mut ctl)?;
        ctl.flush()?;
        let ack = Frame::read_from(&mut ctl)?;
        if ack.kind != Kind::HelloAck {
            return Err(WireError::Corrupt(format!(
                "expected HelloAck, got {:?}",
                ack.kind
            )));
        }
        let links = codec::decode_hello_ack(&ack.payload)?;
        if links.len() != n as usize {
            return Err(WireError::Corrupt(format!(
                "HelloAck carries {} links for an n={n} ring",
                links.len()
            )));
        }
        // Ring edges: connect succ first (connects complete against a
        // bound listener's backlog without an accept), then accept pred.
        let succ = connect_retry(dir, &format!("rank-{}", (rank + 1) % n), kind)?;
        let pred = listener.accept()?;
        spawn_rank(rank, ctl, pred, succ)?.join()?;
        sessions += 1;
        if once {
            return Ok(sessions);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_pair_roundtrips_frames() {
        for kind in [TransportKind::Uds, TransportKind::Tcp] {
            let (mut a, mut b) = WireStream::pair(kind).unwrap();
            let f = Frame::new(Kind::Dense, 1, 2, 3, vec![7; 33]);
            f.write_to(&mut a).unwrap();
            assert_eq!(Frame::read_from(&mut b).unwrap(), f, "{kind:?}");
        }
    }

    #[test]
    fn sim_transport_has_no_sockets() {
        assert!(WireStream::pair(TransportKind::Sim).is_err());
    }

    #[test]
    fn relay_delivers_and_forwards_with_decrement() {
        // 2-rank micro-ring driven by hand: coordinator ctl pairs plus
        // one edge in each direction.
        let (ctl0_coord, ctl0_rank) = WireStream::pair(TransportKind::Uds).unwrap();
        let (ctl1_coord, ctl1_rank) = WireStream::pair(TransportKind::Uds).unwrap();
        let (edge01_w, edge01_r) = WireStream::pair(TransportKind::Uds).unwrap();
        let (edge10_w, edge10_r) = WireStream::pair(TransportKind::Uds).unwrap();
        let s0 = spawn_rank(0, ctl0_rank, edge10_r, edge01_w).unwrap();
        let s1 = spawn_rank(1, ctl1_rank, edge01_r, edge10_w).unwrap();

        let mut ctl0 = ctl0_coord;
        let mut ctl1 = ctl1_coord;
        // Inject at rank 0 with ttl=2: rank 1 delivers + forwards,
        // rank 0 delivers.
        let f = Frame::new(Kind::Tern, 0, 2, 9, vec![1, 2, 3]);
        f.write_to(&mut ctl0).unwrap();
        let d1 = Frame::read_from(&mut ctl1).unwrap();
        let d0 = Frame::read_from(&mut ctl0).unwrap();
        for d in [&d1, &d0] {
            assert_eq!(d.ttl, 0);
            assert_eq!(d.epoch, 9);
            assert_eq!(d.payload, vec![1, 2, 3]);
        }
        // Teardown: ring Shutdown stops both relays, ttl=0 Shutdowns
        // stop both uplinks.
        Frame::new(Kind::Shutdown, 0, 2, 9, Vec::new())
            .write_to(&mut ctl0)
            .unwrap();
        Frame::new(Kind::Shutdown, 0, 0, 9, Vec::new())
            .write_to(&mut ctl0)
            .unwrap();
        Frame::new(Kind::Shutdown, 0, 0, 9, Vec::new())
            .write_to(&mut ctl1)
            .unwrap();
        s0.join().unwrap();
        s1.join().unwrap();
    }
}
