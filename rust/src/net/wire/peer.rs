//! Socket plumbing, per-rank relay sessions, and per-hop recovery
//! (DESIGN.md §13, §16).
//!
//! A wire ring is `n` rank sessions plus one coordinator. Rank `r`
//! owns three streams:
//!
//! * `ctl`  — full-duplex to the coordinator: injections arrive on the
//!   read side, delivered copies leave on the write side;
//! * `pred` — ring edge `(r-1) mod n → r`: data frames in, ACK/NACK
//!   out (the edge socket is full-duplex, so acknowledgments travel
//!   backwards on the same connection);
//! * `succ` — ring edge `r → (r+1) mod n`: data frames out, ACK/NACK
//!   in.
//!
//! Each session runs two threads ([`spawn_rank`] / [`spawn_rank_with`]):
//!
//! * **uplink** reads frames off `ctl` and sends them down `succ` (a
//!   `Shutdown` with `ttl == 0` stops the thread instead);
//! * **relay** receives frames off `pred`; for data frames it writes a
//!   `ttl`-zeroed copy back to the coordinator over `ctl` and, while
//!   `ttl > 1`, forwards the frame to `succ` with `ttl - 1`. A
//!   `Shutdown` frame is forwarded (while `ttl > 1`) but never
//!   delivered, and stops the thread.
//!
//! `succ` is owned by an [`EdgeTx`] shared between the two threads
//! behind a mutex; `ctl` is split by `try_clone` so the directions
//! never contend. A frame injected at `origin` with `ttl = t`
//! traverses `t` real ring edges and produces exactly `t` delivered
//! copies — one from each of ranks `origin+1 … origin+t (mod n)` —
//! which the coordinator collects in deterministic hop order and
//! verifies byte-identical (`net::wire::WireRing`).
//!
//! ## Per-hop recovery (wire protocol v2, DESIGN.md §16)
//!
//! On a v2-negotiated ring every ring-edge data frame runs through a
//! stop-and-wait ARQ: [`EdgeTx`] assigns a per-edge sequence number,
//! transmits, and waits (bounded) for the matching `Ack`; [`EdgeRx`]
//! CRC-validates, suppresses duplicate sequence numbers, and answers
//! corruption or a mid-frame stall with drain-and-resync + `Nack`.
//! Acknowledgment always precedes forwarding/delivery, so only one
//! data frame is ever outstanding per injection and the relay cascade
//! cannot deadlock on the shared `succ` mutex. Recovery activity is
//! accounted in a shared [`RecoveryCounters`] block and surfaced as
//! [`RecoveryStats`]; unrecoverable faults record a typed fatal error
//! there before the session thread dies, so the coordinator can
//! surface *why* instead of a bare timeout. Control channels get the
//! CRC check (v2 framing) but no ARQ — they are process-local pipes.
//!
//! Two wirings share this module: in-process rings build their edges
//! from socket pairs ([`WireStream::pair`]), and external rings
//! rendezvous through a filesystem directory ([`serve_rank`] +
//! `WireRing::connect_external`): rank `r` listens at
//! `<dir>/rank-<r>.sock`, the coordinator at `<dir>/ctl.sock` (`.port`
//! files carrying a loopback TCP port replace `.sock` files under
//! `--transport tcp`).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::codec;
use super::fault::EdgeFaults;
use super::fault::{FaultKind, DEFAULT_ATTEMPTS};
use super::frame::{Frame, FrameMeta, Kind, WireError, FLAG_CAP_V2, HEADER_LEN, V1, VERSION};
use super::TransportKind;

/// How long connect-with-retry waits for a peer to bind (default; the
/// `--wire-timeout-ms` knob overrides it per run).
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Coordinator-side read timeout (default; the `--wire-timeout-ms`
/// knob overrides it per run): a hung rank surfaces as a typed
/// [`WireError::Io`] (`WouldBlock`/`TimedOut`) instead of a hung run.
pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Settle pause before drain-and-resync: lets the tail of a truncated
/// write land so the drain consumes all of it.
const SETTLE: Duration = Duration::from_millis(20);

/// Read timeout while draining a desynchronized edge.
const DRAIN: Duration = Duration::from_millis(20);

/// First reconnect backoff step (microseconds, nominal accounting).
const BACKOFF_BASE_US: u64 = 1_000;

/// Exponential backoff cap (microseconds).
const BACKOFF_CAP_US: u64 = 64_000;

/// Per-frame receive timeout on a v2 ring edge, derived from the wire
/// timeout knob: long enough to never fire on a healthy edge, short
/// enough that a truncated frame is detected well inside the sender's
/// ACK wait.
pub fn rx_frame_timeout(wire_timeout: Duration) -> Duration {
    (wire_timeout / 30).clamp(Duration::from_millis(100), Duration::from_secs(1))
}

/// Sender-side ACK wait: 4× the receive timeout, so the receiver's
/// NACK always wins the race and the sender's timeout only fires when
/// the frame never arrived at all (drop faults, dead peer).
pub fn tx_ack_timeout(wire_timeout: Duration) -> Duration {
    rx_frame_timeout(wire_timeout) * 4
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

fn is_disconnect(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::UnexpectedEof
    )
}

/// Best-effort structural copy of a [`WireError`] (the type holds an
/// `io::Error` and so cannot be `Clone`); used to both *record* a
/// fatal error for the coordinator and *return* it up the thread.
fn mirror(e: &WireError) -> WireError {
    match e {
        WireError::BadMagic => WireError::BadMagic,
        WireError::Version { got, want } => WireError::Version {
            got: *got,
            want: *want,
        },
        WireError::BadKind(b) => WireError::BadKind(*b),
        WireError::Truncated { need, got } => WireError::Truncated {
            need: *need,
            got: *got,
        },
        WireError::Checksum { expected, got } => WireError::Checksum {
            expected: *expected,
            got: *got,
        },
        WireError::Exhausted { attempts } => WireError::Exhausted {
            attempts: *attempts,
        },
        WireError::Corrupt(s) => WireError::Corrupt(s.clone()),
        WireError::Io(io) => WireError::Io(std::io::Error::new(io.kind(), io.to_string())),
    }
}

/// Snapshot of recovery activity on a ring (all edges summed). The
/// counters are cumulative over the ring's lifetime and survive
/// elastic re-rings when the caller threads the same
/// [`RecoveryCounters`] through (as `WireEngine` does).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Data-frame retransmissions (any send attempt after the first).
    pub retransmits: u64,
    /// Connection resets recovered by reconnect + backoff.
    pub reconnects: u64,
    /// Duplicate data frames suppressed by sequence number.
    pub dup_drops: u64,
    /// NACKs issued after corruption or a mid-frame stall.
    pub nacks: u64,
    /// Nominal backoff time spent in reconnects, microseconds.
    pub backoff_us: u64,
}

impl RecoveryStats {
    /// Total discrete recovery events (excludes backoff time).
    pub fn total_events(&self) -> u64 {
        self.retransmits + self.reconnects + self.dup_drops + self.nacks
    }
}

impl std::fmt::Display for RecoveryStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "retransmits={} reconnects={} dup_drops={} nacks={} backoff_us={}",
            self.retransmits, self.reconnects, self.dup_drops, self.nacks, self.backoff_us
        )
    }
}

/// Shared, thread-safe recovery accounting plus a slot for the first
/// typed fatal error a session thread hit (so the coordinator can
/// report the cause instead of a bare control-channel timeout).
#[derive(Debug, Default)]
pub struct RecoveryCounters {
    retransmits: AtomicU64,
    reconnects: AtomicU64,
    dup_drops: AtomicU64,
    nacks: AtomicU64,
    backoff_us: AtomicU64,
    fatal: Mutex<Option<WireError>>,
    /// Teardown flag: set when the coordinator shuts down a ring whose
    /// Shutdown circulation may be broken (a session thread died on an
    /// unrecoverable fault). Survivor relays check it on idle ticks so
    /// every join stays bounded.
    down: AtomicBool,
}

impl RecoveryCounters {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        RecoveryCounters::default()
    }

    /// Current totals. Only authoritative once the ring has shut down
    /// (session threads joined); mid-run snapshots are advisory.
    pub fn snapshot(&self) -> RecoveryStats {
        RecoveryStats {
            retransmits: self.retransmits.load(Ordering::Relaxed),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            dup_drops: self.dup_drops.load(Ordering::Relaxed),
            nacks: self.nacks.load(Ordering::Relaxed),
            backoff_us: self.backoff_us.load(Ordering::Relaxed),
        }
    }

    /// Record the first fatal error (later ones are dropped — the
    /// first cause is the one worth reporting) and return a structural
    /// copy for the caller to propagate.
    pub fn record_fatal(&self, e: WireError) -> WireError {
        let m = mirror(&e);
        let mut slot = self.fatal.lock().expect("fatal slot poisoned");
        if slot.is_none() {
            *slot = Some(e);
        }
        m
    }

    /// Take the recorded fatal error, if any.
    pub fn take_fatal(&self) -> Option<WireError> {
        self.fatal.lock().expect("fatal slot poisoned").take()
    }

    /// True if a fatal error has been recorded (and not yet taken).
    pub fn has_fatal(&self) -> bool {
        self.fatal.lock().expect("fatal slot poisoned").is_some()
    }

    /// Ask surviving relays to exit at their next idle tick — the
    /// teardown path for rings whose Shutdown circulation is broken.
    pub fn request_down(&self) {
        self.down.store(true, Ordering::Relaxed);
    }

    /// True once teardown has been requested.
    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::Relaxed)
    }

    fn bump(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

enum AckOutcome {
    Acked,
    Nacked,
    TimedOut,
    Disconnected,
    Fatal(WireError),
}

/// Sending half of one ring edge: owns the `succ` stream, assigns the
/// per-edge sequence numbers, applies scheduled faults to its own
/// writes, and (on v2) runs the bounded stop-and-wait retransmit loop.
#[derive(Debug)]
pub struct EdgeTx {
    stream: WireStream,
    version: u16,
    seq: u32,
    frames: u64,
    faults: Option<EdgeFaults>,
    attempts: u32,
    counters: Arc<RecoveryCounters>,
}

impl EdgeTx {
    /// Build the sender for one edge. On a v2 ring the stream's read
    /// side is armed with `ack_timeout` (it only ever carries ACK/NACK
    /// traffic back from the successor).
    pub fn new(
        stream: WireStream,
        version: u16,
        faults: Option<EdgeFaults>,
        attempts: u32,
        ack_timeout: Duration,
        counters: Arc<RecoveryCounters>,
    ) -> Result<EdgeTx, WireError> {
        if version >= VERSION {
            stream.set_read_timeout(Some(ack_timeout))?;
        }
        Ok(EdgeTx {
            stream,
            version,
            seq: 0,
            frames: 0,
            faults,
            attempts,
            counters,
        })
    }

    /// Send one data frame down the edge. v1: a single write. v2:
    /// sequence, transmit (with any scheduled fault applied to this
    /// attempt's bytes), await ACK/NACK, retransmit up to the bounded
    /// attempt budget, then fail typed ([`WireError::Exhausted`]).
    pub fn send(&mut self, f: &Frame) -> Result<(), WireError> {
        if self.version < VERSION {
            f.write_to(&mut self.stream)?;
            self.stream.flush()?;
            return Ok(());
        }
        self.seq = self.seq.wrapping_add(1);
        let seq = self.seq;
        let frame_idx = self.frames;
        self.frames += 1;
        let bytes = f.encode_at(VERSION, seq);
        let mut attempt = 0u32;
        while attempt < self.attempts {
            let fault = self.faults.as_ref().and_then(|ef| ef.at(frame_idx, attempt));
            match self.transmit(&bytes, frame_idx, attempt, fault) {
                Ok(true) => {}
                Ok(false) => {
                    // Nothing reached the wire (reset fault / reconnect):
                    // the attempt is consumed, retry after the backoff.
                    attempt += 1;
                    continue;
                }
                Err(e) => return Err(self.counters.record_fatal(e)),
            }
            match self.await_ack(seq) {
                AckOutcome::Acked => return Ok(()),
                AckOutcome::Nacked | AckOutcome::TimedOut => {
                    attempt += 1;
                }
                AckOutcome::Disconnected => {
                    self.reconnect_backoff(attempt);
                    attempt += 1;
                }
                AckOutcome::Fatal(e) => return Err(self.counters.record_fatal(e)),
            }
        }
        let e = WireError::Exhausted {
            attempts: self.attempts,
        };
        Err(self.counters.record_fatal(e))
    }

    /// Write one attempt's bytes, applying `fault`. Returns whether
    /// anything reached the wire (false consumes the attempt without a
    /// transmission — reset faults and real disconnects).
    fn transmit(
        &mut self,
        bytes: &[u8],
        frame_idx: u64,
        attempt: u32,
        fault: Option<FaultKind>,
    ) -> Result<bool, WireError> {
        if attempt > 0 {
            self.counters.bump(&self.counters.retransmits);
        }
        let write = |stream: &mut WireStream, buf: &[u8]| -> Result<(), std::io::Error> {
            stream.write_all(buf)?;
            stream.flush()
        };
        let res = match fault {
            None => write(&mut self.stream, bytes),
            Some(FaultKind::Flip) => {
                let faults = self.faults.as_ref().expect("fault without schedule");
                let bit = faults.flip_bit(frame_idx, attempt, bytes.len());
                let mut c = bytes.to_vec();
                c[bit / 8] ^= 1 << (bit % 8);
                write(&mut self.stream, &c)
            }
            Some(FaultKind::Trunc) => {
                let faults = self.faults.as_ref().expect("fault without schedule");
                let cut = faults.trunc_cut(frame_idx, attempt, bytes.len());
                write(&mut self.stream, &bytes[..cut])
            }
            Some(FaultKind::Drop) => Ok(()), // swallowed; ACK wait times out
            Some(FaultKind::Dup) => {
                write(&mut self.stream, bytes).and_then(|()| write(&mut self.stream, bytes))
            }
            Some(FaultKind::Delay(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                write(&mut self.stream, bytes)
            }
            Some(FaultKind::Reset) => {
                self.reconnect_backoff(attempt);
                return Ok(false);
            }
        };
        match res {
            Ok(()) => Ok(true),
            Err(e) if is_disconnect(&e) => {
                self.reconnect_backoff(attempt);
                Ok(false)
            }
            Err(e) => Err(WireError::Io(e)),
        }
    }

    fn await_ack(&mut self, seq: u32) -> AckOutcome {
        loop {
            match Frame::read_from_ext(&mut self.stream) {
                Ok((af, meta)) => match af.kind {
                    Kind::Ack if meta.seq == seq => return AckOutcome::Acked,
                    Kind::Ack => continue, // stale ack from an earlier exchange
                    Kind::Nack => return AckOutcome::Nacked,
                    other => {
                        return AckOutcome::Fatal(WireError::Corrupt(format!(
                            "unexpected {other:?} frame on ack channel"
                        )))
                    }
                },
                Err(WireError::Io(e)) if is_timeout(&e) => return AckOutcome::TimedOut,
                Err(WireError::Io(e)) if is_disconnect(&e) => return AckOutcome::Disconnected,
                Err(e) => return AckOutcome::Fatal(e),
            }
        }
    }

    /// Account one reconnect with capped exponential backoff. For
    /// in-process rings the underlying socket pair is reused (there is
    /// no address to redial), so the backoff time is *nominal* but the
    /// accounting — and the sleep, which keeps pacing honest — is real.
    fn reconnect_backoff(&self, attempt: u32) {
        self.counters.bump(&self.counters.reconnects);
        let nominal = BACKOFF_BASE_US
            .saturating_mul(1u64 << attempt.min(6))
            .min(BACKOFF_CAP_US);
        self.counters.backoff_us.fetch_add(nominal, Ordering::Relaxed);
        std::thread::sleep(Duration::from_micros(nominal));
    }
}

/// Receiving half of one ring edge: CRC-validates, suppresses
/// duplicate sequence numbers, and converts corruption or a mid-frame
/// stall into drain-and-resync + NACK so the sender retransmits.
#[derive(Debug)]
pub struct EdgeRx {
    stream: WireStream,
    rank: u16,
    version: u16,
    last_seq: u32,
    frame_timeout: Duration,
    counters: Arc<RecoveryCounters>,
}

impl EdgeRx {
    /// Build the receiver for one edge. On a v2 ring the stream is
    /// armed with `frame_timeout` so a frame that starts but never
    /// finishes (truncation) is detected and NACKed.
    pub fn new(
        stream: WireStream,
        rank: u16,
        version: u16,
        frame_timeout: Duration,
        counters: Arc<RecoveryCounters>,
    ) -> Result<EdgeRx, WireError> {
        if version >= VERSION {
            stream.set_read_timeout(Some(frame_timeout))?;
        }
        Ok(EdgeRx {
            stream,
            rank,
            version,
            last_seq: 0,
            frame_timeout,
            counters,
        })
    }

    /// Receive the next in-order data frame. `Ok(None)` is an idle
    /// tick (no frame started within the timeout) — callers just loop.
    /// The matching ACK is written *before* returning, so the sender
    /// unblocks before this rank forwards or delivers.
    pub fn recv(&mut self) -> Result<Option<Frame>, WireError> {
        if self.version < VERSION {
            // v1 edges keep the original blocking semantics.
            return Frame::read_from(&mut self.stream).map(Some);
        }
        loop {
            // 1-byte probe: distinguishes "edge idle" (timeout before
            // any byte) from "mid-frame stall" (timeout after some).
            let mut first = [0u8; 1];
            match self.stream.read(&mut first) {
                Ok(0) => {
                    return Err(WireError::Io(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "ring edge closed",
                    )))
                }
                Ok(_) => {}
                Err(e) if is_timeout(&e) => return Ok(None),
                Err(e) => return Err(WireError::Io(e)),
            }
            match self.read_rest(first[0]) {
                Ok((f, meta)) => {
                    if matches!(f.kind, Kind::Ack | Kind::Nack) {
                        // Defensive: control frames never travel this
                        // direction; ignore rather than desync.
                        continue;
                    }
                    if meta.seq <= self.last_seq {
                        // Duplicate (retransmit we already ACKed, or a
                        // dup fault): suppress silently — re-ACKing
                        // would confuse the stop-and-wait sender.
                        self.counters.bump(&self.counters.dup_drops);
                        continue;
                    }
                    if meta.seq != self.last_seq.wrapping_add(1) {
                        return Err(WireError::Corrupt(format!(
                            "edge sequence gap: expected {}, got {}",
                            self.last_seq.wrapping_add(1),
                            meta.seq
                        )));
                    }
                    self.last_seq = meta.seq;
                    self.ack(Kind::Ack, meta.seq, f.epoch)?;
                    return Ok(Some(f));
                }
                Err(WireError::Io(e)) if is_timeout(&e) => {
                    // Mid-frame stall (truncated write): resync + NACK.
                    self.resync_and_nack()?;
                }
                Err(WireError::Io(e)) => return Err(WireError::Io(e)),
                Err(_corrupt) => {
                    // Checksum / magic / kind / version / length damage:
                    // recoverable — resync + NACK for a retransmit.
                    self.resync_and_nack()?;
                }
            }
        }
    }

    fn read_rest(&mut self, first: u8) -> Result<(Frame, FrameMeta), WireError> {
        let mut header = [0u8; HEADER_LEN];
        header[0] = first;
        self.stream.read_exact(&mut header[1..])?;
        Frame::read_body_ext(&mut self.stream, &header)
    }

    /// After corruption the byte stream may be desynchronized (a
    /// truncated frame leaves a partial tail). Under stop-and-wait at
    /// most one data frame is in flight, so: settle briefly, drain
    /// whatever is buffered, then NACK to request the retransmit.
    fn resync_and_nack(&mut self) -> Result<(), WireError> {
        std::thread::sleep(SETTLE);
        self.stream.set_read_timeout(Some(DRAIN))?;
        let mut scratch = [0u8; 4096];
        loop {
            match self.stream.read(&mut scratch) {
                Ok(0) => break, // EOF surfaces at the next probe
                Ok(_) => continue,
                Err(e) if is_timeout(&e) => break,
                Err(e) => {
                    let _ = self.stream.set_read_timeout(Some(self.frame_timeout));
                    return Err(WireError::Io(e));
                }
            }
        }
        self.stream.set_read_timeout(Some(self.frame_timeout))?;
        self.counters.bump(&self.counters.nacks);
        self.ack(Kind::Nack, self.last_seq.wrapping_add(1), 0)
    }

    fn ack(&mut self, kind: Kind, seq: u32, epoch: u32) -> Result<(), WireError> {
        let f = Frame::new(kind, self.rank, 0, epoch, Vec::new());
        f.write_to_at(&mut self.stream, VERSION, seq)?;
        self.stream.flush()?;
        Ok(())
    }
}

/// One connected stream of either transport flavor.
#[derive(Debug)]
pub enum WireStream {
    /// Unix domain socket.
    Unix(UnixStream),
    /// Loopback (or remote) TCP socket, `TCP_NODELAY` set.
    Tcp(TcpStream),
}

impl WireStream {
    /// Clone the underlying socket (independent file descriptor over
    /// the same connection — used to split ctl into read/write halves).
    pub fn try_clone(&self) -> Result<WireStream, WireError> {
        Ok(match self {
            WireStream::Unix(s) => WireStream::Unix(s.try_clone()?),
            WireStream::Tcp(s) => WireStream::Tcp(s.try_clone()?),
        })
    }

    /// Set (or clear) the blocking-read timeout.
    pub fn set_read_timeout(&self, d: Option<Duration>) -> Result<(), WireError> {
        match self {
            WireStream::Unix(s) => s.set_read_timeout(d)?,
            WireStream::Tcp(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }

    /// Connected socket pair for in-process rings. For TCP the pair is
    /// built through an ephemeral loopback listener.
    pub fn pair(kind: TransportKind) -> Result<(WireStream, WireStream), WireError> {
        match kind {
            TransportKind::Sim => Err(WireError::Corrupt(
                "transport `sim` has no socket pairs".into(),
            )),
            TransportKind::Uds => {
                let (a, b) = UnixStream::pair()?;
                Ok((WireStream::Unix(a), WireStream::Unix(b)))
            }
            TransportKind::Tcp => {
                let listener = TcpListener::bind("127.0.0.1:0")?;
                let addr = listener.local_addr()?;
                let a = TcpStream::connect(addr)?;
                let (b, _) = listener.accept()?;
                a.set_nodelay(true)?;
                b.set_nodelay(true)?;
                Ok((WireStream::Tcp(a), WireStream::Tcp(b)))
            }
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.read(buf),
            WireStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.write(buf),
            WireStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            WireStream::Unix(s) => s.flush(),
            WireStream::Tcp(s) => s.flush(),
        }
    }
}

/// Rendezvous listener for external (serve-mode) rings.
#[derive(Debug)]
pub enum WireListener {
    /// Filesystem Unix socket at `<dir>/<name>.sock`.
    Unix(UnixListener),
    /// Loopback TCP listener, its port advertised in `<dir>/<name>.port`.
    Tcp(TcpListener),
}

impl WireListener {
    /// Bind the rendezvous point `<dir>/<name>` for the given
    /// transport, replacing any stale socket/port file.
    pub fn bind(dir: &Path, name: &str, kind: TransportKind) -> Result<WireListener, WireError> {
        match kind {
            TransportKind::Sim => Err(WireError::Corrupt(
                "transport `sim` has no listeners".into(),
            )),
            TransportKind::Uds => {
                let path = sock_path(dir, name);
                let _ = std::fs::remove_file(&path);
                Ok(WireListener::Unix(UnixListener::bind(&path)?))
            }
            TransportKind::Tcp => {
                let listener = TcpListener::bind("127.0.0.1:0")?;
                let port = listener.local_addr()?.port();
                let path = port_path(dir, name);
                let tmp = path.with_extension("port.tmp");
                std::fs::write(&tmp, port.to_string())?;
                std::fs::rename(&tmp, &path)?;
                Ok(WireListener::Tcp(listener))
            }
        }
    }

    /// Accept one connection.
    pub fn accept(&self) -> Result<WireStream, WireError> {
        Ok(match self {
            WireListener::Unix(l) => WireStream::Unix(l.accept()?.0),
            WireListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nodelay(true)?;
                WireStream::Tcp(s)
            }
        })
    }
}

fn sock_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.sock"))
}

fn port_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.port"))
}

/// Connect to rendezvous point `<dir>/<name>`, retrying until the
/// peer binds or [`CONNECT_TIMEOUT`] expires.
pub fn connect_retry(dir: &Path, name: &str, kind: TransportKind) -> Result<WireStream, WireError> {
    connect_retry_with(dir, name, kind, CONNECT_TIMEOUT)
}

/// [`connect_retry`] with an explicit deadline (the `--wire-timeout-ms`
/// knob).
pub fn connect_retry_with(
    dir: &Path,
    name: &str,
    kind: TransportKind,
    timeout: Duration,
) -> Result<WireStream, WireError> {
    let start = Instant::now();
    loop {
        let attempt: std::io::Result<WireStream> = match kind {
            TransportKind::Sim => {
                return Err(WireError::Corrupt("transport `sim` has no sockets".into()))
            }
            TransportKind::Uds => UnixStream::connect(sock_path(dir, name)).map(WireStream::Unix),
            TransportKind::Tcp => std::fs::read_to_string(port_path(dir, name)).and_then(|p| {
                let port: u16 = p.trim().parse().map_err(|_| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, "bad port file")
                })?;
                let s = TcpStream::connect(("127.0.0.1", port))?;
                s.set_nodelay(true)?;
                Ok(WireStream::Tcp(s))
            }),
        };
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) if start.elapsed() >= timeout => {
                return Err(WireError::Io(std::io::Error::new(
                    e.kind(),
                    format!("connecting to {name} in {}: {e}", dir.display()),
                )))
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Per-session wiring options: the negotiated wire version, this
/// rank's outgoing-edge fault schedule, the bounded retry budget, the
/// wire timeout the ARQ deadlines derive from, and the shared recovery
/// counters.
#[derive(Debug, Clone)]
pub struct SessionOpts {
    /// Negotiated wire version ([`V1`] or [`VERSION`]).
    pub version: u16,
    /// Fault schedule for this rank's outgoing edge (tests/chaos only).
    pub faults: Option<EdgeFaults>,
    /// Bounded per-frame send-attempt budget.
    pub attempts: u32,
    /// Wire timeout the ARQ receive/ack deadlines derive from.
    pub timeout: Duration,
    /// Shared recovery accounting (one block per ring).
    pub counters: Arc<RecoveryCounters>,
}

impl Default for SessionOpts {
    fn default() -> Self {
        SessionOpts {
            version: V1,
            faults: None,
            attempts: DEFAULT_ATTEMPTS,
            timeout: READ_TIMEOUT,
            counters: Arc::new(RecoveryCounters::new()),
        }
    }
}

/// Join handles for one rank session's two threads.
#[derive(Debug)]
pub struct RankSession {
    uplink: std::thread::JoinHandle<Result<(), WireError>>,
    relay: std::thread::JoinHandle<Result<(), WireError>>,
}

impl RankSession {
    /// Wait for both threads; first error wins.
    pub fn join(self) -> Result<(), WireError> {
        let u = self
            .uplink
            .join()
            .unwrap_or_else(|_| Err(WireError::Corrupt("uplink thread panicked".into())));
        let r = self
            .relay
            .join()
            .unwrap_or_else(|_| Err(WireError::Corrupt("relay thread panicked".into())));
        u?;
        r
    }
}

/// Spawn the uplink + relay threads for one rank session at wire
/// version 1 with no faults (byte-compatible with the pre-v2 ring).
pub fn spawn_rank(
    rank: u16,
    ctl: WireStream,
    pred: WireStream,
    succ: WireStream,
) -> Result<RankSession, WireError> {
    spawn_rank_with(rank, ctl, pred, succ, SessionOpts::default())
}

/// Spawn the uplink + relay threads for one rank session. `ctl` is
/// split internally; `succ` is wrapped in an [`EdgeTx`] shared behind
/// a mutex. On a v2 session both ring-edge directions run the ARQ
/// described in the module docs; fatal errors are recorded in
/// `opts.counters` before a thread dies.
pub fn spawn_rank_with(
    rank: u16,
    ctl: WireStream,
    pred: WireStream,
    succ: WireStream,
    opts: SessionOpts,
) -> Result<RankSession, WireError> {
    let version = opts.version;
    let counters = opts.counters;
    let mut ctl_r = ctl.try_clone()?; // uplink reads injections
    let mut ctl_w = ctl; // relay writes deliveries
    let tx = EdgeTx::new(
        succ,
        version,
        opts.faults,
        opts.attempts,
        tx_ack_timeout(opts.timeout),
        counters.clone(),
    )?;
    let tx = Arc::new(Mutex::new(tx));

    let tx_up = tx.clone();
    let counters_up = counters.clone();
    let uplink = std::thread::Builder::new()
        .name(format!("riwp-uplink-{rank}"))
        .spawn(move || -> Result<(), WireError> {
            loop {
                let f = match Frame::read_from(&mut ctl_r) {
                    Ok(f) => f,
                    Err(e) => return Err(counters_up.record_fatal(e)),
                };
                if f.kind == Kind::Shutdown && f.ttl == 0 {
                    return Ok(());
                }
                let mut s = tx_up.lock().expect("edge tx mutex poisoned");
                s.send(&f)?; // send() records its own fatal
            }
        })?;

    let mut rx = EdgeRx::new(pred, rank, version, rx_frame_timeout(opts.timeout), counters.clone())?;
    let relay = std::thread::Builder::new()
        .name(format!("riwp-relay-{rank}"))
        .spawn(move || -> Result<(), WireError> {
            loop {
                let f = match rx.recv() {
                    Ok(Some(f)) => f,
                    Ok(None) => {
                        // Idle tick on a v2 edge. If the coordinator
                        // requested teardown (broken Shutdown
                        // circulation after a fatal), exit here.
                        if counters.is_down() {
                            return Ok(());
                        }
                        continue;
                    }
                    Err(e) => return Err(counters.record_fatal(e)),
                };
                let forward = f.ttl > 1;
                if forward {
                    let fwd = Frame {
                        ttl: f.ttl - 1,
                        payload: f.payload.clone(),
                        ..f
                    };
                    let mut s = tx.lock().expect("edge tx mutex poisoned");
                    s.send(&fwd)?; // send() records its own fatal
                }
                if f.kind == Kind::Shutdown {
                    return Ok(());
                }
                // Deliver a ttl-normalized copy so every hop's copy of
                // the same injection is byte-identical at the
                // coordinator. Control frames carry no ARQ (seq 0) but
                // do carry the v2 CRC when the ring negotiated it.
                let delivered = Frame { ttl: 0, ..f };
                if let Err(e) = delivered
                    .write_to_at(&mut ctl_w, version, 0)
                    .and_then(|()| ctl_w.flush().map_err(WireError::Io))
                {
                    return Err(counters.record_fatal(e));
                }
            }
        })?;

    Ok(RankSession { uplink, relay })
}

/// Options for [`serve_rank_with`]: the rendezvous/read deadline and a
/// caller-owned counter block so recovery stats survive even an
/// erroring session.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Connect/read deadline (the `--wire-timeout-ms` knob).
    pub timeout: Duration,
    /// Shared recovery accounting (snapshot it after serving).
    pub counters: Arc<RecoveryCounters>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            timeout: READ_TIMEOUT,
            counters: Arc::new(RecoveryCounters::new()),
        }
    }
}

/// What [`serve_rank_with`] served.
#[derive(Debug, Clone, Copy)]
pub struct ServeReport {
    /// Completed sessions.
    pub sessions: u32,
    /// Recovery totals across all sessions served.
    pub recovery: RecoveryStats,
}

/// Run rank `rank` of an `n`-node external ring rendezvousing in
/// `dir` (version-1 compatible wrapper; see [`serve_rank_with`]).
/// Returns the number of sessions served.
pub fn serve_rank(
    dir: &Path,
    rank: u16,
    n: u16,
    kind: TransportKind,
    once: bool,
) -> Result<u32, WireError> {
    serve_rank_with(dir, rank, n, kind, once, ServeOpts::default()).map(|r| r.sessions)
}

/// Run rank `rank` of an `n`-node external ring rendezvousing in
/// `dir`: handshake with the coordinator (advertising v2 capability
/// via [`FLAG_CAP_V2`] and honoring the coordinator's decision), wire
/// the ring edges, then relay until the coordinator shuts the session
/// down. Loops over sessions (re-connecting after each shutdown)
/// unless `once` is set.
pub fn serve_rank_with(
    dir: &Path,
    rank: u16,
    n: u16,
    kind: TransportKind,
    once: bool,
    opts: ServeOpts,
) -> Result<ServeReport, WireError> {
    assert!(n >= 2, "ring needs at least 2 ranks");
    assert!(rank < n, "rank {rank} out of range for n={n}");
    let listener = WireListener::bind(dir, &format!("rank-{rank}"), kind)?;
    let mut sessions = 0u32;
    loop {
        // Handshake: Hello(rank, n) → coordinator, HelloAck back. The
        // handshake always travels at wire version 1 — that is what
        // makes the capability negotiation possible at all.
        let mut ctl = connect_retry_with(dir, "ctl", kind, opts.timeout)?;
        let mut hello = Frame::new(Kind::Hello, rank, 0, 0, codec::encode_hello(rank, n));
        hello.flags = FLAG_CAP_V2;
        hello.write_to(&mut ctl)?;
        ctl.flush()?;
        let ack = Frame::read_from(&mut ctl)?;
        if ack.kind != Kind::HelloAck {
            return Err(WireError::Corrupt(format!(
                "expected HelloAck, got {:?}",
                ack.kind
            )));
        }
        let version = if ack.flags & FLAG_CAP_V2 != 0 { VERSION } else { V1 };
        let links = codec::decode_hello_ack(&ack.payload)?;
        if links.len() != n as usize {
            return Err(WireError::Corrupt(format!(
                "HelloAck carries {} links for an n={n} ring",
                links.len()
            )));
        }
        // Ring edges: connect succ first (connects complete against a
        // bound listener's backlog without an accept), then accept pred.
        let succ = connect_retry_with(dir, &format!("rank-{}", (rank + 1) % n), kind, opts.timeout)?;
        let pred = listener.accept()?;
        let session_opts = SessionOpts {
            version,
            faults: None, // fault injection is in-process only
            attempts: DEFAULT_ATTEMPTS,
            timeout: opts.timeout,
            counters: opts.counters.clone(),
        };
        spawn_rank_with(rank, ctl, pred, succ, session_opts)?.join()?;
        sessions += 1;
        if once {
            return Ok(ServeReport {
                sessions,
                recovery: opts.counters.snapshot(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_pair_roundtrips_frames() {
        for kind in [TransportKind::Uds, TransportKind::Tcp] {
            let (mut a, mut b) = WireStream::pair(kind).unwrap();
            let f = Frame::new(Kind::Dense, 1, 2, 3, vec![7; 33]);
            f.write_to(&mut a).unwrap();
            assert_eq!(Frame::read_from(&mut b).unwrap(), f, "{kind:?}");
        }
    }

    #[test]
    fn sim_transport_has_no_sockets() {
        assert!(WireStream::pair(TransportKind::Sim).is_err());
    }

    #[test]
    fn arq_timeouts_derive_from_the_wire_knob() {
        // Defaults: 30s knob → 1s frame timeout, 4s ack wait.
        assert_eq!(rx_frame_timeout(READ_TIMEOUT), Duration::from_secs(1));
        assert_eq!(tx_ack_timeout(READ_TIMEOUT), Duration::from_secs(4));
        // Small knobs clamp at 100ms so the probe loop stays sane.
        assert_eq!(
            rx_frame_timeout(Duration::from_millis(300)),
            Duration::from_millis(100)
        );
        // The receiver always detects (and NACKs) before the sender's
        // ack wait fires — the invariant the recovery design rests on.
        for ms in [300u64, 2_000, 30_000, 600_000] {
            let t = Duration::from_millis(ms);
            assert!(tx_ack_timeout(t) >= rx_frame_timeout(t) * 2 + SETTLE + DRAIN);
        }
    }

    #[test]
    fn relay_delivers_and_forwards_with_decrement() {
        // 2-rank micro-ring driven by hand: coordinator ctl pairs plus
        // one edge in each direction.
        let (ctl0_coord, ctl0_rank) = WireStream::pair(TransportKind::Uds).unwrap();
        let (ctl1_coord, ctl1_rank) = WireStream::pair(TransportKind::Uds).unwrap();
        let (edge01_w, edge01_r) = WireStream::pair(TransportKind::Uds).unwrap();
        let (edge10_w, edge10_r) = WireStream::pair(TransportKind::Uds).unwrap();
        let s0 = spawn_rank(0, ctl0_rank, edge10_r, edge01_w).unwrap();
        let s1 = spawn_rank(1, ctl1_rank, edge01_r, edge10_w).unwrap();

        let mut ctl0 = ctl0_coord;
        let mut ctl1 = ctl1_coord;
        // Inject at rank 0 with ttl=2: rank 1 delivers + forwards,
        // rank 0 delivers.
        let f = Frame::new(Kind::Tern, 0, 2, 9, vec![1, 2, 3]);
        f.write_to(&mut ctl0).unwrap();
        let d1 = Frame::read_from(&mut ctl1).unwrap();
        let d0 = Frame::read_from(&mut ctl0).unwrap();
        for d in [&d1, &d0] {
            assert_eq!(d.ttl, 0);
            assert_eq!(d.epoch, 9);
            assert_eq!(d.payload, vec![1, 2, 3]);
        }
        // Teardown: ring Shutdown stops both relays, ttl=0 Shutdowns
        // stop both uplinks.
        Frame::new(Kind::Shutdown, 0, 2, 9, Vec::new())
            .write_to(&mut ctl0)
            .unwrap();
        Frame::new(Kind::Shutdown, 0, 0, 9, Vec::new())
            .write_to(&mut ctl0)
            .unwrap();
        Frame::new(Kind::Shutdown, 0, 0, 9, Vec::new())
            .write_to(&mut ctl1)
            .unwrap();
        s0.join().unwrap();
        s1.join().unwrap();
    }

    #[test]
    fn v2_session_relays_with_arq_and_crc() {
        // Same micro-ring, negotiated at v2: injections and deliveries
        // on ctl carry the CRC (seq 0), edge traffic is sequenced and
        // acknowledged end to end.
        let counters = Arc::new(RecoveryCounters::new());
        let opts = |c: &Arc<RecoveryCounters>| SessionOpts {
            version: VERSION,
            timeout: Duration::from_secs(3),
            counters: c.clone(),
            ..SessionOpts::default()
        };
        let (ctl0_coord, ctl0_rank) = WireStream::pair(TransportKind::Uds).unwrap();
        let (ctl1_coord, ctl1_rank) = WireStream::pair(TransportKind::Uds).unwrap();
        let (edge01_w, edge01_r) = WireStream::pair(TransportKind::Uds).unwrap();
        let (edge10_w, edge10_r) = WireStream::pair(TransportKind::Uds).unwrap();
        let s0 = spawn_rank_with(0, ctl0_rank, edge10_r, edge01_w, opts(&counters)).unwrap();
        let s1 = spawn_rank_with(1, ctl1_rank, edge01_r, edge10_w, opts(&counters)).unwrap();

        let mut ctl0 = ctl0_coord;
        let mut ctl1 = ctl1_coord;
        let f = Frame::new(Kind::Tern, 0, 2, 9, vec![4, 5, 6]);
        f.write_to_at(&mut ctl0, VERSION, 0).unwrap();
        let (d1, m1) = Frame::read_from_ext(&mut ctl1).unwrap();
        let (d0, m0) = Frame::read_from_ext(&mut ctl0).unwrap();
        for (d, m) in [(&d1, m1), (&d0, m0)] {
            assert_eq!(d.ttl, 0);
            assert_eq!(d.payload, vec![4, 5, 6]);
            assert_eq!(m.version, VERSION);
        }
        Frame::new(Kind::Shutdown, 0, 2, 9, Vec::new())
            .write_to_at(&mut ctl0, VERSION, 0)
            .unwrap();
        Frame::new(Kind::Shutdown, 0, 0, 9, Vec::new())
            .write_to_at(&mut ctl0, VERSION, 0)
            .unwrap();
        Frame::new(Kind::Shutdown, 0, 0, 9, Vec::new())
            .write_to_at(&mut ctl1, VERSION, 0)
            .unwrap();
        s0.join().unwrap();
        s1.join().unwrap();
        // Clean run: no recovery events fired.
        assert_eq!(counters.snapshot(), RecoveryStats::default());
        assert!(counters.take_fatal().is_none());
    }
}
