//! Payload codecs for the five traveling representations plus the
//! handshake bodies (DESIGN.md §13).
//!
//! Each codec is a pure `encode → Vec<u8>` / `decode → Result<T>`
//! pair, byte-exact under roundtrip (f32/f64 travel as IEEE-754 bit
//! patterns via `to_le_bytes`, so NaN payloads and negative zeros
//! survive untouched). Decoders validate every length field against
//! the bytes actually present and return typed [`WireError`]s —
//! `tests/wire_codec.rs` drives the edge shapes (empty support,
//! unaligned trailing mask words, single-element layers) and the
//! malformed inputs.
//!
//! Layouts (all little-endian):
//!
//! ```text
//! dense    : len u32 | len × f32
//! support  : len u32 | ceil(len/8) mask bytes   (BitMask::encode_u8)
//! masked   : len u32 | nnz u32 | ceil(len/8) mask bytes | nnz × f32
//! terngrad : len u32 | n_scales u32 | n_scales × f32 | ceil(len/4) codes
//! ternblob : len u32 | scale f32 | ceil(len/4) codes
//! qblob    : width u8 | block u32 | len u32 | scales × f32 | codes
//!            (scale count = ceil(len/block) for k-bit widths, 0 for
//!            bf16/f16; code bytes = ceil(len·k/8) resp. 2·len — both
//!            derived, so a lying field is caught by the exact takes)
//! hello    : rank u16 | n u16
//! helloack : n_links u32 | n_links × (bandwidth f64 | latency f64)
//! ```
//!
//! These layouts are identical at wire versions 1 and 2: the §16
//! integrity layer (CRC32 + sequence trailer, `frame.rs`) wraps
//! *around* the payload, so the codecs never see it. Hello/HelloAck in
//! particular must stay byte-stable across versions — the capability
//! negotiation rides the `flags` header byte, never the body.

use super::frame::WireError;
use crate::compress::quant::{QBlob, QuantWidth};
use crate::compress::terngrad::{TernBlob, TernGrad};
use crate::net::LinkSpec;
use crate::sparse::BitMask;

/// Byte cursor with typed truncation errors.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated {
                need: self.pos + n,
                got: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Bytes not yet consumed — bounds pre-allocation so a garbage
    /// length field cannot reserve gigabytes before the take fails.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// All bytes consumed? Trailing garbage is corruption, not slack.
    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Corrupt(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Guard a decoded length field before allocating.
fn checked_len(len: u32, what: &str) -> Result<usize, WireError> {
    if len > super::frame::MAX_PAYLOAD {
        return Err(WireError::Corrupt(format!(
            "{what} length {len} exceeds cap"
        )));
    }
    Ok(len as usize)
}

// ---------------------------------------------------------------- dense

/// Encode a dense f32 chunk.
pub fn encode_dense(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 4 * values.len());
    out.extend_from_slice(&(values.len() as u32).to_le_bytes());
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a dense f32 chunk.
pub fn decode_dense(buf: &[u8]) -> Result<Vec<f32>, WireError> {
    let mut c = Cursor::new(buf);
    let len = checked_len(c.u32()?, "dense")?;
    let mut out = Vec::with_capacity(len.min(c.remaining() / 4));
    for _ in 0..len {
        out.push(c.f32()?);
    }
    c.finish()?;
    Ok(out)
}

// -------------------------------------------------------------- support

/// Encode a sparse support bitmask segment.
pub fn encode_support(mask: &BitMask) -> Vec<u8> {
    let bytes = mask.encode_u8();
    let mut out = Vec::with_capacity(4 + bytes.len());
    out.extend_from_slice(&(mask.len() as u32).to_le_bytes());
    out.extend_from_slice(&bytes);
    out
}

/// Decode a sparse support bitmask segment.
pub fn decode_support(buf: &[u8]) -> Result<BitMask, WireError> {
    let mut c = Cursor::new(buf);
    let len = checked_len(c.u32()?, "support")?;
    let mask_bytes = c.take(len.div_ceil(8))?;
    c.finish()?;
    BitMask::decode_u8(mask_bytes, len)
        .map_err(|e| WireError::Corrupt(format!("support mask: {e}")))
}

// --------------------------------------------------------------- masked

/// Encode a word-packed mask plus its compacted values. `values` must
/// hold exactly `mask.count()` entries in support order.
pub fn encode_masked(mask: &BitMask, values: &[f32]) -> Vec<u8> {
    assert_eq!(
        values.len(),
        mask.count(),
        "masked payload: values must match mask support"
    );
    let mask_bytes = mask.encode_u8();
    let mut out = Vec::with_capacity(8 + mask_bytes.len() + 4 * values.len());
    out.extend_from_slice(&(mask.len() as u32).to_le_bytes());
    out.extend_from_slice(&(values.len() as u32).to_le_bytes());
    out.extend_from_slice(&mask_bytes);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode a masked blob into (mask, compacted values).
pub fn decode_masked(buf: &[u8]) -> Result<(BitMask, Vec<f32>), WireError> {
    let mut c = Cursor::new(buf);
    let len = checked_len(c.u32()?, "masked")?;
    let nnz = checked_len(c.u32()?, "masked nnz")?;
    let mask_bytes = c.take(len.div_ceil(8))?;
    let mask = BitMask::decode_u8(mask_bytes, len)
        .map_err(|e| WireError::Corrupt(format!("masked mask: {e}")))?;
    if mask.count() != nnz {
        return Err(WireError::Corrupt(format!(
            "masked payload: mask popcount {} != declared nnz {nnz}",
            mask.count()
        )));
    }
    let mut values = Vec::with_capacity(nnz.min(c.remaining() / 4));
    for _ in 0..nnz {
        values.push(c.f32()?);
    }
    c.finish()?;
    Ok((mask, values))
}

// ------------------------------------------------------------- ternary

/// Encode a per-layer-scaled [`TernGrad`].
pub fn encode_tern_grad(t: &TernGrad) -> Vec<u8> {
    debug_assert_eq!(t.codes.len(), t.len.div_ceil(4));
    let mut out = Vec::with_capacity(8 + 4 * t.scales.len() + t.codes.len());
    out.extend_from_slice(&(t.len as u32).to_le_bytes());
    out.extend_from_slice(&(t.scales.len() as u32).to_le_bytes());
    for s in &t.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend_from_slice(&t.codes);
    out
}

/// Decode a [`TernGrad`].
pub fn decode_tern_grad(buf: &[u8]) -> Result<TernGrad, WireError> {
    let mut c = Cursor::new(buf);
    let len = checked_len(c.u32()?, "terngrad")?;
    let n_scales = checked_len(c.u32()?, "terngrad scales")?;
    let mut scales = Vec::with_capacity(n_scales.min(c.remaining() / 4));
    for _ in 0..n_scales {
        scales.push(c.f32()?);
    }
    let codes = c.take(len.div_ceil(4))?.to_vec();
    c.finish()?;
    Ok(TernGrad { len, scales, codes })
}

/// Encode a single-scale [`TernBlob`].
pub fn encode_tern_blob(t: &TernBlob) -> Vec<u8> {
    debug_assert_eq!(t.codes.len(), t.len.div_ceil(4));
    let mut out = Vec::with_capacity(8 + t.codes.len());
    out.extend_from_slice(&(t.len as u32).to_le_bytes());
    out.extend_from_slice(&t.scale.to_le_bytes());
    out.extend_from_slice(&t.codes);
    out
}

/// Decode a [`TernBlob`].
pub fn decode_tern_blob(buf: &[u8]) -> Result<TernBlob, WireError> {
    let mut c = Cursor::new(buf);
    let len = checked_len(c.u32()?, "ternblob")?;
    let scale = c.f32()?;
    let codes = c.take(len.div_ceil(4))?.to_vec();
    c.finish()?;
    Ok(TernBlob { len, scale, codes })
}

// ---------------------------------------------------------------- qblob

/// Encode a low-precision [`QBlob`] (`+q:<bits>` payload).
pub fn encode_q_blob(q: &QBlob) -> Vec<u8> {
    debug_assert_eq!(q.codes.len(), q.width.code_bytes(q.len));
    let mut out = Vec::with_capacity(9 + 4 * q.scales.len() + q.codes.len());
    out.push(q.width.wire_tag());
    out.extend_from_slice(&(q.block as u32).to_le_bytes());
    out.extend_from_slice(&(q.len as u32).to_le_bytes());
    for s in &q.scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out.extend_from_slice(&q.codes);
    out
}

/// Decode a [`QBlob`]. Scale and code counts are derived from the
/// validated `(width, block, len)` triple, never trusted from the
/// buffer, so the exact `take`s below reject any inconsistent length.
pub fn decode_q_blob(buf: &[u8]) -> Result<QBlob, WireError> {
    let mut c = Cursor::new(buf);
    let tag = c.take(1)?[0];
    let width = QuantWidth::from_wire_tag(tag)
        .ok_or_else(|| WireError::Corrupt(format!("qblob: unknown width tag {tag}")))?;
    let block = checked_len(c.u32()?, "qblob block")?;
    let len = checked_len(c.u32()?, "qblob")?;
    let n_scales = if width.is_float() {
        if block != 0 {
            return Err(WireError::Corrupt(format!(
                "qblob: float width {width} with nonzero scale block {block}"
            )));
        }
        0
    } else {
        if block == 0 {
            return Err(WireError::Corrupt(format!(
                "qblob: k-bit width {width} with zero scale block"
            )));
        }
        len.div_ceil(block)
    };
    let mut scales = Vec::with_capacity(n_scales.min(c.remaining() / 4));
    for _ in 0..n_scales {
        scales.push(c.f32()?);
    }
    let codes = c.take(width.code_bytes(len))?.to_vec();
    c.finish()?;
    Ok(QBlob { width, len, block, scales, codes })
}

// ------------------------------------------------------------ handshake

/// Encode a Hello body (rank + ring size; protocol version lives in
/// the frame header, so skew is caught before the body is read).
pub fn encode_hello(rank: u16, n: u16) -> Vec<u8> {
    let mut out = Vec::with_capacity(4);
    out.extend_from_slice(&rank.to_le_bytes());
    out.extend_from_slice(&n.to_le_bytes());
    out
}

/// Decode a Hello body into (rank, ring size).
pub fn decode_hello(buf: &[u8]) -> Result<(u16, u16), WireError> {
    let mut c = Cursor::new(buf);
    let rank = c.u16()?;
    let n = c.u16()?;
    c.finish()?;
    Ok((rank, n))
}

/// Encode a HelloAck body carrying every hop's link parameters (the
/// heterogeneous-link seam of ROADMAP item 3; entry `i` is rank `i`'s
/// egress edge).
pub fn encode_hello_ack(links: &[LinkSpec]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 16 * links.len());
    out.extend_from_slice(&(links.len() as u32).to_le_bytes());
    for l in links {
        out.extend_from_slice(&l.bandwidth_bps.to_le_bytes());
        out.extend_from_slice(&l.latency_s.to_le_bytes());
    }
    out
}

/// Decode a HelloAck body into per-hop link parameters.
pub fn decode_hello_ack(buf: &[u8]) -> Result<Vec<LinkSpec>, WireError> {
    let mut c = Cursor::new(buf);
    let n = checked_len(c.u32()?, "helloack")?;
    let mut links = Vec::with_capacity(n.min(c.remaining() / 16));
    for _ in 0..n {
        let bandwidth = c.f64()?;
        let latency = c.f64()?;
        if !(bandwidth > 0.0) || !(latency >= 0.0) {
            return Err(WireError::Corrupt(format!(
                "helloack link: bandwidth {bandwidth}, latency {latency}"
            )));
        }
        links.push(LinkSpec::new(bandwidth, latency));
    }
    c.finish()?;
    Ok(links)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_bitexact() {
        let v = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -3.25e7];
        let decoded = decode_dense(&encode_dense(&v)).unwrap();
        assert_eq!(decoded.len(), v.len());
        for (a, b) in v.iter().zip(&decoded) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(decode_dense(&encode_dense(&[])).unwrap().is_empty());
    }

    #[test]
    fn support_roundtrip_unaligned_tail() {
        // 67 bits: unaligned trailing mask word.
        let mut m = BitMask::zeros(67);
        for i in [0, 1, 31, 32, 63, 64, 66] {
            m.set(i);
        }
        let d = decode_support(&encode_support(&m)).unwrap();
        assert_eq!(d.len(), 67);
        assert_eq!(d.count(), m.count());
        for i in 0..67 {
            assert_eq!(d.get(i), m.get(i), "bit {i}");
        }
    }

    #[test]
    fn masked_roundtrip_and_nnz_check() {
        let mut m = BitMask::zeros(10);
        m.set(2);
        m.set(7);
        let vals = vec![1.25f32, -2.5];
        let (dm, dv) = decode_masked(&encode_masked(&m, &vals)).unwrap();
        assert_eq!(dm.count(), 2);
        assert_eq!(dv, vals);
        // Declared nnz inconsistent with mask popcount is corrupt.
        let mut bytes = encode_masked(&m, &vals);
        bytes[4] = 3; // nnz field
        assert!(matches!(decode_masked(&bytes), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn tern_roundtrips() {
        let g = TernGrad {
            len: 9,
            scales: vec![0.5, 2.0],
            codes: vec![0b01_10_00_01, 0b00_00_10_01, 0b10],
        };
        let d = decode_tern_grad(&encode_tern_grad(&g)).unwrap();
        assert_eq!(d.len, g.len);
        assert_eq!(d.codes, g.codes);
        assert_eq!(d.scales, g.scales);
        let b = TernBlob {
            len: 5,
            scale: 1.5,
            codes: vec![0b10_01_00_01, 0b01],
        };
        let db = decode_tern_blob(&encode_tern_blob(&b)).unwrap();
        assert_eq!((db.len, db.scale, &db.codes), (b.len, b.scale, &b.codes));
    }

    #[test]
    fn q_blob_roundtrips_every_width() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x0B10B);
        let vals: Vec<f32> = (0..1100).map(|_| rng.normal_with(0.0, 0.5)).collect();
        for width in QuantWidth::ALL {
            let q = QBlob::encode(&vals, width, &mut rng);
            let d = decode_q_blob(&encode_q_blob(&q)).unwrap();
            assert_eq!(d, q, "{width}");
        }
        // Empty payload roundtrips too.
        let q = QBlob::encode(&[], QuantWidth::Q8, &mut rng);
        assert_eq!(decode_q_blob(&encode_q_blob(&q)).unwrap(), q);
    }

    #[test]
    fn q_blob_rejects_inconsistent_shapes() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let vals = vec![0.5f32; 40];
        let q = QBlob::encode(&vals, QuantWidth::Q4, &mut rng);
        let bytes = encode_q_blob(&q);
        // Unknown width tag.
        let mut bad = bytes.clone();
        bad[0] = 0;
        assert!(matches!(decode_q_blob(&bad), Err(WireError::Corrupt(_))));
        // k-bit width with a zero scale block.
        let mut bad = bytes.clone();
        bad[1..5].copy_from_slice(&0u32.to_le_bytes());
        assert!(matches!(decode_q_blob(&bad), Err(WireError::Corrupt(_))));
        // Float width with a nonzero block (field mismatch).
        let mut bad = bytes.clone();
        bad[0] = QuantWidth::Bf16.wire_tag();
        assert!(matches!(decode_q_blob(&bad), Err(WireError::Corrupt(_))));
        // Truncation and trailing garbage are typed.
        assert!(matches!(
            decode_q_blob(&bytes[..bytes.len() - 1]),
            Err(WireError::Truncated { .. })
        ));
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(decode_q_blob(&long), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn handshake_roundtrips() {
        assert_eq!(decode_hello(&encode_hello(3, 9)).unwrap(), (3, 9));
        let links = vec![LinkSpec::new(1e9, 1e-4), LinkSpec::new(2e8, 0.0)];
        let d = decode_hello_ack(&encode_hello_ack(&links)).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].bandwidth_bps, 1e9);
        assert_eq!(d[1].latency_s, 0.0);
    }

    #[test]
    fn truncation_and_trailing_are_typed() {
        let bytes = encode_dense(&[1.0, 2.0]);
        assert!(matches!(
            decode_dense(&bytes[..bytes.len() - 1]),
            Err(WireError::Truncated { .. })
        ));
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(decode_dense(&long), Err(WireError::Corrupt(_))));
        assert!(matches!(
            decode_support(&[1, 0, 0]),
            Err(WireError::Truncated { .. })
        ));
    }
}
