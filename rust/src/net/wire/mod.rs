//! Real socket ring transport (DESIGN.md §13, §16).
//!
//! Everything below `net::wire` moves actual bytes: rank sessions
//! relay length-prefixed [`frame::Frame`]s over Unix domain sockets
//! (or loopback TCP behind `--transport tcp`), and the coordinator —
//! [`WireRing`] — drives the collectives the compression pipelines
//! need: dense chunk allgather, mask/ternary spreads, per-node support
//! allgather. The in-process simulator stays the bit-exact oracle:
//! `WireEngine` (`exp::simrun`) runs the identical compute core but
//! routes every traveling payload through this module, consuming only
//! the *decoded* frames, so any codec or relay corruption diverges the
//! `StepReport` and the `transport_equivalence` suite catches it.
//!
//! Since wire protocol v2 the ring is *self-healing* (DESIGN.md §16):
//! frames carry a CRC-32 trailer, ring edges run a bounded
//! NACK/retransmit ARQ with duplicate suppression and reconnect
//! backoff ([`peer::EdgeTx`]/[`peer::EdgeRx`]), and a seeded
//! [`FaultPlan`] can corrupt edge traffic deterministically to prove
//! it. The version is negotiated per ring in Hello/HelloAck
//! ([`frame::FLAG_CAP_V2`]), so v1 peers interoperate unchanged.
//! Recovery activity surfaces as [`RecoveryStats`]
//! ([`WireRing::recovery_stats`]).
//!
//! Two wirings:
//!
//! * **in-process** — [`WireRing::new_in_process`] builds every ring
//!   edge and control channel from connected socket pairs and spawns
//!   the rank threads itself (the default for `--transport uds|tcp`);
//! * **external** — `ringiwp serve --rank R` processes rendezvous with
//!   the coordinator through a filesystem directory
//!   ([`WireRing::connect_external`] + [`peer::serve_rank`]), selected
//!   by `RINGIWP_WIRE_DIR`.
//!
//! The handshake (Hello → HelloAck) carries per-hop [`LinkSpec`]s —
//! the heterogeneous-link seam of ROADMAP item 3 — and defaults to
//! today's uniform link bit-for-bit.

pub mod codec;
pub mod fault;
pub mod frame;
pub mod peer;

pub use fault::{FaultEvent, FaultKind, FaultPlan};
pub use frame::{Frame, Kind, WireError, FLAG_CAP_V2, FLAG_TERN_BLOB, V1, VERSION};
pub use peer::{
    serve_rank, serve_rank_with, RecoveryCounters, RecoveryStats, ServeOpts, ServeReport,
    WireListener, WireStream,
};

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::compress::quant::QBlob;
use crate::compress::terngrad::{TernBlob, TernGrad};
use crate::net::LinkSpec;
use crate::sparse::BitMask;
use peer::{RankSession, SessionOpts, READ_TIMEOUT};

/// Which transport the engines run on (`--transport`, `RINGIWP_TRANSPORT`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Single-process virtual network (the default; the oracle).
    Sim,
    /// Unix domain sockets.
    Uds,
    /// Loopback TCP sockets.
    Tcp,
}

impl TransportKind {
    /// Parse a CLI/config transport name.
    pub fn parse(s: &str) -> anyhow::Result<TransportKind> {
        Ok(match s {
            "sim" => TransportKind::Sim,
            "uds" => TransportKind::Uds,
            "tcp" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport `{other}` (sim|uds|tcp)"),
        })
    }

    /// Canonical CLI/CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
        }
    }

    /// True for transports that move real bytes over sockets.
    pub fn is_wire(&self) -> bool {
        !matches!(self, TransportKind::Sim)
    }

    /// Transport from `RINGIWP_TRANSPORT` (default `sim`); panics on a
    /// malformed value, mirroring `TopoKind::from_env`.
    pub fn from_env() -> TransportKind {
        match std::env::var("RINGIWP_TRANSPORT") {
            Ok(s) => TransportKind::parse(&s)
                .unwrap_or_else(|e| panic!("RINGIWP_TRANSPORT: {e}")),
            Err(_) => TransportKind::Sim,
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Wire timeout from `RINGIWP_WIRE_TIMEOUT_MS` (default 30 000 ms, the
/// historical [`peer::READ_TIMEOUT`]); panics on a malformed or zero
/// value, mirroring the other env knobs.
pub fn wire_timeout_from_env() -> u64 {
    match std::env::var("RINGIWP_WIRE_TIMEOUT_MS") {
        Ok(s) => {
            let ms: u64 = s
                .parse()
                .unwrap_or_else(|e| panic!("RINGIWP_WIRE_TIMEOUT_MS: {e}"));
            assert!(ms > 0, "RINGIWP_WIRE_TIMEOUT_MS must be > 0");
            ms
        }
        Err(_) => READ_TIMEOUT.as_millis() as u64,
    }
}

/// Ring construction options: fault schedule, timeout knob, shared
/// recovery counters (so stats survive elastic re-rings), and an
/// explicit wire-version override for negotiation tests.
#[derive(Debug, Clone)]
pub struct RingOpts {
    /// Seeded fault schedule applied to ring-edge data writes
    /// (in-process rings only; `None`/empty ⇒ zero overhead).
    pub faults: Option<FaultPlan>,
    /// Connect/read deadline and the base the ARQ timeouts derive from
    /// (`--wire-timeout-ms`; defaults to the historical 30 s).
    pub timeout: Duration,
    /// Recovery counter block to account into; `None` allocates a
    /// fresh one. `WireEngine` passes one block across re-rings so
    /// [`RecoveryStats`] stays cumulative.
    pub counters: Option<Arc<RecoveryCounters>>,
    /// Force the ring's wire version ([`V1`] or [`VERSION`]) instead
    /// of negotiating v2; `None` ⇒ negotiate (v2 for in-process rings).
    pub force_version: Option<u16>,
}

impl Default for RingOpts {
    fn default() -> Self {
        RingOpts {
            faults: None,
            timeout: READ_TIMEOUT,
            counters: None,
            force_version: None,
        }
    }
}

impl RingOpts {
    fn resolve_counters(&self) -> Arc<RecoveryCounters> {
        self.counters
            .clone()
            .unwrap_or_else(|| Arc::new(RecoveryCounters::new()))
    }

    fn active_faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref().filter(|p| !p.is_empty())
    }

    fn validate(&self) -> Result<(), WireError> {
        if let Some(v) = self.force_version {
            if v != V1 && v != VERSION {
                return Err(WireError::Corrupt(format!(
                    "unsupported forced wire version {v} (1 or {VERSION})"
                )));
            }
        }
        if let Some(plan) = self.active_faults() {
            plan.validate().map_err(WireError::Corrupt)?;
            if self.force_version == Some(V1) {
                return Err(fault::refuse(
                    "the v1 wire protocol has no CRC/ARQ to recover with",
                ));
            }
        }
        Ok(())
    }
}

/// Coordinator handle over an `n`-rank socket ring.
///
/// Every collective is a sequence of *spreads*: a frame injected at
/// its origin rank travels `n-1` real ring edges, each relay hands the
/// coordinator a ttl-normalized copy, and the coordinator verifies all
/// copies byte-identical (and epoch-stamped) before handing the
/// decoded payload to the engine. Injection happens on a scoped
/// thread while the caller drains deliveries, so frames larger than a
/// socket buffer cannot deadlock the ring.
#[derive(Debug)]
pub struct WireRing {
    n: usize,
    transport: TransportKind,
    /// Negotiated wire version for every post-handshake frame.
    version: u16,
    epoch: u32,
    /// Injection halves, indexed by rank.
    ctl_w: Vec<WireStream>,
    /// Delivery halves, indexed by rank.
    ctl_r: Vec<WireStream>,
    /// In-process rank sessions (empty when ranks are external).
    sessions: Vec<RankSession>,
    /// Per-hop link parameters from the handshake (entry `i` = rank
    /// `i`'s egress edge).
    links: Vec<LinkSpec>,
    /// Shared recovery accounting across all edges (and re-rings).
    counters: Arc<RecoveryCounters>,
    /// Real bytes that traversed ring edges (data-frame length at the
    /// negotiated version × hops; ACK/NACK traffic is deliberately
    /// excluded — it is recovery overhead, not payload movement, and
    /// its volume is reported through [`RecoveryStats`] instead).
    real_bytes: u64,
}

impl WireRing {
    /// Build an in-process ring with default options (negotiated v2,
    /// no faults, 30 s timeouts) — see [`WireRing::new_in_process_with`].
    pub fn new_in_process(
        transport: TransportKind,
        links: Vec<LinkSpec>,
    ) -> Result<WireRing, WireError> {
        Self::new_in_process_with(transport, links, RingOpts::default())
    }

    /// Build an in-process ring: socket pairs for every control
    /// channel and ring edge, rank threads spawned here, handshake run
    /// synchronously before any data frame. The handshake travels at
    /// wire version 1 and negotiates the session version via
    /// [`FLAG_CAP_V2`]; `opts.faults` arms the per-edge fault shim
    /// (v2 rings only).
    pub fn new_in_process_with(
        transport: TransportKind,
        links: Vec<LinkSpec>,
        opts: RingOpts,
    ) -> Result<WireRing, WireError> {
        let n = links.len();
        assert!(n >= 2, "ring needs at least 2 ranks");
        assert!(transport.is_wire(), "in-process ring needs a socket transport");
        opts.validate()?;
        let want = opts.force_version.unwrap_or(VERSION);
        let mut ctl_coord = Vec::with_capacity(n);
        let mut ctl_rank = Vec::with_capacity(n);
        let mut all_v2 = true;
        for r in 0..n {
            let (mut coord, mut rank_side) = WireStream::pair(transport)?;
            // Same handshake frames an external rank sends
            // (peer::serve_rank): always encoded at v1, capability
            // advertised in the flags byte so the payload stays
            // byte-identical to what v1 builds parse.
            let mut hello = Frame::new(
                Kind::Hello,
                r as u16,
                0,
                0,
                codec::encode_hello(r as u16, n as u16),
            );
            if want >= VERSION {
                hello.flags = FLAG_CAP_V2;
            }
            hello.write_to(&mut rank_side)?;
            let hello = Frame::read_from(&mut coord)?;
            let (rank, rn) = codec::decode_hello(&hello.payload)?;
            if hello.kind != Kind::Hello || rank as usize != r || rn as usize != n {
                return Err(WireError::Corrupt(format!(
                    "handshake: expected Hello({r}, {n}), got {:?}({rank}, {rn})",
                    hello.kind
                )));
            }
            all_v2 &= hello.flags & FLAG_CAP_V2 != 0;
            ctl_coord.push(coord);
            ctl_rank.push(rank_side);
        }
        // The ring runs v2 iff every Hello advertised the capability.
        let version = if all_v2 { VERSION } else { V1 };
        for (r, (coord, rank_side)) in
            ctl_coord.iter_mut().zip(ctl_rank.iter_mut()).enumerate()
        {
            let mut ack = Frame::new(
                Kind::HelloAck,
                r as u16,
                0,
                0,
                codec::encode_hello_ack(&links),
            );
            if version >= VERSION {
                ack.flags = FLAG_CAP_V2;
            }
            ack.write_to(coord)?;
            let ack = Frame::read_from(rank_side)?;
            let acked = codec::decode_hello_ack(&ack.payload)?;
            if ack.kind != Kind::HelloAck || acked.len() != n {
                return Err(WireError::Corrupt("handshake: bad HelloAck".into()));
            }
        }
        if version < VERSION && opts.active_faults().is_some() {
            return Err(fault::refuse(
                "the ring negotiated wire v1, which has no CRC/ARQ",
            ));
        }
        // Ring edges: edge r carries rank r → rank (r+1) mod n.
        let mut succs = Vec::with_capacity(n);
        let mut preds: Vec<Option<WireStream>> = (0..n).map(|_| None).collect();
        for r in 0..n {
            let (w, rd) = WireStream::pair(transport)?;
            succs.push(w);
            preds[(r + 1) % n] = Some(rd);
        }
        let counters = opts.resolve_counters();
        let plan = opts.active_faults();
        let mut sessions = Vec::with_capacity(n);
        for (r, ((ctl, succ), pred)) in ctl_rank
            .into_iter()
            .zip(succs)
            .zip(preds.iter_mut().map(|p| p.take().expect("pred wired")))
            .enumerate()
        {
            let session_opts = SessionOpts {
                version,
                faults: plan.and_then(|p| p.edge_faults(r, n)),
                attempts: plan.map_or(fault::DEFAULT_ATTEMPTS, |p| p.attempts),
                timeout: opts.timeout,
                counters: counters.clone(),
            };
            sessions.push(peer::spawn_rank_with(r as u16, ctl, pred, succ, session_opts)?);
        }
        Self::finish(n, transport, version, opts.timeout, counters, ctl_coord, sessions, links)
    }

    /// Attach to `n` external `ringiwp serve` ranks with default
    /// options — see [`WireRing::connect_external_with`].
    pub fn connect_external(
        dir: &Path,
        transport: TransportKind,
        links: Vec<LinkSpec>,
    ) -> Result<WireRing, WireError> {
        Self::connect_external_with(dir, transport, links, RingOpts::default())
    }

    /// Attach to `n` external `ringiwp serve` ranks rendezvousing in
    /// `dir`: bind the `ctl` endpoint, accept every rank's Hello
    /// (identified by its payload, not accept order), and reply with
    /// the per-hop link table. The ring runs wire v2 iff every rank's
    /// Hello advertised [`FLAG_CAP_V2`]; fault injection is refused
    /// (it is an in-process test harness, not a tool to corrupt real
    /// peers' traffic).
    pub fn connect_external_with(
        dir: &Path,
        transport: TransportKind,
        links: Vec<LinkSpec>,
        opts: RingOpts,
    ) -> Result<WireRing, WireError> {
        let n = links.len();
        assert!(n >= 2, "ring needs at least 2 ranks");
        assert!(transport.is_wire(), "external ring needs a socket transport");
        opts.validate()?;
        if opts.active_faults().is_some() {
            return Err(fault::refuse(
                "external rings own real peers; faults are in-process only",
            ));
        }
        let listener = WireListener::bind(dir, "ctl", transport)?;
        let mut by_rank: Vec<Option<WireStream>> = (0..n).map(|_| None).collect();
        let mut all_v2 = true;
        for _ in 0..n {
            let mut s = listener.accept()?;
            let hello = Frame::read_from(&mut s)?;
            if hello.kind != Kind::Hello {
                return Err(WireError::Corrupt(format!(
                    "expected Hello, got {:?}",
                    hello.kind
                )));
            }
            let (rank, rn) = codec::decode_hello(&hello.payload)?;
            if rn as usize != n {
                return Err(WireError::Corrupt(format!(
                    "rank {rank} joined with ring size {rn}, coordinator has {n}"
                )));
            }
            if rank as usize >= n {
                return Err(WireError::Corrupt(format!("rank {rank} out of range")));
            }
            all_v2 &= hello.flags & FLAG_CAP_V2 != 0;
            if by_rank[rank as usize].replace(s).is_some() {
                return Err(WireError::Corrupt(format!("rank {rank} joined twice")));
            }
        }
        let version = match opts.force_version {
            Some(v) => v.min(if all_v2 { VERSION } else { V1 }),
            None if all_v2 => VERSION,
            None => V1,
        };
        let mut ctl_coord = Vec::with_capacity(n);
        for (r, slot) in by_rank.iter_mut().enumerate() {
            let mut s = slot.take().expect("all ranks joined");
            let mut ack = Frame::new(
                Kind::HelloAck,
                r as u16,
                0,
                0,
                codec::encode_hello_ack(&links),
            );
            if version >= VERSION {
                ack.flags = FLAG_CAP_V2;
            }
            ack.write_to(&mut s)?;
            ctl_coord.push(s);
        }
        let counters = opts.resolve_counters();
        Self::finish(n, transport, version, opts.timeout, counters, ctl_coord, Vec::new(), links)
    }

    /// Split ctl streams into directional halves and arm read timeouts.
    #[allow(clippy::too_many_arguments)]
    fn finish(
        n: usize,
        transport: TransportKind,
        version: u16,
        timeout: Duration,
        counters: Arc<RecoveryCounters>,
        ctl: Vec<WireStream>,
        sessions: Vec<RankSession>,
        links: Vec<LinkSpec>,
    ) -> Result<WireRing, WireError> {
        let mut ctl_w = Vec::with_capacity(n);
        let mut ctl_r = Vec::with_capacity(n);
        for s in ctl {
            let r = s.try_clone()?;
            r.set_read_timeout(Some(timeout))?;
            ctl_w.push(s);
            ctl_r.push(r);
        }
        Ok(WireRing {
            n,
            transport,
            version,
            epoch: 0,
            ctl_w,
            ctl_r,
            sessions,
            links,
            counters,
            real_bytes: 0,
        })
    }

    /// Ring size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Transport flavor.
    pub fn transport(&self) -> TransportKind {
        self.transport
    }

    /// Negotiated wire version ([`V1`] or [`VERSION`]).
    pub fn version(&self) -> u16 {
        self.version
    }

    /// Per-hop link parameters delivered by the handshake.
    pub fn links(&self) -> &[LinkSpec] {
        &self.links
    }

    /// Total real bytes that traversed ring edges so far (data frames
    /// only; ACK/NACK overhead is excluded by design).
    pub fn real_bytes(&self) -> u64 {
        self.real_bytes
    }

    /// Recovery totals so far. Advisory while the ring is live; exact
    /// once [`WireRing::shutdown`] has joined the session threads.
    pub fn recovery_stats(&self) -> RecoveryStats {
        self.counters.snapshot()
    }

    /// Stamp subsequent frames with this step's epoch; copies with a
    /// different stamp are rejected as corrupt.
    pub fn begin_step(&mut self, epoch: u32) {
        self.epoch = epoch;
    }

    /// Override the delivery-side read timeout (the ring's wire
    /// timeout — `--wire-timeout-ms`, default [`peer::READ_TIMEOUT`] —
    /// by default). A partitioned or dead rank then surfaces as a
    /// typed [`WireError::Io`] after `d` instead of 30 s — the seam
    /// the chaos/failure tests use to keep partition detection fast.
    pub fn set_read_timeout(&self, d: Option<std::time::Duration>) -> Result<(), WireError> {
        for r in &self.ctl_r {
            r.set_read_timeout(d)?;
        }
        Ok(())
    }

    /// Spread one frame from `origin` across all `n-1` ring edges,
    /// collect every relay's delivered copy in hop order, verify the
    /// copies byte-identical, and return the payload. If a session
    /// thread died on an unrecoverable fault, the typed error it
    /// recorded is surfaced here instead of the bare control-channel
    /// timeout it causes.
    fn spread(
        &mut self,
        origin: usize,
        kind: Kind,
        flags: u8,
        payload: Vec<u8>,
    ) -> Result<Vec<u8>, WireError> {
        assert!(origin < self.n, "origin {origin} out of range");
        let ttl = (self.n - 1) as u16;
        let epoch = self.epoch;
        let version = self.version;
        let frame = Frame {
            kind,
            flags,
            origin: origin as u16,
            ttl,
            epoch,
            payload,
        };
        self.real_bytes += frame.encoded_len_at(version) as u64 * ttl as u64;
        let n = self.n;
        let ctl_w = &mut self.ctl_w[origin];
        let ctl_r = &mut self.ctl_r;
        let mut copies: Vec<Frame> = Vec::with_capacity(ttl as usize);
        // Inject on a scoped thread while this thread drains the
        // deliveries — a frame larger than the socket buffers would
        // otherwise deadlock the write against the unread copies.
        let collected: Result<(), WireError> = std::thread::scope(|s| {
            let inject = s.spawn(move || -> Result<(), WireError> {
                frame.write_to_at(ctl_w, version, 0)?;
                std::io::Write::flush(ctl_w)?;
                Ok(())
            });
            for hop in 1..=ttl as usize {
                copies.push(Frame::read_from(&mut ctl_r[(origin + hop) % n])?);
            }
            inject
                .join()
                .unwrap_or_else(|_| Err(WireError::Corrupt("inject thread panicked".into())))
        });
        if let Err(e) = collected {
            // Prefer the typed root cause a dying session recorded.
            return Err(self.counters.take_fatal().unwrap_or(e));
        }
        for (i, c) in copies.iter().enumerate() {
            if c.epoch != epoch {
                return Err(WireError::Corrupt(format!(
                    "hop {} delivered epoch {} during epoch {epoch}",
                    i + 1,
                    c.epoch
                )));
            }
            if c.kind != kind || c.flags != flags || c.origin != origin as u16 || c.ttl != 0 {
                return Err(WireError::Corrupt(format!(
                    "hop {} delivered mismatched header", i + 1
                )));
            }
            if c.payload != copies[0].payload {
                return Err(WireError::Corrupt(format!(
                    "hop {} delivered diverging payload", i + 1
                )));
            }
        }
        Ok(copies.swap_remove(0).payload)
    }

    /// Ring allgather of the dense buffer: `n` contiguous chunks, each
    /// injected at its owner rank and spread around the ring, then
    /// reassembled and verified bit-equal to the input. Returns the
    /// decoded coordinate count (which the engine — not the input —
    /// feeds into the dense accounting).
    pub fn exchange_dense(&mut self, values: &[f32]) -> Result<usize, WireError> {
        let n = self.n;
        let base = values.len() / n;
        let rem = values.len() % n;
        let mut decoded_total = 0usize;
        let mut offset = 0usize;
        for origin in 0..n {
            let len = base + usize::from(origin < rem);
            let chunk = &values[offset..offset + len];
            let out = self.spread(origin, Kind::Dense, 0, codec::encode_dense(chunk))?;
            let got = codec::decode_dense(&out)?;
            if got.len() != len
                || got
                    .iter()
                    .zip(chunk)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(WireError::Corrupt(format!(
                    "dense chunk {origin} decoded differently than sent"
                )));
            }
            decoded_total += got.len();
            offset += len;
        }
        Ok(decoded_total)
    }

    /// Spread one broadcaster's mask (Algorithm 1's mask AllGather
    /// step) and return the decoded copy the downstream OR consumes.
    pub fn spread_mask(&mut self, origin: usize, mask: &BitMask) -> Result<BitMask, WireError> {
        let out = self.spread(origin, Kind::Sparse, 0, codec::encode_support(mask))?;
        codec::decode_support(&out)
    }

    /// Spread a shared mask together with its compacted values and
    /// return both decoded.
    pub fn spread_masked(
        &mut self,
        origin: usize,
        mask: &BitMask,
        values: &[f32],
    ) -> Result<(BitMask, Vec<f32>), WireError> {
        let out = self.spread(origin, Kind::Masked, 0, codec::encode_masked(mask, values))?;
        codec::decode_masked(&out)
    }

    /// Spread a per-layer-scaled ternary gradient; returns the decoded
    /// copy (whose shape feeds the byte accounting).
    pub fn spread_tern_grad(&mut self, t: &TernGrad) -> Result<TernGrad, WireError> {
        let out = self.spread(0, Kind::Tern, 0, codec::encode_tern_grad(t))?;
        codec::decode_tern_grad(&out)
    }

    /// Spread a single-scale ternary blob ([`FLAG_TERN_BLOB`] set).
    pub fn spread_tern_blob(&mut self, t: &TernBlob) -> Result<TernBlob, WireError> {
        let out = self.spread(0, Kind::Tern, FLAG_TERN_BLOB, codec::encode_tern_blob(t))?;
        codec::decode_tern_blob(&out)
    }

    /// Spread a low-precision `+q:<bits>` payload blob ([`Kind::Quant`]);
    /// returns the decoded copy (whose length prices every node's blob).
    pub fn spread_q_blob(&mut self, q: &QBlob) -> Result<QBlob, WireError> {
        let out = self.spread(0, Kind::Quant, 0, codec::encode_q_blob(q))?;
        codec::decode_q_blob(&out)
    }

    /// AllGather every rank's support mask: rank `r`'s mask spreads
    /// from origin `r mod n`; returns the decoded masks in input
    /// order. Inputs beyond the ring size (exchangeable-node supports,
    /// DESIGN.md §9) spread from their index mod `n`.
    pub fn allgather_supports(
        &mut self,
        supports: &[BitMask],
    ) -> Result<Vec<BitMask>, WireError> {
        let mut out = Vec::with_capacity(supports.len());
        for (i, m) in supports.iter().enumerate() {
            let origin = i % self.n;
            let decoded = self.spread(origin, Kind::Sparse, 0, codec::encode_support(m))?;
            out.push(codec::decode_support(&decoded)?);
        }
        Ok(out)
    }

    /// Tear the ring down: one Shutdown around the ring stops every
    /// relay, a ttl-0 Shutdown on each control channel stops every
    /// uplink, then in-process sessions are joined. Teardown is
    /// best-effort end to end — after an unrecoverable fault killed a
    /// session thread, the circulation is broken, so surviving relays
    /// are released through the shared down-flag (checked on their
    /// idle ticks) and every join stays bounded by the ARQ budgets.
    /// Idempotent; the first error (preferring a recorded typed fatal)
    /// is returned after all sessions are reaped.
    pub fn shutdown(&mut self) -> Result<(), WireError> {
        if self.ctl_w.is_empty() {
            return Ok(());
        }
        let epoch = self.epoch;
        let version = self.version;
        let mut first_err: Option<WireError> = None;
        if self.counters.has_fatal() {
            self.counters.request_down();
        }
        if let Err(e) = Frame::new(Kind::Shutdown, 0, self.n as u16, epoch, Vec::new())
            .write_to_at(&mut self.ctl_w[0], version, 0)
        {
            first_err.get_or_insert(e);
        }
        for w in self.ctl_w.iter_mut() {
            if let Err(e) =
                Frame::new(Kind::Shutdown, 0, 0, epoch, Vec::new()).write_to_at(w, version, 0)
            {
                first_err.get_or_insert(e);
            }
        }
        self.ctl_w.clear();
        self.ctl_r.clear();
        // A fatal recorded between the first check and here still needs
        // the down-flag, or a survivor relay would idle forever.
        if self.counters.has_fatal() {
            self.counters.request_down();
        }
        for s in self.sessions.drain(..) {
            if let Err(e) = s.join() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(self.counters.take_fatal().unwrap_or(e)),
            None => Ok(()),
        }
    }
}

impl Drop for WireRing {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<LinkSpec> {
        vec![LinkSpec::new(1e9, 0.0); n]
    }

    #[test]
    fn transport_kind_parse_name_roundtrip() {
        for k in [TransportKind::Sim, TransportKind::Uds, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
            assert_eq!(format!("{k}"), k.name());
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert!(!TransportKind::Sim.is_wire());
        assert!(TransportKind::Uds.is_wire());
    }

    #[test]
    fn dense_exchange_roundtrips_and_accounts() {
        let mut ring = WireRing::new_in_process(TransportKind::Uds, uniform(4)).unwrap();
        assert_eq!(ring.version(), VERSION);
        ring.begin_step(1);
        let v: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 9.0).collect();
        assert_eq!(ring.exchange_dense(&v).unwrap(), 37);
        assert!(ring.real_bytes() > 0);
        ring.shutdown().unwrap();
        // A clean ring recovers nothing.
        assert_eq!(ring.recovery_stats(), RecoveryStats::default());
    }

    #[test]
    fn mask_and_tern_spreads_decode_bitexact() {
        let mut ring = WireRing::new_in_process(TransportKind::Uds, uniform(3)).unwrap();
        ring.begin_step(2);
        let mut m = BitMask::zeros(70);
        for i in [0, 13, 64, 69] {
            m.set(i);
        }
        let d = ring.spread_mask(1, &m).unwrap();
        assert_eq!(d.count(), 4);
        for i in 0..70 {
            assert_eq!(d.get(i), m.get(i));
        }
        let blob = TernBlob {
            len: 5,
            scale: 0.75,
            codes: vec![0b10_01_00_01, 0b01],
        };
        let db = ring.spread_tern_blob(&blob).unwrap();
        assert_eq!((db.len, db.scale, db.codes), (blob.len, blob.scale, blob.codes));
        let qb = QBlob {
            width: crate::compress::quant::QuantWidth::Q8,
            len: 3,
            block: 1024,
            scales: vec![0.5],
            codes: vec![1, 130, 127],
        };
        let dq = ring.spread_q_blob(&qb).unwrap();
        assert_eq!(dq, qb);
        ring.shutdown().unwrap();
    }

    #[test]
    fn allgather_supports_preserves_order() {
        let mut ring = WireRing::new_in_process(TransportKind::Uds, uniform(2)).unwrap();
        ring.begin_step(0);
        let mut a = BitMask::zeros(9);
        a.set(1);
        let mut b = BitMask::zeros(9);
        b.set(8);
        let out = ring.allgather_supports(&[a, b]).unwrap();
        assert!(out[0].get(1) && !out[0].get(8));
        assert!(out[1].get(8) && !out[1].get(1));
        ring.shutdown().unwrap();
    }

    #[test]
    fn tcp_in_process_ring_works() {
        let mut ring = WireRing::new_in_process(TransportKind::Tcp, uniform(2)).unwrap();
        ring.begin_step(3);
        assert_eq!(ring.exchange_dense(&[1.0, 2.0, 3.0]).unwrap(), 3);
        ring.shutdown().unwrap();
    }

    #[test]
    fn handshake_carries_links() {
        let links = vec![LinkSpec::new(1e9, 1e-4), LinkSpec::new(5e8, 2e-4)];
        let ring = WireRing::new_in_process(TransportKind::Uds, links).unwrap();
        assert_eq!(ring.links().len(), 2);
        assert_eq!(ring.links()[1].bandwidth_bps, 5e8);
    }

    #[test]
    fn forced_v1_ring_still_interops() {
        // A ring whose peers lack FLAG_CAP_V2 degrades to v1 framing
        // and keeps moving payloads byte-exactly.
        let opts = RingOpts {
            force_version: Some(V1),
            ..RingOpts::default()
        };
        let mut ring =
            WireRing::new_in_process_with(TransportKind::Uds, uniform(3), opts).unwrap();
        assert_eq!(ring.version(), V1);
        ring.begin_step(5);
        assert_eq!(ring.exchange_dense(&[1.0, -2.0, 3.5, 0.25]).unwrap(), 4);
        ring.shutdown().unwrap();
        assert_eq!(ring.recovery_stats(), RecoveryStats::default());
    }

    #[test]
    fn v2_trailer_is_accounted_in_real_bytes() {
        let run = |force: Option<u16>| -> u64 {
            let mut ring = WireRing::new_in_process_with(
                TransportKind::Uds,
                uniform(3),
                RingOpts {
                    force_version: force,
                    ..RingOpts::default()
                },
            )
            .unwrap();
            ring.begin_step(1);
            ring.exchange_dense(&[1.0, 2.0, 3.0]).unwrap();
            let b = ring.real_bytes();
            ring.shutdown().unwrap();
            b
        };
        let v2 = run(None);
        let v1 = run(Some(V1));
        // 3 chunks × 2 hops × 8-byte trailer.
        assert_eq!(v2, v1 + 3 * 2 * frame::TRAILER_LEN as u64);
    }

    #[test]
    fn fault_plan_recovers_bitexact_with_stats() {
        let plan = FaultPlan::parse("seed=11,flip@0:0,dup@1:1,delay@0:2:2").unwrap();
        let opts = RingOpts {
            faults: Some(plan),
            timeout: Duration::from_secs(5),
            ..RingOpts::default()
        };
        let mut ring =
            WireRing::new_in_process_with(TransportKind::Uds, uniform(3), opts).unwrap();
        ring.begin_step(1);
        let v: Vec<f32> = (0..23).map(|i| (i as f32).sin()).collect();
        assert_eq!(ring.exchange_dense(&v).unwrap(), 23);
        ring.shutdown().unwrap();
        let stats = ring.recovery_stats();
        assert!(stats.retransmits >= 1, "{stats}");
        assert!(stats.nacks >= 1, "{stats}");
        assert!(stats.dup_drops >= 1, "{stats}");
    }

    #[test]
    fn faults_are_refused_on_v1_and_external_rings() {
        let opts = RingOpts {
            faults: Some(FaultPlan::parse("flip@0:0").unwrap()),
            force_version: Some(V1),
            ..RingOpts::default()
        };
        assert!(WireRing::new_in_process_with(TransportKind::Uds, uniform(2), opts).is_err());
        let dir = std::env::temp_dir().join("riwp-fault-refuse-test");
        let _ = std::fs::create_dir_all(&dir);
        let opts = RingOpts {
            faults: Some(FaultPlan::parse("flip@0:0").unwrap()),
            ..RingOpts::default()
        };
        assert!(
            WireRing::connect_external_with(&dir, TransportKind::Uds, uniform(2), opts).is_err()
        );
    }
}
