//! Real socket ring transport (DESIGN.md §13).
//!
//! Everything below `net::wire` moves actual bytes: rank sessions
//! relay length-prefixed [`frame::Frame`]s over Unix domain sockets
//! (or loopback TCP behind `--transport tcp`), and the coordinator —
//! [`WireRing`] — drives the collectives the compression pipelines
//! need: dense chunk allgather, mask/ternary spreads, per-node support
//! allgather. The in-process simulator stays the bit-exact oracle:
//! `WireEngine` (`exp::simrun`) runs the identical compute core but
//! routes every traveling payload through this module, consuming only
//! the *decoded* frames, so any codec or relay corruption diverges the
//! `StepReport` and the `transport_equivalence` suite catches it.
//!
//! Two wirings:
//!
//! * **in-process** — [`WireRing::new_in_process`] builds every ring
//!   edge and control channel from connected socket pairs and spawns
//!   the rank threads itself (the default for `--transport uds|tcp`);
//! * **external** — `ringiwp serve --rank R` processes rendezvous with
//!   the coordinator through a filesystem directory
//!   ([`WireRing::connect_external`] + [`peer::serve_rank`]), selected
//!   by `RINGIWP_WIRE_DIR`.
//!
//! The handshake (Hello → HelloAck) carries per-hop [`LinkSpec`]s —
//! the heterogeneous-link seam of ROADMAP item 3 — and defaults to
//! today's uniform link bit-for-bit.

pub mod codec;
pub mod frame;
pub mod peer;

pub use frame::{Frame, Kind, WireError, FLAG_TERN_BLOB, VERSION};
pub use peer::{serve_rank, WireListener, WireStream};

use std::path::Path;

use crate::compress::terngrad::{TernBlob, TernGrad};
use crate::net::LinkSpec;
use crate::sparse::BitMask;
use peer::{RankSession, READ_TIMEOUT};

/// Which transport the engines run on (`--transport`, `RINGIWP_TRANSPORT`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Single-process virtual network (the default; the oracle).
    Sim,
    /// Unix domain sockets.
    Uds,
    /// Loopback TCP sockets.
    Tcp,
}

impl TransportKind {
    /// Parse a CLI/config transport name.
    pub fn parse(s: &str) -> anyhow::Result<TransportKind> {
        Ok(match s {
            "sim" => TransportKind::Sim,
            "uds" => TransportKind::Uds,
            "tcp" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport `{other}` (sim|uds|tcp)"),
        })
    }

    /// Canonical CLI/CSV name.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
        }
    }

    /// True for transports that move real bytes over sockets.
    pub fn is_wire(&self) -> bool {
        !matches!(self, TransportKind::Sim)
    }

    /// Transport from `RINGIWP_TRANSPORT` (default `sim`); panics on a
    /// malformed value, mirroring `TopoKind::from_env`.
    pub fn from_env() -> TransportKind {
        match std::env::var("RINGIWP_TRANSPORT") {
            Ok(s) => TransportKind::parse(&s)
                .unwrap_or_else(|e| panic!("RINGIWP_TRANSPORT: {e}")),
            Err(_) => TransportKind::Sim,
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Coordinator handle over an `n`-rank socket ring.
///
/// Every collective is a sequence of *spreads*: a frame injected at
/// its origin rank travels `n-1` real ring edges, each relay hands the
/// coordinator a ttl-normalized copy, and the coordinator verifies all
/// copies byte-identical (and epoch-stamped) before handing the
/// decoded payload to the engine. Injection happens on a scoped
/// thread while the caller drains deliveries, so frames larger than a
/// socket buffer cannot deadlock the ring.
#[derive(Debug)]
pub struct WireRing {
    n: usize,
    transport: TransportKind,
    epoch: u32,
    /// Injection halves, indexed by rank.
    ctl_w: Vec<WireStream>,
    /// Delivery halves, indexed by rank.
    ctl_r: Vec<WireStream>,
    /// In-process rank sessions (empty when ranks are external).
    sessions: Vec<RankSession>,
    /// Per-hop link parameters from the handshake (entry `i` = rank
    /// `i`'s egress edge).
    links: Vec<LinkSpec>,
    /// Real bytes that traversed ring edges (frame length × hops).
    real_bytes: u64,
}

impl WireRing {
    /// Build an in-process ring: socket pairs for every control
    /// channel and ring edge, rank threads spawned here, handshake run
    /// synchronously before any data frame.
    pub fn new_in_process(
        transport: TransportKind,
        links: Vec<LinkSpec>,
    ) -> Result<WireRing, WireError> {
        let n = links.len();
        assert!(n >= 2, "ring needs at least 2 ranks");
        assert!(transport.is_wire(), "in-process ring needs a socket transport");
        let mut ctl_coord = Vec::with_capacity(n);
        let mut ctl_rank = Vec::with_capacity(n);
        for r in 0..n {
            let (mut coord, mut rank_side) = WireStream::pair(transport)?;
            // Same handshake frames an external rank sends (peer::serve_rank).
            Frame::new(
                Kind::Hello,
                r as u16,
                0,
                0,
                codec::encode_hello(r as u16, n as u16),
            )
            .write_to(&mut rank_side)?;
            let hello = Frame::read_from(&mut coord)?;
            let (rank, rn) = codec::decode_hello(&hello.payload)?;
            if hello.kind != Kind::Hello || rank as usize != r || rn as usize != n {
                return Err(WireError::Corrupt(format!(
                    "handshake: expected Hello({r}, {n}), got {:?}({rank}, {rn})",
                    hello.kind
                )));
            }
            Frame::new(Kind::HelloAck, r as u16, 0, 0, codec::encode_hello_ack(&links))
                .write_to(&mut coord)?;
            let ack = Frame::read_from(&mut rank_side)?;
            let acked = codec::decode_hello_ack(&ack.payload)?;
            if ack.kind != Kind::HelloAck || acked.len() != n {
                return Err(WireError::Corrupt("handshake: bad HelloAck".into()));
            }
            ctl_coord.push(coord);
            ctl_rank.push(rank_side);
        }
        // Ring edges: edge r carries rank r → rank (r+1) mod n.
        let mut succs = Vec::with_capacity(n);
        let mut preds: Vec<Option<WireStream>> = (0..n).map(|_| None).collect();
        for r in 0..n {
            let (w, rd) = WireStream::pair(transport)?;
            succs.push(w);
            preds[(r + 1) % n] = Some(rd);
        }
        let mut sessions = Vec::with_capacity(n);
        for (r, ((ctl, succ), pred)) in ctl_rank
            .into_iter()
            .zip(succs)
            .zip(preds.iter_mut().map(|p| p.take().expect("pred wired")))
            .enumerate()
        {
            sessions.push(peer::spawn_rank(r as u16, ctl, pred, succ)?);
        }
        Self::finish(n, transport, ctl_coord, sessions, links)
    }

    /// Attach to `n` external `ringiwp serve` ranks rendezvousing in
    /// `dir`: bind the `ctl` endpoint, accept every rank's Hello
    /// (identified by its payload, not accept order), and reply with
    /// the per-hop link table.
    pub fn connect_external(
        dir: &Path,
        transport: TransportKind,
        links: Vec<LinkSpec>,
    ) -> Result<WireRing, WireError> {
        let n = links.len();
        assert!(n >= 2, "ring needs at least 2 ranks");
        assert!(transport.is_wire(), "external ring needs a socket transport");
        let listener = WireListener::bind(dir, "ctl", transport)?;
        let mut by_rank: Vec<Option<WireStream>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let mut s = listener.accept()?;
            let hello = Frame::read_from(&mut s)?;
            if hello.kind != Kind::Hello {
                return Err(WireError::Corrupt(format!(
                    "expected Hello, got {:?}",
                    hello.kind
                )));
            }
            let (rank, rn) = codec::decode_hello(&hello.payload)?;
            if rn as usize != n {
                return Err(WireError::Corrupt(format!(
                    "rank {rank} joined with ring size {rn}, coordinator has {n}"
                )));
            }
            if rank as usize >= n {
                return Err(WireError::Corrupt(format!("rank {rank} out of range")));
            }
            if by_rank[rank as usize].replace(s).is_some() {
                return Err(WireError::Corrupt(format!("rank {rank} joined twice")));
            }
        }
        let mut ctl_coord = Vec::with_capacity(n);
        for (r, slot) in by_rank.iter_mut().enumerate() {
            let mut s = slot.take().expect("all ranks joined");
            Frame::new(Kind::HelloAck, r as u16, 0, 0, codec::encode_hello_ack(&links))
                .write_to(&mut s)?;
            ctl_coord.push(s);
        }
        Self::finish(n, transport, ctl_coord, Vec::new(), links)
    }

    /// Split ctl streams into directional halves and arm read timeouts.
    fn finish(
        n: usize,
        transport: TransportKind,
        ctl: Vec<WireStream>,
        sessions: Vec<RankSession>,
        links: Vec<LinkSpec>,
    ) -> Result<WireRing, WireError> {
        let mut ctl_w = Vec::with_capacity(n);
        let mut ctl_r = Vec::with_capacity(n);
        for s in ctl {
            let r = s.try_clone()?;
            r.set_read_timeout(Some(READ_TIMEOUT))?;
            ctl_w.push(s);
            ctl_r.push(r);
        }
        Ok(WireRing {
            n,
            transport,
            epoch: 0,
            ctl_w,
            ctl_r,
            sessions,
            links,
            real_bytes: 0,
        })
    }

    /// Ring size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Transport flavor.
    pub fn transport(&self) -> TransportKind {
        self.transport
    }

    /// Per-hop link parameters delivered by the handshake.
    pub fn links(&self) -> &[LinkSpec] {
        &self.links
    }

    /// Total real bytes that traversed ring edges so far.
    pub fn real_bytes(&self) -> u64 {
        self.real_bytes
    }

    /// Stamp subsequent frames with this step's epoch; copies with a
    /// different stamp are rejected as corrupt.
    pub fn begin_step(&mut self, epoch: u32) {
        self.epoch = epoch;
    }

    /// Override the delivery-side read timeout ([`peer::READ_TIMEOUT`]
    /// by default). A partitioned or dead rank then surfaces as a
    /// typed [`WireError::Io`] after `d` instead of 30 s — the seam
    /// the chaos/failure tests use to keep partition detection fast.
    pub fn set_read_timeout(&self, d: Option<std::time::Duration>) -> Result<(), WireError> {
        for r in &self.ctl_r {
            r.set_read_timeout(d)?;
        }
        Ok(())
    }

    /// Spread one frame from `origin` across all `n-1` ring edges,
    /// collect every relay's delivered copy in hop order, verify the
    /// copies byte-identical, and return the payload.
    fn spread(
        &mut self,
        origin: usize,
        kind: Kind,
        flags: u8,
        payload: Vec<u8>,
    ) -> Result<Vec<u8>, WireError> {
        assert!(origin < self.n, "origin {origin} out of range");
        let ttl = (self.n - 1) as u16;
        let epoch = self.epoch;
        let frame = Frame {
            kind,
            flags,
            origin: origin as u16,
            ttl,
            epoch,
            payload,
        };
        self.real_bytes += frame.encoded_len() as u64 * ttl as u64;
        let n = self.n;
        let ctl_w = &mut self.ctl_w[origin];
        let ctl_r = &mut self.ctl_r;
        let mut copies: Vec<Frame> = Vec::with_capacity(ttl as usize);
        // Inject on a scoped thread while this thread drains the
        // deliveries — a frame larger than the socket buffers would
        // otherwise deadlock the write against the unread copies.
        let collected: Result<(), WireError> = std::thread::scope(|s| {
            let inject = s.spawn(move || -> Result<(), WireError> {
                frame.write_to(ctl_w)?;
                std::io::Write::flush(ctl_w)?;
                Ok(())
            });
            for hop in 1..=ttl as usize {
                copies.push(Frame::read_from(&mut ctl_r[(origin + hop) % n])?);
            }
            inject
                .join()
                .unwrap_or_else(|_| Err(WireError::Corrupt("inject thread panicked".into())))
        });
        collected?;
        for (i, c) in copies.iter().enumerate() {
            if c.epoch != epoch {
                return Err(WireError::Corrupt(format!(
                    "hop {} delivered epoch {} during epoch {epoch}",
                    i + 1,
                    c.epoch
                )));
            }
            if c.kind != kind || c.flags != flags || c.origin != origin as u16 || c.ttl != 0 {
                return Err(WireError::Corrupt(format!(
                    "hop {} delivered mismatched header", i + 1
                )));
            }
            if c.payload != copies[0].payload {
                return Err(WireError::Corrupt(format!(
                    "hop {} delivered diverging payload", i + 1
                )));
            }
        }
        Ok(copies.swap_remove(0).payload)
    }

    /// Ring allgather of the dense buffer: `n` contiguous chunks, each
    /// injected at its owner rank and spread around the ring, then
    /// reassembled and verified bit-equal to the input. Returns the
    /// decoded coordinate count (which the engine — not the input —
    /// feeds into the dense accounting).
    pub fn exchange_dense(&mut self, values: &[f32]) -> Result<usize, WireError> {
        let n = self.n;
        let base = values.len() / n;
        let rem = values.len() % n;
        let mut decoded_total = 0usize;
        let mut offset = 0usize;
        for origin in 0..n {
            let len = base + usize::from(origin < rem);
            let chunk = &values[offset..offset + len];
            let out = self.spread(origin, Kind::Dense, 0, codec::encode_dense(chunk))?;
            let got = codec::decode_dense(&out)?;
            if got.len() != len
                || got
                    .iter()
                    .zip(chunk)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(WireError::Corrupt(format!(
                    "dense chunk {origin} decoded differently than sent"
                )));
            }
            decoded_total += got.len();
            offset += len;
        }
        Ok(decoded_total)
    }

    /// Spread one broadcaster's mask (Algorithm 1's mask AllGather
    /// step) and return the decoded copy the downstream OR consumes.
    pub fn spread_mask(&mut self, origin: usize, mask: &BitMask) -> Result<BitMask, WireError> {
        let out = self.spread(origin, Kind::Sparse, 0, codec::encode_support(mask))?;
        codec::decode_support(&out)
    }

    /// Spread a shared mask together with its compacted values and
    /// return both decoded.
    pub fn spread_masked(
        &mut self,
        origin: usize,
        mask: &BitMask,
        values: &[f32],
    ) -> Result<(BitMask, Vec<f32>), WireError> {
        let out = self.spread(origin, Kind::Masked, 0, codec::encode_masked(mask, values))?;
        codec::decode_masked(&out)
    }

    /// Spread a per-layer-scaled ternary gradient; returns the decoded
    /// copy (whose shape feeds the byte accounting).
    pub fn spread_tern_grad(&mut self, t: &TernGrad) -> Result<TernGrad, WireError> {
        let out = self.spread(0, Kind::Tern, 0, codec::encode_tern_grad(t))?;
        codec::decode_tern_grad(&out)
    }

    /// Spread a single-scale ternary blob ([`FLAG_TERN_BLOB`] set).
    pub fn spread_tern_blob(&mut self, t: &TernBlob) -> Result<TernBlob, WireError> {
        let out = self.spread(0, Kind::Tern, FLAG_TERN_BLOB, codec::encode_tern_blob(t))?;
        codec::decode_tern_blob(&out)
    }

    /// AllGather every rank's support mask: rank `r`'s mask spreads
    /// from origin `r mod n`; returns the decoded masks in input
    /// order. Inputs beyond the ring size (exchangeable-node supports,
    /// DESIGN.md §9) spread from their index mod `n`.
    pub fn allgather_supports(
        &mut self,
        supports: &[BitMask],
    ) -> Result<Vec<BitMask>, WireError> {
        let mut out = Vec::with_capacity(supports.len());
        for (i, m) in supports.iter().enumerate() {
            let origin = i % self.n;
            let decoded = self.spread(origin, Kind::Sparse, 0, codec::encode_support(m))?;
            out.push(codec::decode_support(&decoded)?);
        }
        Ok(out)
    }

    /// Tear the ring down: one Shutdown around the ring stops every
    /// relay, a ttl-0 Shutdown on each control channel stops every
    /// uplink, then in-process sessions are joined. Idempotent.
    pub fn shutdown(&mut self) -> Result<(), WireError> {
        if self.ctl_w.is_empty() {
            return Ok(());
        }
        let epoch = self.epoch;
        Frame::new(Kind::Shutdown, 0, self.n as u16, epoch, Vec::new())
            .write_to(&mut self.ctl_w[0])?;
        for w in self.ctl_w.iter_mut() {
            Frame::new(Kind::Shutdown, 0, 0, epoch, Vec::new()).write_to(w)?;
        }
        self.ctl_w.clear();
        self.ctl_r.clear();
        for s in self.sessions.drain(..) {
            s.join()?;
        }
        Ok(())
    }
}

impl Drop for WireRing {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Vec<LinkSpec> {
        vec![LinkSpec::new(1e9, 0.0); n]
    }

    #[test]
    fn transport_kind_parse_name_roundtrip() {
        for k in [TransportKind::Sim, TransportKind::Uds, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
            assert_eq!(format!("{k}"), k.name());
        }
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert!(!TransportKind::Sim.is_wire());
        assert!(TransportKind::Uds.is_wire());
    }

    #[test]
    fn dense_exchange_roundtrips_and_accounts() {
        let mut ring = WireRing::new_in_process(TransportKind::Uds, uniform(4)).unwrap();
        ring.begin_step(1);
        let v: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 9.0).collect();
        assert_eq!(ring.exchange_dense(&v).unwrap(), 37);
        assert!(ring.real_bytes() > 0);
        ring.shutdown().unwrap();
    }

    #[test]
    fn mask_and_tern_spreads_decode_bitexact() {
        let mut ring = WireRing::new_in_process(TransportKind::Uds, uniform(3)).unwrap();
        ring.begin_step(2);
        let mut m = BitMask::zeros(70);
        for i in [0, 13, 64, 69] {
            m.set(i);
        }
        let d = ring.spread_mask(1, &m).unwrap();
        assert_eq!(d.count(), 4);
        for i in 0..70 {
            assert_eq!(d.get(i), m.get(i));
        }
        let blob = TernBlob {
            len: 5,
            scale: 0.75,
            codes: vec![0b10_01_00_01, 0b01],
        };
        let db = ring.spread_tern_blob(&blob).unwrap();
        assert_eq!((db.len, db.scale, db.codes), (blob.len, blob.scale, blob.codes));
        ring.shutdown().unwrap();
    }

    #[test]
    fn allgather_supports_preserves_order() {
        let mut ring = WireRing::new_in_process(TransportKind::Uds, uniform(2)).unwrap();
        ring.begin_step(0);
        let mut a = BitMask::zeros(9);
        a.set(1);
        let mut b = BitMask::zeros(9);
        b.set(8);
        let out = ring.allgather_supports(&[a, b]).unwrap();
        assert!(out[0].get(1) && !out[0].get(8));
        assert!(out[1].get(8) && !out[1].get(1));
        ring.shutdown().unwrap();
    }

    #[test]
    fn tcp_in_process_ring_works() {
        let mut ring = WireRing::new_in_process(TransportKind::Tcp, uniform(2)).unwrap();
        ring.begin_step(3);
        assert_eq!(ring.exchange_dense(&[1.0, 2.0, 3.0]).unwrap(), 3);
        ring.shutdown().unwrap();
    }

    #[test]
    fn handshake_carries_links() {
        let links = vec![LinkSpec::new(1e9, 1e-4), LinkSpec::new(5e8, 2e-4)];
        let ring = WireRing::new_in_process(TransportKind::Uds, links).unwrap();
        assert_eq!(ring.links().len(), 2);
        assert_eq!(ring.links()[1].bandwidth_bps, 5e8);
    }
}
