//! Deterministic byte-level fault injection for the wire transport
//! (DESIGN.md §16).
//!
//! A [`FaultPlan`] is a seeded schedule of frame-level faults applied
//! to ring-edge **data** writes (never to ACK/NACK control traffic and
//! never to the coordinator control channels): bit flips, mid-frame
//! truncation, dropped frames, duplicated frames, fixed delays, and
//! connection resets. The grammar mirrors `net::chaos` so `ringiwp
//! chaos` sweeps wire faults next to membership faults:
//!
//! ```text
//! attempts=4,seed=7,flip@0:1,trunc@2:0,drop@1:2,dup@3:1,delay@0:0:5,reset@4:2
//!           kind@frame:edge            delay@frame:edge:ms
//! ```
//!
//! * `frame` — 0-based index of the original data frame on that edge
//!   (retransmissions do not advance the index);
//! * `edge` — ring-edge index, taken modulo the live ring size so a
//!   plan survives elastic re-rings;
//! * `attempts` — the bounded per-frame retry budget (send attempts
//!   including the first; `WireError::Exhausted` past it);
//! * `seed` — drives the *positions* (which bit flips, where the cut
//!   lands) via SplitMix64, keyed per `(edge, frame, attempt)` so the
//!   same plan replays byte-identically.
//!
//! When several events name the same `(frame, edge)` cell, the k-th
//! listed event fires on the k-th send attempt — a plan with more
//! events on a cell than `attempts` is an *unrecoverable* schedule by
//! construction and must fail loudly (the `wire_fault_recovery.rs`
//! golden suite pins both directions).
//!
//! Faults are in-process only: external rings (`--wire-dir`) refuse a
//! non-empty plan, because a shim that corrupts real remote peers'
//! traffic is a footgun, not a test harness.

use std::collections::HashMap;
use std::fmt;

use crate::util::rng::Rng;

use super::frame::WireError;

/// Default bounded retry budget (send attempts per frame).
pub const DEFAULT_ATTEMPTS: u32 = 4;

/// Hard cap on a scheduled delay fault, so a typo cannot stall a CI
/// ring past its watchdog.
pub const MAX_DELAY_MS: u64 = 100;

/// XOR tag decorrelating the wire-fault stream from the membership
/// stream inside `ChaosPlan::generate` (which uses `seed ^ 0xC4A0_55ED`).
pub const GENERATE_TAG: u64 = 0x57A6_F001;

/// One kind of injectable frame fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip one seeded bit of the encoded frame (CRC catches it, the
    /// receiver NACKs, the sender retransmits).
    Flip,
    /// Cut the write at a seeded mid-frame offset (receiver stalls
    /// mid-frame, drains, NACKs).
    Trunc,
    /// Swallow the write entirely (sender's ACK timeout retransmits).
    Drop,
    /// Write the frame twice (receiver drops the duplicate seq).
    Dup,
    /// Sleep this many milliseconds before the write (≤ [`MAX_DELAY_MS`]).
    Delay(u64),
    /// Surface a connection reset at the sender before the write; the
    /// sender reconnects with capped exponential backoff and retries.
    Reset,
}

impl FaultKind {
    fn token(&self) -> String {
        match self {
            FaultKind::Flip => "flip".into(),
            FaultKind::Trunc => "trunc".into(),
            FaultKind::Drop => "drop".into(),
            FaultKind::Dup => "dup".into(),
            FaultKind::Delay(_) => "delay".into(),
            FaultKind::Reset => "reset".into(),
        }
    }
}

/// One scheduled fault: fire `kind` on data frame `frame` of ring edge
/// `edge` (edge taken modulo the live ring size).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// 0-based original-frame index on the edge (retransmits don't count).
    pub frame: u64,
    /// Ring-edge index (sender rank), modulo the live ring size.
    pub edge: usize,
    /// What to do to that frame's write.
    pub kind: FaultKind,
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            FaultKind::Delay(ms) => write!(f, "delay@{}:{}:{}", self.frame, self.edge, ms),
            ref k => write!(f, "{}@{}:{}", k.token(), self.frame, self.edge),
        }
    }
}

/// A seeded, deterministic schedule of wire faults.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Scheduled fault events (listed order breaks ties on the same
    /// `(frame, edge)` cell: k-th event → k-th send attempt).
    pub events: Vec<FaultEvent>,
    /// Bounded per-frame send-attempt budget (validated 2..=6).
    pub attempts: u32,
    /// Seed for fault *positions* (flip bit, truncation cut).
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            events: Vec::new(),
            attempts: DEFAULT_ATTEMPTS,
            seed: 0,
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if self.attempts != DEFAULT_ATTEMPTS {
            parts.push(format!("attempts={}", self.attempts));
        }
        if self.seed != 0 {
            parts.push(format!("seed={}", self.seed));
        }
        parts.extend(self.events.iter().map(|e| e.to_string()));
        write!(f, "{}", parts.join(","))
    }
}

impl FaultPlan {
    /// The no-fault plan (identical to [`FaultPlan::default`]).
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// True when no events are scheduled (attempts/seed alone do not
    /// make a plan "active" — an empty plan must be bit-identical to
    /// no plan at all).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the comma-separated grammar (see module docs). Empty input
    /// parses to the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            if let Some(v) = tok.strip_prefix("attempts=") {
                plan.attempts = v
                    .parse::<u32>()
                    .map_err(|e| format!("bad attempts `{tok}`: {e}"))?;
            } else if let Some(v) = tok.strip_prefix("seed=") {
                plan.seed = v
                    .parse::<u64>()
                    .map_err(|e| format!("bad seed `{tok}`: {e}"))?;
            } else {
                plan.events.push(Self::parse_event(tok)?);
            }
        }
        Ok(plan)
    }

    fn parse_event(tok: &str) -> Result<FaultEvent, String> {
        let (kind_s, rest) = tok
            .split_once('@')
            .ok_or_else(|| format!("bad wire-fault token `{tok}` (want kind@frame:edge)"))?;
        let fields: Vec<&str> = rest.split(':').collect();
        let need = if kind_s == "delay" { 3 } else { 2 };
        if fields.len() != need {
            return Err(format!(
                "bad wire-fault token `{tok}`: `{kind_s}` wants {need} `:`-fields"
            ));
        }
        let frame = fields[0]
            .parse::<u64>()
            .map_err(|e| format!("bad frame in `{tok}`: {e}"))?;
        let edge = fields[1]
            .parse::<usize>()
            .map_err(|e| format!("bad edge in `{tok}`: {e}"))?;
        let kind = match kind_s {
            "flip" => FaultKind::Flip,
            "trunc" => FaultKind::Trunc,
            "drop" => FaultKind::Drop,
            "dup" => FaultKind::Dup,
            "reset" => FaultKind::Reset,
            "delay" => {
                let ms = fields[2]
                    .parse::<u64>()
                    .map_err(|e| format!("bad delay ms in `{tok}`: {e}"))?;
                FaultKind::Delay(ms)
            }
            other => return Err(format!("unknown wire-fault kind `{other}` in `{tok}`")),
        };
        Ok(FaultEvent { frame, edge, kind })
    }

    /// Parse `RINGIWP_WIRE_FAULTS`; panics on malformed input (mirrors
    /// the other env knobs: a typo'd schedule silently dropped would
    /// un-test exactly what the operator asked to test). Unset → `None`.
    pub fn from_env() -> Option<FaultPlan> {
        let s = std::env::var("RINGIWP_WIRE_FAULTS").ok()?;
        Some(Self::parse(&s).unwrap_or_else(|e| panic!("RINGIWP_WIRE_FAULTS: {e}")))
    }

    /// Structural validation (grammar-level; ring-size concerns are
    /// handled by the modulo mapping at ring build time).
    pub fn validate(&self) -> Result<(), String> {
        if !(2..=6).contains(&self.attempts) {
            return Err(format!(
                "wire-fault attempts {} out of range 2..=6",
                self.attempts
            ));
        }
        for e in &self.events {
            if let FaultKind::Delay(ms) = e.kind {
                if ms > MAX_DELAY_MS {
                    return Err(format!(
                        "wire-fault delay {ms}ms exceeds cap {MAX_DELAY_MS}ms ({e})"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Generate a small recoverable plan from a seed: 2–3 events drawn
    /// from the *cheap* kinds (flip, dup, delay, reset) on early frames
    /// of random edges. Drop and truncation are excluded on purpose —
    /// they recover through multi-second ACK timeouts, which would blow
    /// the CI chaos-smoke budget; dedicated tests cover them instead.
    pub fn generate(seed: u64, edges: usize, frames: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ GENERATE_TAG);
        let mut plan = FaultPlan {
            seed,
            ..FaultPlan::default()
        };
        let count = 2 + rng.below(2);
        for _ in 0..count {
            let frame = rng.below(frames.max(1) as usize) as u64;
            let edge = rng.below(edges.max(1));
            let kind = match rng.below(4) {
                0 => FaultKind::Flip,
                1 => FaultKind::Dup,
                2 => FaultKind::Delay(1 + rng.below(5) as u64),
                _ => FaultKind::Reset,
            };
            plan.events.push(FaultEvent { frame, edge, kind });
        }
        plan
    }

    /// Project the plan onto one ring edge of an `n`-edge ring: events
    /// whose `edge % n` equals `edge`. Returns `None` when nothing is
    /// scheduled there (the edge runs fault-free at zero overhead).
    pub fn edge_faults(&self, edge: usize, n: usize) -> Option<EdgeFaults> {
        let mut by_frame: HashMap<u64, Vec<FaultKind>> = HashMap::new();
        for e in self.events.iter().filter(|e| e.edge % n == edge) {
            by_frame.entry(e.frame).or_default().push(e.kind);
        }
        if by_frame.is_empty() {
            return None;
        }
        Some(EdgeFaults {
            edge,
            seed: self.seed,
            by_frame,
        })
    }

    /// A plan whose events outnumber the attempt budget on some cell is
    /// unrecoverable by construction; typed helper for refusals.
    pub fn unrecoverable_cells(&self) -> Vec<(u64, usize)> {
        let mut counts: HashMap<(u64, usize), u32> = HashMap::new();
        for e in &self.events {
            *counts.entry((e.frame, e.edge)).or_default() += 1;
        }
        let mut cells: Vec<(u64, usize)> = counts
            .into_iter()
            .filter(|&(_, c)| c >= self.attempts)
            .map(|(k, _)| k)
            .collect();
        cells.sort_unstable();
        cells
    }
}

/// One edge's projection of a [`FaultPlan`]: fault lookups keyed by
/// original-frame index + attempt, plus the seeded position draws.
#[derive(Debug, Clone)]
pub struct EdgeFaults {
    edge: usize,
    seed: u64,
    by_frame: HashMap<u64, Vec<FaultKind>>,
}

impl EdgeFaults {
    /// The fault to apply on send attempt `attempt` (0-based) of
    /// original frame `frame`, if any: the k-th scheduled event on the
    /// cell fires on the k-th attempt.
    pub fn at(&self, frame: u64, attempt: u32) -> Option<FaultKind> {
        self.by_frame
            .get(&frame)
            .and_then(|ks| ks.get(attempt as usize))
            .copied()
    }

    /// Seeded position stream for `(frame, attempt)` on this edge —
    /// same plan seed ⇒ same flipped bit / truncation cut every run.
    fn pos_rng(&self, frame: u64, attempt: u32) -> Rng {
        Rng::new(
            self.seed
                ^ (frame.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ ((self.edge as u64) << 32)
                ^ u64::from(attempt).wrapping_mul(0xBF58_476D_1CE4_E5B9),
        )
    }

    /// Which bit of an `nbytes`-byte encoded frame a Flip corrupts.
    pub fn flip_bit(&self, frame: u64, attempt: u32, nbytes: usize) -> usize {
        debug_assert!(nbytes > 0);
        self.pos_rng(frame, attempt).below(nbytes * 8)
    }

    /// Where a Trunc cuts: at least 1 byte written, strictly less than
    /// the full frame (so the receiver always stalls mid-frame).
    pub fn trunc_cut(&self, frame: u64, attempt: u32, nbytes: usize) -> usize {
        debug_assert!(nbytes > 1);
        1 + self.pos_rng(frame, attempt).below(nbytes - 1)
    }
}

/// Refuse a plan/context combination the recovery layer cannot honor
/// (external rings, v1-negotiated rings).
pub fn refuse(reason: &str) -> WireError {
    WireError::Corrupt(format!("wire-fault injection refused: {reason}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_roundtrips_through_display() {
        let s = "attempts=3,seed=9,flip@0:1,trunc@2:0,drop@1:2,dup@3:1,delay@0:0:5,reset@4:2";
        let plan = FaultPlan::parse(s).unwrap();
        assert_eq!(plan.attempts, 3);
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.events.len(), 6);
        let echoed = plan.to_string();
        assert_eq!(FaultPlan::parse(&echoed).unwrap(), plan);
        assert_eq!(echoed, s);
    }

    #[test]
    fn defaults_are_elided_from_display_and_empty_is_empty() {
        let plan = FaultPlan::parse("flip@0:0").unwrap();
        assert_eq!(plan.attempts, DEFAULT_ATTEMPTS);
        assert_eq!(plan.to_string(), "flip@0:0");
        let empty = FaultPlan::parse("").unwrap();
        assert!(empty.is_empty());
        assert_eq!(empty, FaultPlan::default());
        assert_eq!(empty.to_string(), "");
    }

    #[test]
    fn malformed_tokens_are_rejected() {
        for bad in [
            "flip@0",          // missing edge
            "flip@0:1:2",      // extra field
            "delay@0:1",       // delay needs ms
            "warp@0:1",        // unknown kind
            "flip@x:1",        // non-numeric frame
            "attempts=zero",   // non-numeric attempts
            "seed=",           // empty seed
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn validate_bounds_attempts_and_delay() {
        let mut plan = FaultPlan::parse("flip@0:0").unwrap();
        assert!(plan.validate().is_ok());
        plan.attempts = 1;
        assert!(plan.validate().is_err());
        plan.attempts = 7;
        assert!(plan.validate().is_err());
        plan.attempts = 4;
        plan.events.push(FaultEvent {
            frame: 0,
            edge: 0,
            kind: FaultKind::Delay(MAX_DELAY_MS + 1),
        });
        assert!(plan.validate().is_err());
    }

    #[test]
    fn generate_is_deterministic_recoverable_and_seed_sensitive() {
        let a = FaultPlan::generate(17, 5, 8);
        let b = FaultPlan::generate(17, 5, 8);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.validate().is_ok());
        assert!(a.unrecoverable_cells().is_empty());
        // Only cheap kinds appear (no drop/trunc in generated plans).
        for e in &a.events {
            assert!(
                !matches!(e.kind, FaultKind::Drop | FaultKind::Trunc),
                "generated plan must avoid slow kinds, got {e}"
            );
        }
        assert_ne!(FaultPlan::generate(18, 5, 8), a);
    }

    #[test]
    fn edge_projection_wraps_modulo_ring_size() {
        let plan = FaultPlan::parse("flip@0:0,dup@1:3,reset@2:4").unwrap();
        // Ring of 3: edge 3 wraps to 0, edge 4 wraps to 1.
        let e0 = plan.edge_faults(0, 3).unwrap();
        assert_eq!(e0.at(0, 0), Some(FaultKind::Flip));
        assert_eq!(e0.at(1, 0), Some(FaultKind::Dup));
        let e1 = plan.edge_faults(1, 3).unwrap();
        assert_eq!(e1.at(2, 0), Some(FaultKind::Reset));
        assert!(plan.edge_faults(2, 3).is_none());
    }

    #[test]
    fn stacked_events_fire_per_attempt_in_listed_order() {
        let plan = FaultPlan::parse("flip@0:0,reset@0:0").unwrap();
        let e = plan.edge_faults(0, 5).unwrap();
        assert_eq!(e.at(0, 0), Some(FaultKind::Flip));
        assert_eq!(e.at(0, 1), Some(FaultKind::Reset));
        assert_eq!(e.at(0, 2), None); // third attempt runs clean
        assert!(plan.unrecoverable_cells().is_empty());
    }

    #[test]
    fn unrecoverable_cells_are_detected() {
        let mut plan = FaultPlan::parse("flip@0:0,flip@0:0,flip@0:0,flip@0:0").unwrap();
        assert_eq!(plan.unrecoverable_cells(), vec![(0, 0)]);
        plan.attempts = 5;
        assert!(plan.unrecoverable_cells().is_empty());
    }

    #[test]
    fn seeded_positions_replay_and_stay_in_bounds() {
        let plan = FaultPlan::parse("seed=42,flip@0:1").unwrap();
        let e = plan.edge_faults(1, 4).unwrap();
        let bit = e.flip_bit(0, 0, 64);
        assert_eq!(e.flip_bit(0, 0, 64), bit);
        assert!(bit < 64 * 8);
        // Different attempt → (almost surely) different position stream.
        assert!(e.flip_bit(0, 1, 64) != bit || e.flip_bit(0, 2, 64) != bit);
        let cut = e.trunc_cut(0, 0, 64);
        assert!((1..64).contains(&cut));
        // A different plan seed moves the position.
        let plan2 = FaultPlan::parse("seed=43,flip@0:1").unwrap();
        let e2 = plan2.edge_faults(1, 4).unwrap();
        assert!(e2.flip_bit(0, 0, 64) != bit || e2.trunc_cut(0, 0, 64) != cut);
    }
}
