//! Versioned, length-prefixed wire frames (DESIGN.md §13).
//!
//! Every message on a real ring edge travels as one frame: a fixed
//! 20-byte little-endian header followed by `payload_len` payload
//! bytes. The header carries everything the relay loop needs without
//! touching the payload — kind, origin rank, remaining hop count
//! (`ttl`), and the step epoch — so forwarding is a header rewrite
//! plus a byte copy, never a re-encode.
//!
//! ```text
//! offset  size  field        notes
//! ------  ----  -----------  ----------------------------------------
//!      0     4  magic        b"RIWP"
//!      4     2  version      u16 LE, currently 1; mismatch is typed
//!      6     1  kind         Dense|Sparse|Masked|Tern|Hello|HelloAck|Shutdown
//!      7     1  flags        bit0 = FLAG_TERN_BLOB (Tern payload is a
//!                            single-scale TernBlob, not a TernGrad)
//!      8     2  origin       u16 LE, rank that injected the frame
//!     10     2  ttl          u16 LE, ring-edge traversals remaining
//!     12     4  epoch        u32 LE, step/handshake epoch stamp
//!     16     4  payload_len  u32 LE
//!     20     …  payload      codec-encoded (see `super::codec`)
//! ```
//!
//! Decoding is total: malformed input returns a typed [`WireError`],
//! never a panic — the transport-equivalence suite and
//! `tests/wire_codec.rs` exercise truncation, bad magic, bad kind and
//! version skew explicitly.

use std::io::{Read, Write};

/// Frame magic: ASCII "RIWP".
pub const MAGIC: [u8; 4] = *b"RIWP";

/// Current wire protocol version. Bump on any header or payload layout
/// change; peers reject mismatches with [`WireError::Version`].
pub const VERSION: u16 = 1;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 20;

/// Hard cap on a single frame payload (guards against garbage
/// `payload_len` allocating gigabytes on a corrupt stream).
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Flag bit 0: the Tern payload is a single-scale `TernBlob` rather
/// than a per-layer-scaled `TernGrad`.
pub const FLAG_TERN_BLOB: u8 = 1;

/// Frame kinds — the four payload codecs plus control traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// Dense f32 chunk.
    Dense = 1,
    /// Sparse support bitmask segment.
    Sparse = 2,
    /// Word-packed mask + compacted values blob.
    Masked = 3,
    /// Ternary blob (TernGrad or, with [`FLAG_TERN_BLOB`], TernBlob).
    Tern = 4,
    /// Handshake: rank → coordinator (version, rank, ring size).
    Hello = 5,
    /// Handshake reply: coordinator → rank (per-hop link parameters).
    HelloAck = 6,
    /// Orderly session teardown.
    Shutdown = 7,
}

impl Kind {
    /// Decode a kind byte.
    pub fn from_u8(b: u8) -> Result<Kind, WireError> {
        Ok(match b {
            1 => Kind::Dense,
            2 => Kind::Sparse,
            3 => Kind::Masked,
            4 => Kind::Tern,
            5 => Kind::Hello,
            6 => Kind::HelloAck,
            7 => Kind::Shutdown,
            other => return Err(WireError::BadKind(other)),
        })
    }
}

/// Typed transport failures. Everything a peer can receive off a
/// socket decodes to one of these — the engines `expect` only on
/// programmer errors, never on wire input.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    /// Header does not start with `b"RIWP"`.
    #[error("bad frame magic (expected \"RIWP\")")]
    BadMagic,
    /// Peer speaks a different protocol version.
    #[error("wire protocol version mismatch: got {got}, want {want}")]
    Version {
        /// Version advertised by the peer.
        got: u16,
        /// Version this build speaks ([`VERSION`]).
        want: u16,
    },
    /// Unknown kind byte.
    #[error("unknown frame kind byte {0}")]
    BadKind(u8),
    /// Stream ended (or buffer was shorter) than the header promised.
    #[error("truncated frame: needed {need} bytes, got {got}")]
    Truncated {
        /// Bytes the header/codec required.
        need: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// Structurally valid frame whose contents are inconsistent
    /// (payload/shape mismatch, diverging relay copies, epoch skew).
    #[error("corrupt frame: {0}")]
    Corrupt(String),
    /// Underlying socket failure (includes read timeouts).
    #[error("wire i/o: {0}")]
    Io(#[from] std::io::Error),
}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Payload kind.
    pub kind: Kind,
    /// Flag bits ([`FLAG_TERN_BLOB`]).
    pub flags: u8,
    /// Rank that injected the frame into the ring.
    pub origin: u16,
    /// Ring-edge traversals remaining (relays forward while > 1).
    pub ttl: u16,
    /// Step epoch stamp; receivers reject cross-epoch frames.
    pub epoch: u32,
    /// Codec-encoded payload bytes.
    pub payload: Vec<u8>,
}

impl Frame {
    /// Build a frame with no flags set.
    pub fn new(kind: Kind, origin: u16, ttl: u16, epoch: u32, payload: Vec<u8>) -> Self {
        Frame {
            kind,
            flags: 0,
            origin,
            ttl,
            epoch,
            payload,
        }
    }

    /// Encode header + payload into a fresh buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.payload.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind as u8);
        out.push(self.flags);
        out.extend_from_slice(&self.origin.to_le_bytes());
        out.extend_from_slice(&self.ttl.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Total encoded size in bytes.
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Decode a frame from an in-memory buffer. The buffer must contain
    /// exactly one frame (trailing bytes are rejected as corrupt).
    pub fn decode(buf: &[u8]) -> Result<Frame, WireError> {
        if buf.len() < HEADER_LEN {
            return Err(WireError::Truncated {
                need: HEADER_LEN,
                got: buf.len(),
            });
        }
        let (frame, used) = Self::decode_prefix(buf)?;
        if used != buf.len() {
            return Err(WireError::Corrupt(format!(
                "{} trailing bytes after frame",
                buf.len() - used
            )));
        }
        Ok(frame)
    }

    /// Decode one frame from the front of `buf`, returning it and the
    /// number of bytes consumed.
    pub fn decode_prefix(buf: &[u8]) -> Result<(Frame, usize), WireError> {
        if buf.len() < HEADER_LEN {
            return Err(WireError::Truncated {
                need: HEADER_LEN,
                got: buf.len(),
            });
        }
        let (kind, flags, origin, ttl, epoch, payload_len) = parse_header(&buf[..HEADER_LEN])?;
        let total = HEADER_LEN + payload_len as usize;
        if buf.len() < total {
            return Err(WireError::Truncated {
                need: total,
                got: buf.len(),
            });
        }
        let payload = buf[HEADER_LEN..total].to_vec();
        Ok((
            Frame {
                kind,
                flags,
                origin,
                ttl,
                epoch,
                payload,
            },
            total,
        ))
    }

    /// Write the frame to a stream (single buffered write).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<(), WireError> {
        w.write_all(&self.encode())?;
        Ok(())
    }

    /// Read one frame off a stream. A clean EOF before any header byte
    /// maps to [`WireError::Io`] with `UnexpectedEof`; a partial header
    /// or payload does too (the socket layer cannot distinguish a
    /// truncated frame from a dropped connection).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Frame, WireError> {
        let mut header = [0u8; HEADER_LEN];
        r.read_exact(&mut header)?;
        let (kind, flags, origin, ttl, epoch, payload_len) = parse_header(&header)?;
        let mut payload = vec![0u8; payload_len as usize];
        r.read_exact(&mut payload)?;
        Ok(Frame {
            kind,
            flags,
            origin,
            ttl,
            epoch,
            payload,
        })
    }
}

/// Validate and split a 20-byte header.
fn parse_header(h: &[u8]) -> Result<(Kind, u8, u16, u16, u32, u32), WireError> {
    debug_assert_eq!(h.len(), HEADER_LEN);
    if h[0..4] != MAGIC {
        return Err(WireError::BadMagic);
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != VERSION {
        return Err(WireError::Version {
            got: version,
            want: VERSION,
        });
    }
    let kind = Kind::from_u8(h[6])?;
    let flags = h[7];
    let origin = u16::from_le_bytes([h[8], h[9]]);
    let ttl = u16::from_le_bytes([h[10], h[11]]);
    let epoch = u32::from_le_bytes([h[12], h[13], h[14], h[15]]);
    let payload_len = u32::from_le_bytes([h[16], h[17], h[18], h[19]]);
    if payload_len > MAX_PAYLOAD {
        return Err(WireError::Corrupt(format!(
            "payload_len {payload_len} exceeds cap {MAX_PAYLOAD}"
        )));
    }
    Ok((kind, flags, origin, ttl, epoch, payload_len))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        Frame {
            kind: Kind::Masked,
            flags: FLAG_TERN_BLOB,
            origin: 3,
            ttl: 8,
            epoch: 42,
            payload: vec![1, 2, 3, 4, 5],
        }
    }

    #[test]
    fn roundtrip_buffer() {
        let f = sample();
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        assert_eq!(Frame::decode(&bytes).unwrap(), f);
    }

    #[test]
    fn roundtrip_stream() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(Frame::read_from(&mut cursor).unwrap(), f);
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadMagic)));
    }

    #[test]
    fn version_bump_is_typed() {
        let mut bytes = sample().encode();
        bytes[4] = (VERSION + 1) as u8;
        match Frame::decode(&bytes) {
            Err(WireError::Version { got, want }) => {
                assert_eq!(got, VERSION + 1);
                assert_eq!(want, VERSION);
            }
            other => panic!("expected Version error, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed() {
        let bytes = sample().encode();
        for cut in [0, 3, HEADER_LEN - 1, HEADER_LEN, bytes.len() - 1] {
            assert!(
                matches!(Frame::decode(&bytes[..cut]), Err(WireError::Truncated { .. })),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn bad_kind_and_trailing_bytes_are_typed() {
        let mut bytes = sample().encode();
        bytes[6] = 99;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadKind(99))));
        let mut bytes = sample().encode();
        bytes.push(0);
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn empty_payload_roundtrips() {
        let f = Frame::new(Kind::Shutdown, 0, 0, 7, Vec::new());
        assert_eq!(Frame::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn decode_prefix_consumes_exactly_one_frame() {
        let a = sample();
        let b = Frame::new(Kind::Dense, 1, 2, 3, vec![9]);
        let mut bytes = a.encode();
        bytes.extend_from_slice(&b.encode());
        let (fa, used) = Frame::decode_prefix(&bytes).unwrap();
        assert_eq!(fa, a);
        let (fb, used2) = Frame::decode_prefix(&bytes[used..]).unwrap();
        assert_eq!(fb, b);
        assert_eq!(used + used2, bytes.len());
    }
}
