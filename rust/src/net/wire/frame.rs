//! Versioned, length-prefixed wire frames (DESIGN.md §13, §16).
//!
//! Every message on a real ring edge travels as one frame: a fixed
//! 20-byte little-endian header followed by `payload_len` payload
//! bytes. The header carries everything the relay loop needs without
//! touching the payload — kind, origin rank, remaining hop count
//! (`ttl`), and the step epoch — so forwarding is a header rewrite
//! plus a byte copy, never a re-encode.
//!
//! ```text
//! offset  size  field        notes
//! ------  ----  -----------  ----------------------------------------
//!      0     4  magic        b"RIWP"
//!      4     2  version      u16 LE, 1 or 2; anything else is typed
//!      6     1  kind         Dense|Sparse|Masked|Tern|Hello|HelloAck|
//!                            Shutdown|Ack|Nack|Quant
//!      7     1  flags        bit0 = FLAG_TERN_BLOB, bit1 = FLAG_CAP_V2
//!      8     2  origin       u16 LE, rank that injected the frame
//!     10     2  ttl          u16 LE, ring-edge traversals remaining
//!     12     4  epoch        u32 LE, step/handshake epoch stamp
//!     16     4  payload_len  u32 LE
//!     20     …  payload      codec-encoded (see `super::codec`)
//! ```
//!
//! **Version 2** appends an 8-byte integrity trailer after the payload
//! (DESIGN.md §16):
//!
//! ```text
//! offset              size  field  notes
//! ------------------  ----  -----  --------------------------------
//! 20 + payload_len       4  seq    u32 LE, per-edge transmission
//!                                  sequence (0 on control channels)
//! 24 + payload_len       4  crc    u32 LE, CRC-32 (IEEE) over
//!                                  header ‖ payload ‖ seq
//! ```
//!
//! Decoders accept both versions on the same stream — that is what
//! makes Hello/HelloAck version negotiation possible ([`FLAG_CAP_V2`]):
//! the handshake always travels at version 1, and the negotiated
//! version governs every frame after it. A corrupted trailer surfaces
//! as the typed [`WireError::Checksum`] the per-hop recovery layer
//! (`super::peer`) turns into a NACK + retransmit.
//!
//! Decoding is total: malformed input returns a typed [`WireError`],
//! never a panic — the transport-equivalence suite and
//! `tests/wire_codec.rs` exercise truncation, bad magic, bad kind,
//! version skew and single-bit corruption explicitly.

use std::io::{Read, Write};

/// Frame magic: ASCII "RIWP".
pub const MAGIC: [u8; 4] = *b"RIWP";

/// Legacy wire protocol version: header + payload, no trailer.
pub const V1: u16 = 1;

/// Current wire protocol version: header + payload + CRC-32 trailer.
/// Decoders accept [`V1`] and [`VERSION`]; anything else is rejected
/// with [`WireError::Version`].
pub const VERSION: u16 = 2;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 20;

/// Version-2 trailer size in bytes (`seq` + `crc`).
pub const TRAILER_LEN: usize = 8;

/// Hard cap on a single frame payload (guards against garbage
/// `payload_len` allocating gigabytes on a corrupt stream).
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Flag bit 0: the Tern payload is a single-scale `TernBlob` rather
/// than a per-layer-scaled `TernGrad`.
pub const FLAG_TERN_BLOB: u8 = 1;

/// Flag bit 1, on Hello/HelloAck frames only: the sender speaks wire
/// protocol version 2 (CRC trailer + per-hop ARQ). A ring runs at v2
/// iff every Hello carried the bit; the coordinator echoes the
/// decision on each HelloAck. Old v1 peers leave the bit clear and
/// the ring transparently degrades to v1 framing.
pub const FLAG_CAP_V2: u8 = 1 << 1;

/// Frame kinds — the five payload codecs plus control traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    /// Dense f32 chunk.
    Dense = 1,
    /// Sparse support bitmask segment.
    Sparse = 2,
    /// Word-packed mask + compacted values blob.
    Masked = 3,
    /// Ternary blob (TernGrad or, with [`FLAG_TERN_BLOB`], TernBlob).
    Tern = 4,
    /// Handshake: rank → coordinator (version, rank, ring size).
    Hello = 5,
    /// Handshake reply: coordinator → rank (per-hop link parameters).
    HelloAck = 6,
    /// Orderly session teardown.
    Shutdown = 7,
    /// Per-edge ARQ acknowledgment (v2 only, empty payload, trailer
    /// `seq` names the acknowledged transmission).
    Ack = 8,
    /// Per-edge retransmit request (v2 only, empty payload, trailer
    /// `seq` names the first missing transmission).
    Nack = 9,
    /// Low-precision payload blob (`+q:<bits>` QBlob: width tag,
    /// per-block scales, packed codes — see `super::codec`).
    Quant = 10,
}

impl Kind {
    /// Decode a kind byte.
    pub fn from_u8(b: u8) -> Result<Kind, WireError> {
        Ok(match b {
            1 => Kind::Dense,
            2 => Kind::Sparse,
            3 => Kind::Masked,
            4 => Kind::Tern,
            5 => Kind::Hello,
            6 => Kind::HelloAck,
            7 => Kind::Shutdown,
            8 => Kind::Ack,
            9 => Kind::Nack,
            10 => Kind::Quant,
            other => return Err(WireError::BadKind(other)),
        })
    }
}

/// Typed transport failures. Everything a peer can receive off a
/// socket decodes to one of these — the engines `expect` only on
/// programmer errors, never on wire input.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    /// Header does not start with `b"RIWP"`.
    #[error("bad frame magic (expected \"RIWP\")")]
    BadMagic,
    /// Peer speaks a protocol version this build does not (neither
    /// [`V1`] nor [`VERSION`]).
    #[error("wire protocol version mismatch: got {got}, want {want}")]
    Version {
        /// Version advertised by the peer.
        got: u16,
        /// Newest version this build speaks ([`VERSION`]).
        want: u16,
    },
    /// Unknown kind byte.
    #[error("unknown frame kind byte {0}")]
    BadKind(u8),
    /// Stream ended (or buffer was shorter) than the header promised.
    #[error("truncated frame: needed {need} bytes, got {got}")]
    Truncated {
        /// Bytes the header/codec required.
        need: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// Version-2 trailer CRC does not match the received bytes — the
    /// recoverable corruption signal the ARQ layer NACKs on.
    #[error("frame checksum mismatch: expected {expected:#010x}, got {got:#010x}")]
    Checksum {
        /// CRC-32 recomputed over the received header ‖ payload ‖ seq.
        expected: u32,
        /// CRC-32 carried by the trailer.
        got: u32,
    },
    /// A recoverable fault persisted through every retransmit attempt
    /// — the fault is treated as fatal and the ring tears down.
    #[error("unrecoverable wire fault: retry budget exhausted after {attempts} attempts")]
    Exhausted {
        /// The bounded attempt budget that was exhausted.
        attempts: u32,
    },
    /// Structurally valid frame whose contents are inconsistent
    /// (payload/shape mismatch, diverging relay copies, epoch skew).
    #[error("corrupt frame: {0}")]
    Corrupt(String),
    /// Underlying socket failure (includes read timeouts).
    #[error("wire i/o: {0}")]
    Io(#[from] std::io::Error),
}

/// CRC-32 (IEEE 802.3, polynomial `0xEDB88320`) lookup table, built at
/// compile time — no new dependency for the integrity trailer.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) over the concatenation of `chunks`.
pub fn crc32(chunks: &[&[u8]]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for chunk in chunks {
        for &b in *chunk {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    c ^ 0xFFFF_FFFF
}

/// Per-transmission metadata a decoder recovers next to the [`Frame`]:
/// the wire version the bytes traveled at and, for version 2, the
/// per-edge sequence number from the trailer (0 at version 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameMeta {
    /// Wire version of this transmission ([`V1`] or [`VERSION`]).
    pub version: u16,
    /// Trailer sequence number (0 for version-1 frames).
    pub seq: u32,
}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Payload kind.
    pub kind: Kind,
    /// Flag bits ([`FLAG_TERN_BLOB`], [`FLAG_CAP_V2`]).
    pub flags: u8,
    /// Rank that injected the frame into the ring.
    pub origin: u16,
    /// Ring-edge traversals remaining (relays forward while > 1).
    pub ttl: u16,
    /// Step epoch stamp; receivers reject cross-epoch frames.
    pub epoch: u32,
    /// Codec-encoded payload bytes.
    pub payload: Vec<u8>,
}

impl Frame {
    /// Build a frame with no flags set.
    pub fn new(kind: Kind, origin: u16, ttl: u16, epoch: u32, payload: Vec<u8>) -> Self {
        Frame {
            kind,
            flags: 0,
            origin,
            ttl,
            epoch,
            payload,
        }
    }

    /// Encode header + payload at version 1 (no trailer) into a fresh
    /// buffer — the encoding every pre-negotiation frame and every v1
    /// ring edge uses, byte-identical to the PR-6 format.
    pub fn encode(&self) -> Vec<u8> {
        self.encode_at(V1, 0)
    }

    /// Encode at an explicit wire version. Version 2 appends the
    /// `seq`+CRC trailer; version 1 ignores `seq`.
    pub fn encode_at(&self, version: u16, seq: u32) -> Vec<u8> {
        debug_assert!(version == V1 || version == VERSION, "unknown version {version}");
        let trailer = if version >= VERSION { TRAILER_LEN } else { 0 };
        let mut out = Vec::with_capacity(HEADER_LEN + self.payload.len() + trailer);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.push(self.kind as u8);
        out.push(self.flags);
        out.extend_from_slice(&self.origin.to_le_bytes());
        out.extend_from_slice(&self.ttl.to_le_bytes());
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        if version >= VERSION {
            out.extend_from_slice(&seq.to_le_bytes());
            let crc = crc32(&[&out]);
            out.extend_from_slice(&crc.to_le_bytes());
        }
        out
    }

    /// Total encoded size in bytes at version 1.
    pub fn encoded_len(&self) -> usize {
        self.encoded_len_at(V1)
    }

    /// Total encoded size in bytes at the given wire version.
    pub fn encoded_len_at(&self, version: u16) -> usize {
        HEADER_LEN
            + self.payload.len()
            + if version >= VERSION { TRAILER_LEN } else { 0 }
    }

    /// Decode a frame from an in-memory buffer. The buffer must contain
    /// exactly one frame (trailing bytes are rejected as corrupt).
    pub fn decode(buf: &[u8]) -> Result<Frame, WireError> {
        let (frame, _, used) = Self::decode_prefix_ext(buf)?;
        if used != buf.len() {
            return Err(WireError::Corrupt(format!(
                "{} trailing bytes after frame",
                buf.len() - used
            )));
        }
        Ok(frame)
    }

    /// Decode one frame from the front of `buf`, returning it and the
    /// number of bytes consumed.
    pub fn decode_prefix(buf: &[u8]) -> Result<(Frame, usize), WireError> {
        let (frame, _, used) = Self::decode_prefix_ext(buf)?;
        Ok((frame, used))
    }

    /// Decode one frame from the front of `buf` with its transmission
    /// metadata (version + trailer sequence) and the bytes consumed.
    pub fn decode_prefix_ext(buf: &[u8]) -> Result<(Frame, FrameMeta, usize), WireError> {
        if buf.len() < HEADER_LEN {
            return Err(WireError::Truncated {
                need: HEADER_LEN,
                got: buf.len(),
            });
        }
        let h = Header::parse(&buf[..HEADER_LEN])?;
        let trailer = if h.version >= VERSION { TRAILER_LEN } else { 0 };
        let body_end = HEADER_LEN + h.payload_len as usize;
        let total = body_end + trailer;
        if buf.len() < total {
            return Err(WireError::Truncated {
                need: total,
                got: buf.len(),
            });
        }
        let mut seq = 0u32;
        if h.version >= VERSION {
            let t = &buf[body_end..total];
            seq = u32::from_le_bytes([t[0], t[1], t[2], t[3]]);
            let got = u32::from_le_bytes([t[4], t[5], t[6], t[7]]);
            let expected = crc32(&[&buf[..body_end], &t[..4]]);
            if expected != got {
                return Err(WireError::Checksum { expected, got });
            }
        }
        let payload = buf[HEADER_LEN..body_end].to_vec();
        Ok((
            Frame {
                kind: h.kind,
                flags: h.flags,
                origin: h.origin,
                ttl: h.ttl,
                epoch: h.epoch,
                payload,
            },
            FrameMeta {
                version: h.version,
                seq,
            },
            total,
        ))
    }

    /// Write the frame to a stream at version 1 (single buffered write).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<(), WireError> {
        w.write_all(&self.encode())?;
        Ok(())
    }

    /// Write the frame to a stream at an explicit wire version.
    pub fn write_to_at<W: Write>(&self, w: &mut W, version: u16, seq: u32) -> Result<(), WireError> {
        w.write_all(&self.encode_at(version, seq))?;
        Ok(())
    }

    /// Read one frame off a stream (either wire version). A clean EOF
    /// before any header byte maps to [`WireError::Io`] with
    /// `UnexpectedEof`; a partial header, payload or trailer does too
    /// (the socket layer cannot distinguish a truncated frame from a
    /// dropped connection).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Frame, WireError> {
        Self::read_from_ext(r).map(|(f, _)| f)
    }

    /// Read one frame off a stream together with its transmission
    /// metadata — the ARQ layer keys duplicate suppression and
    /// acknowledgments off `meta.seq`.
    pub fn read_from_ext<R: Read>(r: &mut R) -> Result<(Frame, FrameMeta), WireError> {
        let mut header = [0u8; HEADER_LEN];
        r.read_exact(&mut header)?;
        Self::read_body_ext(r, &header)
    }

    /// Finish reading a frame whose 20-byte header has already been
    /// consumed — the receive path uses a 1-byte probe to tell an idle
    /// edge from a mid-frame stall, then hands the header here.
    pub fn read_body_ext<R: Read>(
        r: &mut R,
        header: &[u8; HEADER_LEN],
    ) -> Result<(Frame, FrameMeta), WireError> {
        let h = Header::parse(header)?;
        let mut payload = vec![0u8; h.payload_len as usize];
        r.read_exact(&mut payload)?;
        let mut seq = 0u32;
        if h.version >= VERSION {
            let mut t = [0u8; TRAILER_LEN];
            r.read_exact(&mut t)?;
            seq = u32::from_le_bytes([t[0], t[1], t[2], t[3]]);
            let got = u32::from_le_bytes([t[4], t[5], t[6], t[7]]);
            let expected = crc32(&[header, &payload, &t[..4]]);
            if expected != got {
                return Err(WireError::Checksum { expected, got });
            }
        }
        Ok((
            Frame {
                kind: h.kind,
                flags: h.flags,
                origin: h.origin,
                ttl: h.ttl,
                epoch: h.epoch,
                payload,
            },
            FrameMeta {
                version: h.version,
                seq,
            },
        ))
    }
}

/// Validated header fields.
struct Header {
    version: u16,
    kind: Kind,
    flags: u8,
    origin: u16,
    ttl: u16,
    epoch: u32,
    payload_len: u32,
}

impl Header {
    /// Validate and split a 20-byte header.
    fn parse(h: &[u8]) -> Result<Header, WireError> {
        debug_assert_eq!(h.len(), HEADER_LEN);
        if h[0..4] != MAGIC {
            return Err(WireError::BadMagic);
        }
        let version = u16::from_le_bytes([h[4], h[5]]);
        if version != V1 && version != VERSION {
            return Err(WireError::Version {
                got: version,
                want: VERSION,
            });
        }
        let kind = Kind::from_u8(h[6])?;
        let flags = h[7];
        let origin = u16::from_le_bytes([h[8], h[9]]);
        let ttl = u16::from_le_bytes([h[10], h[11]]);
        let epoch = u32::from_le_bytes([h[12], h[13], h[14], h[15]]);
        let payload_len = u32::from_le_bytes([h[16], h[17], h[18], h[19]]);
        if payload_len > MAX_PAYLOAD {
            return Err(WireError::Corrupt(format!(
                "payload_len {payload_len} exceeds cap {MAX_PAYLOAD}"
            )));
        }
        Ok(Header {
            version,
            kind,
            flags,
            origin,
            ttl,
            epoch,
            payload_len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        Frame {
            kind: Kind::Masked,
            flags: FLAG_TERN_BLOB,
            origin: 3,
            ttl: 8,
            epoch: 42,
            payload: vec![1, 2, 3, 4, 5],
        }
    }

    #[test]
    fn roundtrip_buffer() {
        let f = sample();
        let bytes = f.encode();
        assert_eq!(bytes.len(), f.encoded_len());
        assert_eq!(Frame::decode(&bytes).unwrap(), f);
    }

    #[test]
    fn roundtrip_stream() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(Frame::read_from(&mut cursor).unwrap(), f);
    }

    #[test]
    fn v2_roundtrips_with_trailer_and_seq() {
        let f = sample();
        let bytes = f.encode_at(VERSION, 77);
        assert_eq!(bytes.len(), f.encoded_len_at(VERSION));
        assert_eq!(bytes.len(), f.encoded_len() + TRAILER_LEN);
        let (d, meta, used) = Frame::decode_prefix_ext(&bytes).unwrap();
        assert_eq!(d, f);
        assert_eq!(meta, FrameMeta { version: VERSION, seq: 77 });
        assert_eq!(used, bytes.len());
        let mut cursor = std::io::Cursor::new(bytes);
        let (d, meta) = Frame::read_from_ext(&mut cursor).unwrap();
        assert_eq!(d, f);
        assert_eq!(meta.seq, 77);
    }

    #[test]
    fn v1_frames_still_decode_under_the_v2_build() {
        // Version negotiation's load-bearing half: a v1 peer's bytes
        // (no trailer) parse on the same decoders a v2 edge uses.
        let f = sample();
        let (d, meta, used) = Frame::decode_prefix_ext(&f.encode_at(V1, 99)).unwrap();
        assert_eq!(d, f);
        assert_eq!(meta, FrameMeta { version: V1, seq: 0 });
        assert_eq!(used, f.encoded_len());
    }

    #[test]
    fn v2_corruption_is_typed_checksum() {
        let f = sample();
        let mut bytes = f.encode_at(VERSION, 5);
        let i = HEADER_LEN + 2; // payload byte
        bytes[i] ^= 0x10;
        assert!(matches!(
            Frame::decode(&bytes),
            Err(WireError::Checksum { .. })
        ));
        // Seq corruption is covered too — the CRC spans the seq field.
        let mut bytes = f.encode_at(VERSION, 5);
        let i = bytes.len() - TRAILER_LEN;
        bytes[i] ^= 1;
        assert!(matches!(
            Frame::decode(&bytes),
            Err(WireError::Checksum { .. })
        ));
    }

    #[test]
    fn v2_trailer_truncation_is_typed() {
        let bytes = sample().encode_at(VERSION, 1);
        for cut in [bytes.len() - TRAILER_LEN, bytes.len() - 1] {
            assert!(
                matches!(Frame::decode(&bytes[..cut]), Err(WireError::Truncated { .. })),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // The classic reference vector: CRC-32("123456789") = 0xCBF43926.
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        // Incremental chunking is associative.
        assert_eq!(crc32(&[b"1234", b"56789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[b""]), 0);
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadMagic)));
    }

    #[test]
    fn version_bump_is_typed() {
        let mut bytes = sample().encode();
        bytes[4] = (VERSION + 1) as u8;
        match Frame::decode(&bytes) {
            Err(WireError::Version { got, want }) => {
                assert_eq!(got, VERSION + 1);
                assert_eq!(want, VERSION);
            }
            other => panic!("expected Version error, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed() {
        let bytes = sample().encode();
        for cut in [0, 3, HEADER_LEN - 1, HEADER_LEN, bytes.len() - 1] {
            assert!(
                matches!(Frame::decode(&bytes[..cut]), Err(WireError::Truncated { .. })),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn bad_kind_and_trailing_bytes_are_typed() {
        let mut bytes = sample().encode();
        bytes[6] = 99;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadKind(99))));
        let mut bytes = sample().encode();
        bytes.push(0);
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn ack_and_nack_kinds_roundtrip() {
        for kind in [Kind::Ack, Kind::Nack] {
            let f = Frame::new(kind, 2, 0, 9, Vec::new());
            let bytes = f.encode_at(VERSION, 31);
            let (d, meta, _) = Frame::decode_prefix_ext(&bytes).unwrap();
            assert_eq!(d, f);
            assert_eq!(meta.seq, 31);
        }
        assert_eq!(Kind::from_u8(8).unwrap(), Kind::Ack);
        assert_eq!(Kind::from_u8(9).unwrap(), Kind::Nack);
        assert_eq!(Kind::from_u8(10).unwrap(), Kind::Quant);
        assert!(Kind::from_u8(11).is_err());
    }

    #[test]
    fn empty_payload_roundtrips() {
        let f = Frame::new(Kind::Shutdown, 0, 0, 7, Vec::new());
        assert_eq!(Frame::decode(&f.encode()).unwrap(), f);
    }

    #[test]
    fn decode_prefix_consumes_exactly_one_frame() {
        let a = sample();
        let b = Frame::new(Kind::Dense, 1, 2, 3, vec![9]);
        let mut bytes = a.encode();
        bytes.extend_from_slice(&b.encode_at(VERSION, 4));
        let (fa, used) = Frame::decode_prefix(&bytes).unwrap();
        assert_eq!(fa, a);
        let (fb, used2) = Frame::decode_prefix(&bytes[used..]).unwrap();
        assert_eq!(fb, b);
        assert_eq!(used + used2, bytes.len());
    }
}
