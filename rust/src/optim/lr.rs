//! Learning-rate schedules: linear warm-up + step decay (the standard
//! large-batch ImageNet recipe the paper trains under).

/// Piecewise schedule: linear warm-up over `warmup_steps`, then decay by
/// `gamma` at each milestone (in steps).
#[derive(Debug, Clone)]
pub struct LrSchedule {
    /// Post-warm-up base rate.
    pub base_lr: f32,
    /// Linear warm-up length in steps (0 disables).
    pub warmup_steps: usize,
    /// Steps at which the rate decays by `gamma`.
    pub milestones: Vec<usize>,
    /// Multiplicative decay at each milestone.
    pub gamma: f32,
}

impl LrSchedule {
    /// Flat schedule at `lr`.
    pub fn constant(lr: f32) -> Self {
        LrSchedule {
            base_lr: lr,
            warmup_steps: 0,
            milestones: Vec::new(),
            gamma: 1.0,
        }
    }

    /// Linear warm-up to `lr`, flat afterwards.
    pub fn with_warmup(lr: f32, warmup_steps: usize) -> Self {
        LrSchedule {
            base_lr: lr,
            warmup_steps,
            milestones: Vec::new(),
            gamma: 1.0,
        }
    }

    /// The learning rate at `step`.
    pub fn at(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        let decays = self.milestones.iter().filter(|&&m| step >= m).count();
        self.base_lr * self.gamma.powi(decays as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::with_warmup(1.0, 10);
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(9), 1.0);
        assert_eq!(s.at(10), 1.0);
    }

    #[test]
    fn milestones_decay() {
        let s = LrSchedule {
            base_lr: 1.0,
            warmup_steps: 0,
            milestones: vec![100, 200],
            gamma: 0.1,
        };
        assert_eq!(s.at(50), 1.0);
        assert!((s.at(150) - 0.1).abs() < 1e-7);
        assert!((s.at(250) - 0.01).abs() < 1e-8);
    }
}
