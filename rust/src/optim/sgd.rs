//! Momentum SGD — Eq. 1:  g_t = m·g_{t-1} + Σ_k ∇_{k,t};  ω_{t+1} = ω_t − η·g_t.
//!
//! In the compressed paths the *momentum lives in the per-node residual
//! store* (momentum correction, Eq. 3), so the global optimizer is then
//! run with momentum = 0 to avoid double-applying it. The baseline dense
//! path uses this optimizer's momentum directly.

/// Momentum SGD over a flat parameter buffer.
#[derive(Debug, Clone)]
pub struct MomentumSgd {
    momentum: f32,
    velocity: Vec<f32>,
}

impl MomentumSgd {
    /// Zero-velocity optimizer over `len` parameters.
    pub fn new(len: usize, momentum: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum));
        MomentumSgd {
            momentum,
            velocity: vec![0.0; len],
        }
    }

    /// The configured momentum m.
    pub fn momentum(&self) -> f32 {
        self.momentum
    }

    /// Dense update: params -= lr * (m·v + g).
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert!(params.len() == grad.len() && params.len() == self.velocity.len());
        if self.momentum == 0.0 {
            for i in 0..params.len() {
                params[i] -= lr * grad[i];
            }
        } else {
            for i in 0..params.len() {
                self.velocity[i] = self.momentum * self.velocity[i] + grad[i];
                params[i] -= lr * self.velocity[i];
            }
        }
    }

    /// Sparse update on a known support (Alg. 1 line 13 after a masked
    /// reduce): `indices[j]` gets `values[j]`. Momentum is intentionally
    /// NOT applied here — compressed paths carry it in the residual store.
    pub fn step_sparse(&mut self, params: &mut [f32], indices: &[usize], values: &[f32], lr: f32) {
        assert_eq!(indices.len(), values.len());
        for (&i, &v) in indices.iter().zip(values) {
            params[i] -= lr * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_descends() {
        let mut opt = MomentumSgd::new(2, 0.0);
        let mut p = vec![1.0f32, -1.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, -0.95]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = MomentumSgd::new(1, 0.9);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        opt.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn sparse_update_touches_support_only() {
        let mut opt = MomentumSgd::new(4, 0.9);
        let mut p = vec![1.0f32; 4];
        opt.step_sparse(&mut p, &[1, 3], &[10.0, 20.0], 0.1);
        assert_eq!(p, vec![1.0, 0.0, 1.0, -1.0]);
    }

    #[test]
    fn momentum_matches_eq1_closed_form() {
        // After T steps of constant gradient 1: p = -lr * sum_{t=1..T} sum_{tau=0}^{t-1} m^tau
        let m: f32 = 0.5;
        let lr = 0.1;
        let mut opt = MomentumSgd::new(1, m);
        let mut p = vec![0.0f32];
        let t_steps = 5;
        for _ in 0..t_steps {
            opt.step(&mut p, &[1.0], lr);
        }
        let mut expect = 0.0f32;
        let mut v = 0.0f32;
        for _ in 0..t_steps {
            v = m * v + 1.0;
            expect -= lr * v;
        }
        assert!((p[0] - expect).abs() < 1e-6);
    }
}
