//! Momentum SGD — Eq. 1:  g_t = m·g_{t-1} + Σ_k ∇_{k,t};  ω_{t+1} = ω_t − η·g_t.
//!
//! In the compressed paths the *momentum lives in the per-node residual
//! store* (momentum correction, Eq. 3), so the global optimizer is then
//! run with momentum = 0 to avoid double-applying it. The baseline dense
//! path uses this optimizer's momentum directly.

/// Momentum SGD over a flat parameter buffer.
#[derive(Debug, Clone)]
pub struct MomentumSgd {
    momentum: f32,
    velocity: Vec<f32>,
}

impl MomentumSgd {
    /// Zero-velocity optimizer over `len` parameters.
    pub fn new(len: usize, momentum: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum));
        MomentumSgd {
            momentum,
            velocity: vec![0.0; len],
        }
    }

    /// The configured momentum m.
    pub fn momentum(&self) -> f32 {
        self.momentum
    }

    /// Dense update: params -= lr * (m·v + g).
    pub fn step(&mut self, params: &mut [f32], grad: &[f32], lr: f32) {
        assert!(params.len() == grad.len() && params.len() == self.velocity.len());
        if self.momentum == 0.0 {
            for i in 0..params.len() {
                params[i] -= lr * grad[i];
            }
        } else {
            for i in 0..params.len() {
                self.velocity[i] = self.momentum * self.velocity[i] + grad[i];
                params[i] -= lr * self.velocity[i];
            }
        }
    }

    /// [`MomentumSgd::step`] over an unaveraged reduce **sum**: the mean
    /// `sum[i] / nodes` is formed inline instead of in a caller-allocated
    /// average buffer — bit-identical to `step` on the materialized
    /// average, one dense pass and zero allocations (DESIGN.md §11).
    pub fn step_mean(&mut self, params: &mut [f32], sum: &[f32], nodes: f32, lr: f32) {
        assert!(params.len() == sum.len() && params.len() == self.velocity.len());
        if self.momentum == 0.0 {
            for i in 0..params.len() {
                params[i] -= lr * (sum[i] / nodes);
            }
        } else {
            for i in 0..params.len() {
                self.velocity[i] = self.momentum * self.velocity[i] + sum[i] / nodes;
                params[i] -= lr * self.velocity[i];
            }
        }
    }

    /// Sparse update on a known support (Alg. 1 line 13 after a masked
    /// reduce): `indices[j]` gets `values[j]`. Momentum is intentionally
    /// NOT applied here — compressed paths carry it in the residual store.
    pub fn step_sparse(&mut self, params: &mut [f32], indices: &[usize], values: &[f32], lr: f32) {
        assert_eq!(indices.len(), values.len());
        for (&i, &v) in indices.iter().zip(values) {
            params[i] -= lr * v;
        }
    }

    /// [`MomentumSgd::step_sparse`] driven by a mask's set-bit iterator
    /// with the post-reduce `1/N` scaling fused in — the trainer's IWP
    /// update without materializing the support index table or a scaled
    /// value buffer (DESIGN.md §11). `values[j]` pairs with the j-th set
    /// bit of `mask`; bit-identical to scaling into a scratch buffer and
    /// calling `step_sparse` on the collected support.
    pub fn step_sparse_mask(
        &mut self,
        params: &mut [f32],
        mask: &crate::sparse::BitMask,
        values: &[f32],
        scale: f32,
        lr: f32,
    ) {
        debug_assert_eq!(mask.count(), values.len());
        for (j, i) in mask.iter_set().enumerate() {
            params[i] -= lr * (values[j] * scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_descends() {
        let mut opt = MomentumSgd::new(2, 0.0);
        let mut p = vec![1.0f32, -1.0];
        opt.step(&mut p, &[0.5, -0.5], 0.1);
        assert_eq!(p, vec![0.95, -0.95]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = MomentumSgd::new(1, 0.9);
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0], 1.0); // v=1, p=-1
        opt.step(&mut p, &[1.0], 1.0); // v=1.9, p=-2.9
        assert!((p[0] + 2.9).abs() < 1e-6);
    }

    #[test]
    fn sparse_update_touches_support_only() {
        let mut opt = MomentumSgd::new(4, 0.9);
        let mut p = vec![1.0f32; 4];
        opt.step_sparse(&mut p, &[1, 3], &[10.0, 20.0], 0.1);
        assert_eq!(p, vec![1.0, 0.0, 1.0, -1.0]);
    }

    #[test]
    fn step_mean_is_bit_identical_to_materialized_average() {
        for momentum in [0.0f32, 0.9] {
            let sum = vec![3.0f32, -1.5, 0.25, 7.0];
            let n = 4.0f32;
            let mut a = MomentumSgd::new(4, momentum);
            let mut b = MomentumSgd::new(4, momentum);
            let mut pa = vec![1.0f32; 4];
            let mut pb = vec![1.0f32; 4];
            for _ in 0..3 {
                let avg: Vec<f32> = sum.iter().map(|&g| g / n).collect();
                a.step(&mut pa, &avg, 0.1);
                b.step_mean(&mut pb, &sum, n, 0.1);
            }
            let bits = |p: &[f32]| -> Vec<u32> { p.iter().map(|v| v.to_bits()).collect() };
            assert_eq!(bits(&pa), bits(&pb), "momentum={momentum}");
        }
    }

    #[test]
    fn step_sparse_mask_is_bit_identical_to_scaled_support() {
        use crate::sparse::BitMask;
        let len = 10;
        let mut mask = BitMask::zeros(len);
        mask.set(1);
        mask.set(4);
        mask.set(9);
        let summed = vec![3.0f32, -6.0, 0.5];
        let scale = 1.0 / 3.0f32;
        let mut a = MomentumSgd::new(len, 0.9);
        let mut b = MomentumSgd::new(len, 0.9);
        let mut pa = vec![1.0f32; len];
        let mut pb = vec![1.0f32; len];
        let support: Vec<usize> = mask.iter_set().collect();
        let scaled: Vec<f32> = summed.iter().map(|&v| v * scale).collect();
        a.step_sparse(&mut pa, &support, &scaled, 0.05);
        b.step_sparse_mask(&mut pb, &mask, &summed, scale, 0.05);
        let bits = |p: &[f32]| -> Vec<u32> { p.iter().map(|v| v.to_bits()).collect() };
        assert_eq!(bits(&pa), bits(&pb));
    }

    #[test]
    fn momentum_matches_eq1_closed_form() {
        // After T steps of constant gradient 1: p = -lr * sum_{t=1..T} sum_{tau=0}^{t-1} m^tau
        let m: f32 = 0.5;
        let lr = 0.1;
        let mut opt = MomentumSgd::new(1, m);
        let mut p = vec![0.0f32];
        let t_steps = 5;
        for _ in 0..t_steps {
            opt.step(&mut p, &[1.0], lr);
        }
        let mut expect = 0.0f32;
        let mut v = 0.0f32;
        for _ in 0..t_steps {
            v = m * v + 1.0;
            expect -= lr * v;
        }
        assert!((p[0] - expect).abs() < 1e-6);
    }
}
