//! Optimizers + learning-rate schedules (Eq. 1's distributed momentum SGD).

pub mod lr;
pub mod sgd;

pub use lr::LrSchedule;
pub use sgd::MomentumSgd;
