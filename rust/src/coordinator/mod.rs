//! The distributed trainer — the L3 composition of everything:
//!
//! ```text
//! per step, per node:  PJRT train_step (L2 HLO, contains the L1 kernel
//!                      lineage) -> local gradient
//! per step, globally:  clip -> residual accumulate -> importance mask
//!                      (L1 kernel via PJRT) -> ring all-reduce over the
//!                      virtual network -> SGD update
//! ```
//!
//! Replicas stay bit-identical across nodes (synchronous SGD), so the
//! trainer keeps ONE parameter copy and per-node gradient/residual
//! state — the transport still moves per-node data and accounts every
//! wire byte.  Determinism note: node threads would buy nothing on this
//! 1-core testbed and would cost reproducibility; the ring transport is
//! the unit under test, not the OS scheduler (DESIGN.md §2).

pub mod trainer;

pub use trainer::{TrainOutcome, Trainer};
