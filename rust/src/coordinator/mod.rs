//! The distributed trainer — the L3 composition of everything:
//!
//! ```text
//! per step, per node:  PJRT train_step (L2 HLO, contains the L1 kernel
//!                      lineage) -> local gradient
//! per step, globally:  clip -> residual accumulate -> importance mask
//!                      (L1 kernel via PJRT) -> ring all-reduce over the
//!                      virtual network -> SGD update
//! ```
//!
//! Replicas stay bit-identical across nodes (synchronous SGD), so the
//! trainer keeps ONE parameter copy and per-node gradient/residual
//! state — the transport still moves per-node data and accounts every
//! wire byte.  Determinism note: per-node work (clipping, residual
//! accumulation, encode/decode, the ring reduce itself) fans out over
//! the node-parallel executor (`ring::exec`, `--parallelism W`), which
//! is constructed so results stay bit-identical to the sequential
//! oracle — the OS scheduler never becomes part of the unit under test
//! (DESIGN.md §4). Only the PJRT local steps stay serialized behind the
//! single artifact handle (DESIGN.md §2).

pub mod trainer;

pub use trainer::{TrainOutcome, Trainer};
