//! End-to-end N-node trainer over the simulated ring.
//!
//! Since the compressor subsystem (DESIGN.md §12) the trainer owns the
//! task/data side (PJRT forward/backward, eval, clipping, optimizer,
//! net, topology) and reduces every step through the configured
//! [`Compressor`] pipeline — no per-method match arms remain here; the
//! legacy `Method` values run bit-identically through their canonical
//! specs (`rust/tests/compressor_equivalence.rs`).

use crate::compress::pipeline::{self, StageCfg, TrainCtx};
use crate::compress::{clip, Compressor};
use crate::config::Config;
use crate::data::{CharCorpus, SynthClassification};
use crate::metrics::CompressionAccount;
use crate::model::ParamLayout;
use crate::net::{RingNet, Topology, Tuner, TunerMode};
use crate::optim::{LrSchedule, MomentumSgd};
use crate::ring::{Arena, Executor};
use crate::runtime::{Artifact, ImportanceKernel, Runtime};
use crate::util::rng::Rng;

/// What a training run produces (feeds Table I, Figs. 5–8, E2E log).
#[derive(Debug, Clone, Default)]
pub struct TrainOutcome {
    /// (step, mean train loss across nodes).
    pub losses: Vec<(usize, f64)>,
    /// (step, eval loss, eval accuracy) — accuracy 0 for LM tasks.
    pub evals: Vec<(usize, f64, f64)>,
    /// Compression accounting over the whole run.
    pub account: CompressionAccount,
    /// Virtual seconds spent on the wire.
    pub net_seconds: f64,
    /// Node-0 I/O trace (KB/s series) for Fig. 7/8-style plots.
    pub io_trace: Vec<(f64, f64)>,
    /// Peak node-0 transmit rate over the run (KB/s).
    pub peak_kbps: f64,
    /// Eval loss after the final step.
    pub final_eval_loss: f64,
    /// Eval accuracy after the final step (0 for LM tasks).
    pub final_eval_acc: f64,
}

/// The data-side of a task.
enum Task {
    Mlp {
        data: SynthClassification,
        eval_x: Vec<f32>,
        eval_y: Vec<f32>,
    },
    Lm {
        corpus: CharCorpus,
        seq_len: usize,
        eval_tokens: Vec<f32>,
    },
}

/// N-node synchronous trainer.
pub struct Trainer {
    cfg: Config,
    art: Artifact,
    layout: ParamLayout,
    kernel: Option<ImportanceKernel>,
    task: Task,
    /// Flat parameter buffer (replicas are identical; see mod docs).
    params: Vec<f32>,
    opt: MomentumSgd,
    lr: LrSchedule,
    net: RingNet,
    /// Per-node data RNG streams + one control stream.
    node_rngs: Vec<Rng>,
    ctl_rng: Rng,
    /// Scratch: per-node gradient buffers.
    grads: Vec<Vec<f32>>,
    account_scratch: CompressionAccount,
    /// Node-parallel executor for the reduce paths (`cfg.parallelism`).
    exec: Executor,
    /// Communication topology of the reduce (`--topology`,
    /// DESIGN.md §10).
    topo: Box<dyn Topology>,
    /// Staging arena for the reduce hot paths (DESIGN.md §9).
    arena: Arena,
    /// The configured compression pipeline — owns every method-specific
    /// piece of per-node state (DESIGN.md §12).
    comp: Box<dyn Compressor>,
    /// Online autotuner (`--tuner`, DESIGN.md §14); `None` when off.
    tuner: Option<Tuner>,
}

impl Trainer {
    /// Build a trainer from config; loads artifacts via the runtime.
    pub fn new(cfg: Config, rt: &Runtime) -> anyhow::Result<Self> {
        let (art_name, task) = match cfg.model.as_str() {
            "mlp" => {
                let data = SynthClassification::cifar_like(cfg.seed);
                let (eval_x, eval_y) = data.eval_set(128, cfg.seed);
                (
                    "train_step_mlp_b32",
                    Task::Mlp {
                        data,
                        eval_x,
                        eval_y,
                    },
                )
            }
            "tfm_tiny" => {
                let corpus = CharCorpus::tiny();
                let mut erng = Rng::new(cfg.seed ^ 0xE7A1);
                let eval_tokens = corpus.batch(&mut erng, 8, 64);
                (
                    "train_step_tfm_tiny_b8",
                    Task::Lm {
                        corpus,
                        seq_len: 64,
                        eval_tokens,
                    },
                )
            }
            other => anyhow::bail!("trainer model `{other}` (mlp|tfm_tiny)"),
        };
        let art = rt.load(art_name)?;
        let layout = art.meta.layout()?;
        let spec = cfg.method;
        let kernel = if spec.needs_kernel() {
            Some(ImportanceKernel::load(rt)?)
        } else {
            None
        };
        let total = layout.total_params();

        let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
        let params = init_params(&layout, &mut init_rng);

        let mut root = Rng::new(cfg.seed);
        let node_rngs: Vec<Rng> = (0..cfg.nodes).map(|i| root.split(i as u64)).collect();
        let ctl_rng = root.split(0xC011);

        // Compressed paths carry momentum in the residual store (momentum
        // correction); the global optimizer momentum is for dense paths.
        let opt_momentum = if spec.optimizer_momentum() {
            cfg.momentum
        } else {
            0.0
        };
        let comp = pipeline::build(
            spec,
            &StageCfg {
                nodes: cfg.nodes,
                state_nodes: cfg.nodes,
                threshold: cfg.threshold,
                beta: cfg.beta,
                c: cfg.c,
                mask_nodes: cfg.mask_nodes,
                random_select: cfg.random_select,
                momentum: cfg.momentum,
                dgc_density: cfg.dgc_density,
                warmup_epochs: cfg.warmup_epochs,
            },
            &layout,
        );

        Ok(Trainer {
            exec: Executor::new(cfg.parallelism),
            topo: cfg.topology.build(cfg.nodes),
            arena: Arena::for_nodes(cfg.nodes),
            net: RingNet::new(cfg.nodes, cfg.link_spec(), 0.05),
            opt: MomentumSgd::new(total, opt_momentum),
            lr: LrSchedule::with_warmup(cfg.lr, cfg.steps_per_epoch / 2),
            grads: vec![vec![0.0; total]; cfg.nodes],
            account_scratch: CompressionAccount::new(),
            node_rngs,
            ctl_rng,
            comp,
            tuner: (cfg.tuner != TunerMode::Off)
                .then(|| Tuner::new(cfg.tuner, cfg.nodes, cfg.link_spec())),
            task,
            params,
            layout,
            kernel,
            art,
            cfg,
        })
    }

    /// The model layout under training.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// The online autotuner — `None` when `--tuner off` (DESIGN.md §14).
    pub fn tuner(&self) -> Option<&Tuner> {
        self.tuner.as_ref()
    }

    /// Dense per-node wire reference: 2(N-1)/N of the gradient bytes —
    /// the denominator-side of the paper's compression ratio on a ring.
    fn dense_ref_bytes(&self) -> u64 {
        let n = self.cfg.nodes as u64;
        2 * (n - 1) * self.layout.dense_bytes() / n
    }

    /// One local forward/backward on `node` — PJRT executes the L2 HLO.
    /// Returns the train loss; fills `self.grads[node]`.
    fn local_step(&mut self, node: usize) -> anyhow::Result<f64> {
        let (loss, outs) = match &self.task {
            Task::Mlp { data, .. } => {
                let (x, y) = data.batch(&mut self.node_rngs[node], 32);
                let mut inputs: Vec<&[f32]> = Vec::with_capacity(self.layout.n_layers() + 2);
                let splits = self.layout.split(&self.params);
                inputs.extend(splits);
                inputs.push(&x);
                inputs.push(&y);
                let out = self.art.run_f32(&inputs)?;
                (out[0][0] as f64, out[2..].to_vec())
            }
            Task::Lm {
                corpus, seq_len, ..
            } => {
                let tokens = corpus.batch(&mut self.node_rngs[node], 8, *seq_len);
                let mut inputs: Vec<&[f32]> = Vec::with_capacity(self.layout.n_layers() + 1);
                let splits = self.layout.split(&self.params);
                inputs.extend(splits);
                inputs.push(&tokens);
                let out = self.art.run_f32(&inputs)?;
                (out[0][0] as f64, out[1..].to_vec())
            }
        };
        // Flatten per-layer grads into the node's flat buffer.
        let flat = &mut self.grads[node];
        for (layer, g) in self.layout.layers().iter().zip(&outs) {
            flat[layer.range()].copy_from_slice(g);
        }
        Ok(loss)
    }

    /// Evaluate on the held-out set (no update).
    fn eval(&mut self) -> anyhow::Result<(f64, f64)> {
        match &self.task {
            Task::Mlp { eval_x, eval_y, .. } => {
                let mut loss_sum = 0.0;
                let mut acc_sum = 0.0;
                let n_batches = eval_x.len() / (32 * 3072);
                for b in 0..n_batches {
                    let x = &eval_x[b * 32 * 3072..(b + 1) * 32 * 3072];
                    let y = &eval_y[b * 32..(b + 1) * 32];
                    let mut inputs: Vec<&[f32]> = Vec::new();
                    inputs.extend(self.layout.split(&self.params));
                    inputs.push(x);
                    inputs.push(y);
                    let out = self.art.run_f32(&inputs)?;
                    loss_sum += out[0][0] as f64;
                    acc_sum += out[1][0] as f64;
                }
                Ok((loss_sum / n_batches as f64, acc_sum / n_batches as f64))
            }
            Task::Lm { eval_tokens, .. } => {
                let mut inputs: Vec<&[f32]> = Vec::new();
                inputs.extend(self.layout.split(&self.params));
                inputs.push(eval_tokens);
                let out = self.art.run_f32(&inputs)?;
                Ok((out[0][0] as f64, 0.0))
            }
        }
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> anyhow::Result<TrainOutcome> {
        let mut out = TrainOutcome::default();
        let eval_every = (self.cfg.steps / 20).max(5);
        for step in 0..self.cfg.steps {
            let loss = self.step(step)?;
            out.losses.push((step, loss));
            if step % eval_every == 0 || step + 1 == self.cfg.steps {
                let (el, ea) = self.eval()?;
                out.evals.push((step, el, ea));
            }
        }
        let (el, ea) = self.eval()?;
        out.final_eval_loss = el;
        out.final_eval_acc = ea;
        out.net_seconds = self.net.clock();
        out.io_trace = self.net.trace().kbps_series(0);
        out.peak_kbps = self.net.trace().peak_kbps(0);
        out.account = std::mem::take(&mut self.account_scratch);
        Ok(out)
    }

    /// One synchronous step across all nodes. Returns the mean train loss.
    pub fn step(&mut self, step: usize) -> anyhow::Result<f64> {
        let n = self.cfg.nodes;
        let epoch = self.cfg.epoch_of(step);
        let lr = self.lr.at(step);

        // ---- local gradients (PJRT per node) -------------------------
        let mut loss_sum = 0.0;
        for node in 0..n {
            loss_sum += self.local_step(node)?;
        }

        // ---- local gradient clipping ---------------------------------
        if self.cfg.clip_norm > 0.0 {
            let per_node = clip::per_node_max_norm(self.cfg.clip_norm, n);
            self.exec.map_mut(&mut self.grads, |_, g| {
                clip::clip_by_global_norm(g, per_node);
            });
        }

        // ---- reduce + update through the configured pipeline ---------
        let out = {
            let mut ctx = TrainCtx {
                epoch,
                lr,
                nodes: n,
                layout: &self.layout,
                params: &mut self.params,
                grads: &mut self.grads,
                net: &mut self.net,
                topo: &*self.topo,
                exec: &self.exec,
                arena: &mut self.arena,
                node_rngs: &mut self.node_rngs,
                ctl_rng: &mut self.ctl_rng,
                opt: &mut self.opt,
                kernel: self.kernel.as_mut(),
                tuner: self.tuner.as_mut(),
            };
            self.comp.train_reduce(&mut ctx)?
        };
        self.account_scratch.record_full(
            self.dense_ref_bytes(),
            out.wire_bytes_per_node,
            self.layout.dense_bytes(),
            out.payload_bytes,
            out.density,
        );

        // Small compute-phase gap so I/O traces show the paper's idle
        // valleys between bursts (virtual time, trace realism only).
        self.net.advance(0.01);

        Ok(loss_sum / n as f64)
    }
}

/// Kind-aware parameter init over a flat buffer (mirrors the python
/// init; numerics need not match bit-for-bit, only distribution).
pub fn init_params(layout: &ParamLayout, rng: &mut Rng) -> Vec<f32> {
    let mut params = vec![0.0f32; layout.total_params()];
    for layer in layout.layers() {
        let p = &mut params[layer.range()];
        match layer.kind {
            crate::model::LayerKind::Norm => p.fill(1.0),
            crate::model::LayerKind::Bias => {}
            crate::model::LayerKind::BatchNorm => p.fill(1.0),
            crate::model::LayerKind::Fc | crate::model::LayerKind::Conv => {
                let sigma = (2.0 / layer.fan_in() as f32).sqrt();
                rng.fill_normal(p, 0.0, sigma);
            }
            _ => {
                let sigma = 1.0 / (layer.fan_in() as f32).sqrt();
                rng.fill_normal(p, 0.0, sigma);
            }
        }
    }
    params
}
