//! End-to-end N-node trainer over the simulated ring.

use crate::compress::importance::LayerStats;
use crate::compress::residual::ResidualStore;
use crate::compress::threshold::{ThresholdCfg, ThresholdPolicy};
use crate::compress::{clip, dgc::Dgc, select, terngrad::TernGrad, warmup::Warmup, Method};
use crate::config::Config;
use crate::data::{CharCorpus, SynthClassification};
use crate::metrics::CompressionAccount;
use crate::model::ParamLayout;
use crate::net::{RingNet, Topology};
use crate::optim::{LrSchedule, MomentumSgd};
use crate::ring::{Arena, Executor};
use crate::runtime::{Artifact, ImportanceKernel, Runtime};
use crate::sparse::BitMask;
use crate::util::rng::Rng;

/// What a training run produces (feeds Table I, Figs. 5–8, E2E log).
#[derive(Debug, Clone, Default)]
pub struct TrainOutcome {
    /// (step, mean train loss across nodes).
    pub losses: Vec<(usize, f64)>,
    /// (step, eval loss, eval accuracy) — accuracy 0 for LM tasks.
    pub evals: Vec<(usize, f64, f64)>,
    /// Compression accounting over the whole run.
    pub account: CompressionAccount,
    /// Virtual seconds spent on the wire.
    pub net_seconds: f64,
    /// Node-0 I/O trace (KB/s series) for Fig. 7/8-style plots.
    pub io_trace: Vec<(f64, f64)>,
    /// Peak node-0 transmit rate over the run (KB/s).
    pub peak_kbps: f64,
    /// Eval loss after the final step.
    pub final_eval_loss: f64,
    /// Eval accuracy after the final step (0 for LM tasks).
    pub final_eval_acc: f64,
}

/// The data-side of a task.
enum Task {
    Mlp {
        data: SynthClassification,
        eval_x: Vec<f32>,
        eval_y: Vec<f32>,
    },
    Lm {
        corpus: CharCorpus,
        seq_len: usize,
        eval_tokens: Vec<f32>,
    },
}

/// N-node synchronous trainer.
pub struct Trainer {
    cfg: Config,
    art: Artifact,
    layout: ParamLayout,
    kernel: Option<ImportanceKernel>,
    task: Task,
    /// Flat parameter buffer (replicas are identical; see mod docs).
    params: Vec<f32>,
    /// Per-node residual stores (IWP methods).
    stores: Vec<ResidualStore>,
    /// Per-node DGC state.
    dgcs: Vec<Dgc>,
    opt: MomentumSgd,
    lr: LrSchedule,
    net: RingNet,
    policy: ThresholdPolicy,
    warmup: Warmup,
    /// Trailing per-layer importance stats (layerwise controller input).
    prev_stats: Vec<LayerStats>,
    /// Per-node data RNG streams + one control stream.
    node_rngs: Vec<Rng>,
    ctl_rng: Rng,
    /// Scratch: per-node gradient buffers.
    grads: Vec<Vec<f32>>,
    u_buf: Vec<f32>,
    /// Reusable per-broadcaster selection masks (`clear_all`-ed and
    /// refilled by the kernel every step — DESIGN.md §11).
    mask_slots: Vec<BitMask>,
    /// Reusable per-layer threshold table (Eq. 4 controller output).
    thrs_buf: Vec<f32>,
    /// Reusable stats accumulator: merged per broadcaster, swapped into
    /// `prev_stats` only once the whole (fallible) kernel loop succeeds.
    stats_scratch: Vec<LayerStats>,
    account_scratch: CompressionAccount,
    /// Node-parallel executor for the reduce paths (`cfg.parallelism`).
    exec: Executor,
    /// Communication topology of the reduce (`--topology`,
    /// DESIGN.md §10).
    topo: Box<dyn Topology>,
    /// Staging arena for the reduce hot paths (DESIGN.md §9).
    arena: Arena,
}

impl Trainer {
    /// Build a trainer from config; loads artifacts via the runtime.
    pub fn new(cfg: Config, rt: &Runtime) -> anyhow::Result<Self> {
        let (art_name, task) = match cfg.model.as_str() {
            "mlp" => {
                let data = SynthClassification::cifar_like(cfg.seed);
                let (eval_x, eval_y) = data.eval_set(128, cfg.seed);
                (
                    "train_step_mlp_b32",
                    Task::Mlp {
                        data,
                        eval_x,
                        eval_y,
                    },
                )
            }
            "tfm_tiny" => {
                let corpus = CharCorpus::tiny();
                let mut erng = Rng::new(cfg.seed ^ 0xE7A1);
                let eval_tokens = corpus.batch(&mut erng, 8, 64);
                (
                    "train_step_tfm_tiny_b8",
                    Task::Lm {
                        corpus,
                        seq_len: 64,
                        eval_tokens,
                    },
                )
            }
            other => anyhow::bail!("trainer model `{other}` (mlp|tfm_tiny)"),
        };
        let art = rt.load(art_name)?;
        let layout = art.meta.layout()?;
        let kernel = match cfg.method {
            Method::IwpFixed | Method::IwpLayerwise => Some(ImportanceKernel::load(rt)?),
            _ => None,
        };
        let total = layout.total_params();

        let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
        let params = init_params(&layout, &mut init_rng);

        let mut root = Rng::new(cfg.seed);
        let node_rngs: Vec<Rng> = (0..cfg.nodes).map(|i| root.split(i as u64)).collect();
        let ctl_rng = root.split(0xC011);

        let policy = match cfg.method {
            Method::IwpLayerwise => ThresholdPolicy::Layerwise(ThresholdCfg {
                alpha: cfg.threshold,
                beta: cfg.beta,
                c: cfg.c,
                ..Default::default()
            }),
            _ => ThresholdPolicy::Fixed(cfg.threshold),
        };
        let warmup = if cfg.warmup_epochs > 0 {
            Warmup {
                epochs: cfg.warmup_epochs,
                start_mult: 0.1,
            }
        } else {
            Warmup::none()
        };

        // Compressed paths carry momentum in the residual store (momentum
        // correction); the global optimizer momentum is for dense paths.
        let (opt_momentum, store_momentum) = match cfg.method {
            Method::Baseline | Method::TernGrad => (cfg.momentum, 0.0),
            _ => (0.0, cfg.momentum),
        };

        Ok(Trainer {
            exec: Executor::new(cfg.parallelism),
            topo: cfg.topology.build(cfg.nodes),
            arena: Arena::for_nodes(cfg.nodes),
            net: RingNet::new(cfg.nodes, cfg.link_spec(), 0.05),
            stores: (0..cfg.nodes)
                .map(|_| ResidualStore::new(total, store_momentum))
                .collect(),
            dgcs: (0..cfg.nodes)
                .map(|_| Dgc::new(total, cfg.dgc_density, cfg.momentum))
                .collect(),
            opt: MomentumSgd::new(total, opt_momentum),
            lr: LrSchedule::with_warmup(cfg.lr, cfg.steps_per_epoch / 2),
            prev_stats: vec![LayerStats::default(); layout.n_layers()],
            grads: vec![vec![0.0; total]; cfg.nodes],
            u_buf: vec![1.0; total],
            mask_slots: (0..cfg.mask_nodes.min(cfg.nodes))
                .map(|_| BitMask::zeros(total))
                .collect(),
            thrs_buf: Vec::with_capacity(layout.n_layers()),
            stats_scratch: vec![LayerStats::default(); layout.n_layers()],
            account_scratch: CompressionAccount::new(),
            node_rngs,
            ctl_rng,
            policy,
            warmup,
            task,
            params,
            layout,
            kernel,
            art,
            cfg,
        })
    }

    /// The model layout under training.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// Dense per-node wire reference: 2(N-1)/N of the gradient bytes —
    /// the denominator-side of the paper's compression ratio on a ring.
    fn dense_ref_bytes(&self) -> u64 {
        let n = self.cfg.nodes as u64;
        2 * (n - 1) * self.layout.dense_bytes() / n
    }

    /// One local forward/backward on `node` — PJRT executes the L2 HLO.
    /// Returns the train loss; fills `self.grads[node]`.
    fn local_step(&mut self, node: usize) -> anyhow::Result<f64> {
        let (loss, outs) = match &self.task {
            Task::Mlp { data, .. } => {
                let (x, y) = data.batch(&mut self.node_rngs[node], 32);
                let mut inputs: Vec<&[f32]> = Vec::with_capacity(self.layout.n_layers() + 2);
                let splits = self.layout.split(&self.params);
                inputs.extend(splits);
                inputs.push(&x);
                inputs.push(&y);
                let out = self.art.run_f32(&inputs)?;
                (out[0][0] as f64, out[2..].to_vec())
            }
            Task::Lm {
                corpus, seq_len, ..
            } => {
                let tokens = corpus.batch(&mut self.node_rngs[node], 8, *seq_len);
                let mut inputs: Vec<&[f32]> = Vec::with_capacity(self.layout.n_layers() + 1);
                let splits = self.layout.split(&self.params);
                inputs.extend(splits);
                inputs.push(&tokens);
                let out = self.art.run_f32(&inputs)?;
                (out[0][0] as f64, out[1..].to_vec())
            }
        };
        // Flatten per-layer grads into the node's flat buffer.
        let flat = &mut self.grads[node];
        for (layer, g) in self.layout.layers().iter().zip(&outs) {
            flat[layer.range()].copy_from_slice(g);
        }
        Ok(loss)
    }

    /// Evaluate on the held-out set (no update).
    fn eval(&mut self) -> anyhow::Result<(f64, f64)> {
        match &self.task {
            Task::Mlp { eval_x, eval_y, .. } => {
                let mut loss_sum = 0.0;
                let mut acc_sum = 0.0;
                let n_batches = eval_x.len() / (32 * 3072);
                for b in 0..n_batches {
                    let x = &eval_x[b * 32 * 3072..(b + 1) * 32 * 3072];
                    let y = &eval_y[b * 32..(b + 1) * 32];
                    let mut inputs: Vec<&[f32]> = Vec::new();
                    inputs.extend(self.layout.split(&self.params));
                    inputs.push(x);
                    inputs.push(y);
                    let out = self.art.run_f32(&inputs)?;
                    loss_sum += out[0][0] as f64;
                    acc_sum += out[1][0] as f64;
                }
                Ok((loss_sum / n_batches as f64, acc_sum / n_batches as f64))
            }
            Task::Lm { eval_tokens, .. } => {
                let mut inputs: Vec<&[f32]> = Vec::new();
                inputs.extend(self.layout.split(&self.params));
                inputs.push(eval_tokens);
                let out = self.art.run_f32(&inputs)?;
                Ok((out[0][0] as f64, 0.0))
            }
        }
    }

    /// Run the configured number of steps.
    pub fn run(&mut self) -> anyhow::Result<TrainOutcome> {
        let mut out = TrainOutcome::default();
        let eval_every = (self.cfg.steps / 20).max(5);
        for step in 0..self.cfg.steps {
            let loss = self.step(step)?;
            out.losses.push((step, loss));
            if step % eval_every == 0 || step + 1 == self.cfg.steps {
                let (el, ea) = self.eval()?;
                out.evals.push((step, el, ea));
            }
        }
        let (el, ea) = self.eval()?;
        out.final_eval_loss = el;
        out.final_eval_acc = ea;
        out.net_seconds = self.net.clock();
        out.io_trace = self.net.trace().kbps_series(0);
        out.peak_kbps = self.net.trace().peak_kbps(0);
        out.account = std::mem::take(&mut self.account_scratch);
        Ok(out)
    }

    /// One synchronous step across all nodes. Returns the mean train loss.
    pub fn step(&mut self, step: usize) -> anyhow::Result<f64> {
        let n = self.cfg.nodes;
        let epoch = self.cfg.epoch_of(step);
        let lr = self.lr.at(step);

        // ---- local gradients (PJRT per node) -------------------------
        let mut loss_sum = 0.0;
        for node in 0..n {
            loss_sum += self.local_step(node)?;
        }

        // ---- local gradient clipping ---------------------------------
        if self.cfg.clip_norm > 0.0 {
            let per_node = clip::per_node_max_norm(self.cfg.clip_norm, n);
            self.exec.map_mut(&mut self.grads, |_, g| {
                clip::clip_by_global_norm(g, per_node);
            });
        }

        // ---- reduce + update (method-specific) -----------------------
        match self.cfg.method {
            Method::Baseline => self.reduce_dense(lr)?,
            Method::TernGrad => self.reduce_terngrad(lr)?,
            Method::Dgc => self.reduce_dgc(lr, epoch)?,
            Method::IwpFixed | Method::IwpLayerwise => self.reduce_iwp(lr, epoch)?,
        }

        // Small compute-phase gap so I/O traces show the paper's idle
        // valleys between bursts (virtual time, trace realism only).
        self.net.advance(0.01);

        Ok(loss_sum / n as f64)
    }

    // ---- reduce paths ------------------------------------------------

    fn reduce_dense(&mut self, lr: f32) -> anyhow::Result<()> {
        let rep = self
            .topo
            .dense(&mut self.net, &mut self.grads, &self.exec, &mut self.arena);
        let n = self.cfg.nodes as f32;
        // grads[0] now holds the sum; the optimizer averages inline (one
        // pass, no materialized average buffer — bit-identical).
        self.opt.step_mean(&mut self.params, &self.grads[0], n, lr);
        self.account_scratch.record_full(
            self.dense_ref_bytes(),
            rep.mean_bytes_per_node() as u64,
            self.layout.dense_bytes(),
            self.layout.dense_bytes(),
            1.0,
        );
        Ok(())
    }

    fn reduce_terngrad(&mut self, lr: f32) -> anyhow::Result<()> {
        let n = self.cfg.nodes;
        // Encode per node in parallel (each node consumes only its own
        // RNG stream; the ternary blobs are ~16x smaller than dense, so
        // holding all n is cheap), then decode + sum sequentially in
        // node order — the same f32 addition order as the sequential
        // loop, one transient dense vector at a time — and spread the
        // quantized blobs over the configured topology (blob sizes are
        // shape-determined, so every node's blob prices identically).
        let grads = &self.grads;
        let layout = &self.layout;
        let encoded: Vec<TernGrad> = self.exec.map_mut(&mut self.node_rngs, |node, rng| {
            TernGrad::encode(&grads[node], layout, rng)
        });
        let mut sum = vec![0.0f32; self.layout.total_params()];
        for t in &encoded {
            for (s, v) in sum.iter_mut().zip(t.decode(&self.layout)) {
                *s += v;
            }
        }
        let rep =
            self.topo
                .spread_bytes(&mut self.net, encoded[0].wire_bytes(), n, &mut self.arena);
        let wire = rep.total_bytes() / n as u64;
        self.opt.step_mean(&mut self.params, &sum, n as f32, lr);
        self.account_scratch.record_full(
            self.dense_ref_bytes(),
            wire,
            self.layout.dense_bytes(),
            encoded[0].wire_bytes(),
            1.0,
        );
        Ok(())
    }

    fn reduce_dgc(&mut self, lr: f32, epoch: usize) -> anyhow::Result<()> {
        let n = self.cfg.nodes;
        let density =
            Dgc::density_at_epoch(self.cfg.dgc_density, epoch, self.cfg.warmup_epochs);
        let grads = &self.grads;
        let sparses: Vec<_> = self.exec.map_mut(&mut self.dgcs, |node, dgc| {
            dgc.density = density;
            dgc.step(&grads[node])
        });
        let (sum, rep) = self
            .topo
            .sparse(&mut self.net, &sparses, &self.exec, &mut self.arena);
        let inv_n = 1.0 / n as f32;
        for (i, &v) in sum.iter().enumerate() {
            if v != 0.0 {
                self.params[i] -= lr * v * inv_n;
            }
        }
        let k = sparses[0].nnz();
        let total = self.layout.total_params();
        self.account_scratch.record_full(
            self.dense_ref_bytes(),
            rep.mean_bytes_per_node() as u64,
            self.layout.dense_bytes(),
            crate::sparse::wire_bytes(
                crate::sparse::WireFormat::cheapest(total, k),
                total,
                k,
            ),
            rep.density_per_hop.last().copied().unwrap_or(density),
        );
        Ok(())
    }

    fn reduce_iwp(&mut self, lr: f32, epoch: usize) -> anyhow::Result<()> {
        let n = self.cfg.nodes;
        // Residual accumulation (momentum correction) on every node,
        // fanned out across the executor (disjoint per-node stores).
        {
            let grads = &self.grads;
            self.exec.map_mut(&mut self.stores, |node, store| {
                store.accumulate(&grads[node]);
            });
        }

        // Per-layer thresholds from trailing stats (Eq. 4 controller),
        // refilled into the reusable table.
        let wmult = self.warmup.multiplier(epoch);
        self.policy.layer_thresholds_into(
            &self.layout,
            &self.prev_stats,
            epoch,
            wmult,
            &mut self.thrs_buf,
        );

        // Random broadcaster nodes (Alg. 1 line 6).
        let broadcasters = self
            .ctl_rng
            .choose_distinct(n, self.cfg.mask_nodes.min(n));

        // Each broadcaster scores its pending residuals with the L1
        // kernel, layer by layer, packing selection bits straight into a
        // reusable model-wide mask slot (`score_into` — no per-layer
        // mask or importance allocation, DESIGN.md §11). This loop stays
        // sequential: the PJRT kernel executes through a single loaded
        // artifact handle (parallelizing across PJRT clients is the
        // ROADMAP async direction); the CPU-mirror engine in
        // `exp::simrun` runs the fully fused `fuse::score_select_compact`
        // fan-out instead. Stats accumulate in a scratch buffer so a
        // kernel error mid-loop leaves `prev_stats` (and therefore the
        // next step's Eq.-4 thresholds) untouched.
        for s in self.stats_scratch.iter_mut() {
            *s = LayerStats::default();
        }
        let kernel = self
            .kernel
            .as_mut()
            .expect("IWP methods always load the kernel");
        for (bi, &b) in broadcasters.iter().enumerate() {
            select::fill_u(&mut self.node_rngs[b], self.cfg.random_select, &mut self.u_buf);
            let pending = self.stores[b].pending();
            let weights = &self.params;
            let mask = &mut self.mask_slots[bi];
            mask.clear_all();
            for (li, layer) in self.layout.layers().iter().enumerate() {
                let r = layer.range();
                let st = kernel.score_into(
                    &pending[r.clone()],
                    &weights[r.clone()],
                    &self.u_buf[r.clone()],
                    self.thrs_buf[li],
                    crate::compress::importance::EPS,
                    r.start,
                    mask,
                )?;
                self.stats_scratch[li].merge(&st);
            }
        }
        std::mem::swap(&mut self.prev_stats, &mut self.stats_scratch);

        // Shared-mask ring all-reduce (Alg. 1 lines 7–12). `values`
        // borrows `stores` while the net (a disjoint field) mutates.
        let mask_refs: Vec<&BitMask> =
            self.mask_slots[..broadcasters.len()].iter().collect();
        let values: Vec<&[f32]> = self.stores.iter().map(|s| s.pending()).collect();
        let (shared, summed, rep) = self.topo.masked(
            &mut self.net,
            &mask_refs,
            &values,
            &self.exec,
            &mut self.arena,
        );

        // Fused residual take (momentum factor masking): zero residual +
        // velocity on the shared support in one sweep per node — no
        // per-node sent-values Vec (the compacted payload the schedule
        // reduced already lives in the arena).
        let shared_ref = &shared;
        self.exec.map_mut(&mut self.stores, |_, store| {
            store.clear_masked(shared_ref);
        });

        // Sparse SGD update on the shared support (Alg. 1 line 13),
        // driven by the mask iterator with the 1/N scaling fused in.
        let inv_n = 1.0 / n as f32;
        self.opt
            .step_sparse_mask(&mut self.params, &shared, &summed, inv_n, lr);

        let nnz = shared.count();
        let total = self.layout.total_params();
        self.account_scratch.record_full(
            self.dense_ref_bytes(),
            rep.mean_bytes_per_node() as u64,
            self.layout.dense_bytes(),
            crate::sparse::wire_bytes(
                crate::sparse::WireFormat::cheapest(total, nnz),
                total,
                nnz,
            ),
            shared.density(),
        );
        Ok(())
    }
}

/// Kind-aware parameter init over a flat buffer (mirrors the python
/// init; numerics need not match bit-for-bit, only distribution).
pub fn init_params(layout: &ParamLayout, rng: &mut Rng) -> Vec<f32> {
    let mut params = vec![0.0f32; layout.total_params()];
    for layer in layout.layers() {
        let p = &mut params[layer.range()];
        match layer.kind {
            crate::model::LayerKind::Norm => p.fill(1.0),
            crate::model::LayerKind::Bias => {}
            crate::model::LayerKind::BatchNorm => p.fill(1.0),
            crate::model::LayerKind::Fc | crate::model::LayerKind::Conv => {
                let sigma = (2.0 / layer.fan_in() as f32).sqrt();
                rng.fill_normal(p, 0.0, sigma);
            }
            _ => {
                let sigma = 1.0 / (layer.fan_in() as f32).sqrt();
                rng.fill_normal(p, 0.0, sigma);
            }
        }
    }
    params
}
