//! Dense ring all-reduce: scatter-reduce + allgather (Baidu/Gibiansky).
//!
//! With N nodes and V bytes of gradient, every node transmits
//! `2·(N−1)/N · V` bytes regardless of N — the constant-cost property
//! that makes rings the right substrate for large models, and the
//! baseline transport whose I/O trace is Fig. 7.

use std::ops::Range;
use std::sync::atomic::AtomicU64;

use super::{chunk_ranges_into, per_node_delta, snapshot, Arena, Executor, ReduceReport};
use crate::net::RingNet;

/// In-place dense all-reduce over every node's buffer. On return every
/// `bufs[i]` holds the element-wise **sum** across nodes (callers divide
/// by N for the average — Algorithm 1 line 12 averages after reduce).
pub fn allreduce(net: &mut RingNet, bufs: &mut [Vec<f32>]) -> ReduceReport {
    allreduce_exec(net, bufs, &Executor::sequential())
}

/// [`allreduce`] with per-node staging/accumulation fanned out over
/// `exec`'s worker threads. Bit-identical to the sequential path: every
/// round stages all senders' chunks first (reads), then applies all
/// receivers' accumulations (writes to disjoint `bufs[dst]`), so neither
/// phase has cross-node ordering effects.
pub fn allreduce_exec(net: &mut RingNet, bufs: &mut [Vec<f32>], exec: &Executor) -> ReduceReport {
    allreduce_in(net, bufs, exec, &mut Arena::new())
}

/// [`allreduce_exec`] against a caller-owned [`Arena`]: the per-round
/// staging copies and send-size tables live in the arena's reusable
/// buffers, so the steady-state loop allocates nothing once warm
/// (DESIGN.md §9). Results are bit-identical to the other entry points.
pub fn allreduce_in(
    net: &mut RingNet,
    bufs: &mut [Vec<f32>],
    exec: &Executor,
    arena: &mut Arena,
) -> ReduceReport {
    let Arena {
        grows,
        dense_staging,
        dense_sends,
        dense_chunks,
        ..
    } = arena;
    allreduce_parts(net, bufs, exec, grows, dense_staging, dense_sends, dense_chunks)
}

/// Core dense schedule over explicit scratch parts, so the masked
/// schedule can run it on the arena's dense scratch while holding its
/// own arena fields.
pub(super) fn allreduce_parts(
    net: &mut RingNet,
    bufs: &mut [Vec<f32>],
    exec: &Executor,
    grows: &AtomicU64,
    staging: &mut Vec<Vec<f32>>,
    sends: &mut Vec<u64>,
    chunks: &mut Vec<Range<usize>>,
) -> ReduceReport {
    let n = net.n_nodes();
    assert_eq!(bufs.len(), n, "one buffer per node");
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    if len == 0 {
        return ReduceReport {
            bytes_per_node: vec![0; n],
            ..Default::default()
        };
    }

    let cap = chunks.capacity();
    chunk_ranges_into(len, n, chunks);
    Arena::note(grows, chunks.capacity() != cap);
    Arena::slots(grows, staging, n, Vec::new);
    let chunks: &[Range<usize>] = chunks;
    let before = snapshot(net);
    let t0 = net.clock();

    // Scatter-reduce: round r, node i sends chunk (i - r) mod n to i+1,
    // which accumulates it into its own copy.
    for r in 0..n - 1 {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                let c = (i + n - r) % n;
                (chunks[c].len() * 4) as u64
            }),
        );
        net.round(sends);
        // Apply the data movement: receiver (i+1) accumulates sender i's
        // current copy of chunk (i - r). Use a staging copy so updates
        // within a round don't cascade.
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                let c = (i + n - r) % n;
                Arena::note(grows, Arena::refill_slice(stage, &bufs_src[i][chunks[c].clone()]));
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            let src = (dst + n - 1) % n;
            let c = (src + n - r) % n;
            let range = chunks[c].clone();
            for (k, idx) in range.enumerate() {
                buf[idx] += staged[src][k];
            }
        });
    }

    // After scatter-reduce, node i owns the fully-reduced chunk (i+1)%n.
    // Allgather: round r, node i sends chunk (i + 1 - r) mod n onward.
    for r in 0..n - 1 {
        Arena::refill(
            grows,
            sends,
            (0..n).map(|i| {
                let c = (i + 1 + n - r) % n;
                (chunks[c].len() * 4) as u64
            }),
        );
        net.round(sends);
        {
            let bufs_src: &[Vec<f32>] = bufs;
            exec.map_mut(&mut staging[..n], |i, stage| {
                let c = (i + 1 + n - r) % n;
                Arena::note(grows, Arena::refill_slice(stage, &bufs_src[i][chunks[c].clone()]));
            });
        }
        let staged: &[Vec<f32>] = staging;
        exec.map_mut(bufs, |dst, buf| {
            let src = (dst + n - 1) % n;
            let c = (src + 1 + n - r) % n;
            let range = chunks[c].clone();
            for (k, idx) in range.enumerate() {
                buf[idx] = staged[src][k];
            }
        });
    }

    ReduceReport {
        bytes_per_node: per_node_delta(net, &before),
        seconds: net.clock() - t0,
        density_per_hop: Vec::new(),
    }
}

/// Accounting-only dense schedule: models the `2(N-1)` rounds' bytes and
/// virtual time on the net without moving any values — the Baseline arm
/// of `exp::simrun`, where only the wire behaviour matters. Send
/// sequences match the exact schedule's rotation, so byte/time totals
/// are identical to [`allreduce`] over the same coordinate count.
pub fn rounds_bytes_only(net: &mut RingNet, coords: usize, arena: &mut Arena) {
    let n = net.n_nodes();
    let Arena {
        grows,
        dense_sends,
        dense_chunks,
        mk_chunk_bytes,
        ..
    } = arena;
    let cap = dense_chunks.capacity();
    chunk_ranges_into(coords, n, dense_chunks);
    Arena::note(grows, dense_chunks.capacity() != cap);
    Arena::refill(
        grows,
        mk_chunk_bytes,
        dense_chunks.iter().map(|r| (r.len() * 4) as u64),
    );
    for r in 0..2 * (n - 1) {
        Arena::refill(
            grows,
            dense_sends,
            (0..n).map(|i| mk_chunk_bytes[(i + n - (r % n)) % n]),
        );
        net.round(dense_sends);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;
    use crate::util::prop::forall;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    #[test]
    fn reduces_to_sum_small() {
        let mut nw = net(3);
        let mut bufs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0],
            vec![10.0, 20.0, 30.0, 40.0],
            vec![100.0, 200.0, 300.0, 400.0],
        ];
        allreduce(&mut nw, &mut bufs);
        for b in &bufs {
            assert_eq!(b, &[111.0, 222.0, 333.0, 444.0]);
        }
    }

    #[test]
    fn arena_path_is_bit_identical_and_stops_allocating() {
        let n = 5;
        let len = 777;
        let mut rng = crate::util::rng::Rng::new(3);
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; len];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let mut net_a = net(n);
        let mut bufs_a = base.clone();
        let rep_a = allreduce(&mut net_a, &mut bufs_a);
        let mut arena = Arena::for_nodes(n);
        let exec = Executor::sequential();
        let mut grows_after_warmup = 0;
        for pass in 0..3 {
            let mut net_b = net(n);
            let mut bufs_b = base.clone();
            let rep_b = allreduce_in(&mut net_b, &mut bufs_b, &exec, &mut arena);
            assert_eq!(rep_a.bytes_per_node, rep_b.bytes_per_node);
            assert_eq!(rep_a.seconds.to_bits(), rep_b.seconds.to_bits());
            for (a, b) in bufs_a.iter().zip(&bufs_b) {
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb);
            }
            if pass == 0 {
                grows_after_warmup = arena.grows();
            } else {
                assert_eq!(arena.grows(), grows_after_warmup, "pass {pass} reallocated");
            }
        }
    }

    #[test]
    fn rounds_bytes_only_matches_exact_accounting() {
        let n = 6;
        let len = 1234;
        let mut net_a = net(n);
        let mut bufs = vec![vec![1.0f32; len]; n];
        let rep = allreduce(&mut net_a, &mut bufs);
        let mut net_b = net(n);
        rounds_bytes_only(&mut net_b, len, &mut Arena::new());
        assert_eq!(net_b.total_bytes(), rep.total_bytes());
        assert_eq!(net_b.clock().to_bits(), rep.seconds.to_bits());
        assert_eq!(net_b.rounds(), 2 * (n as u64 - 1));
    }

    #[test]
    fn byte_cost_is_2_n_minus_1_over_n() {
        let n = 8;
        let len = 800usize;
        let mut nw = net(n);
        let mut bufs = vec![vec![1.0f32; len]; n];
        let rep = allreduce(&mut nw, &mut bufs);
        let expect = 2 * (n as u64 - 1) * (len as u64 * 4) / n as u64;
        for &b in &rep.bytes_per_node {
            assert_eq!(b, expect);
        }
    }

    #[test]
    fn allreduce_equals_direct_sum_property() {
        forall("ring dense allreduce == sum", 40, |g| {
            let n = g.usize_in(2, 9);
            let len = g.usize_in(1, 64);
            let bufs_orig: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_normal(len, 0.0, 1.0)).collect();
            let mut expect = vec![0.0f32; len];
            for b in &bufs_orig {
                for (e, &v) in expect.iter_mut().zip(b) {
                    *e += v;
                }
            }
            let mut nw = net(n);
            let mut bufs = bufs_orig.clone();
            allreduce(&mut nw, &mut bufs);
            for b in &bufs {
                for (x, e) in b.iter().zip(&expect) {
                    assert!(
                        (x - e).abs() <= 1e-3 * e.abs().max(1.0),
                        "node disagrees with direct sum: {x} vs {e}"
                    );
                }
            }
        });
    }

    #[test]
    fn len_smaller_than_ring_still_works() {
        let mut nw = net(5);
        let mut bufs = vec![vec![1.0f32, 2.0]; 5];
        allreduce(&mut nw, &mut bufs);
        for b in &bufs {
            assert_eq!(b, &[5.0, 10.0]);
        }
    }

    #[test]
    fn empty_buffers_are_noop() {
        let mut nw = net(3);
        let mut bufs = vec![Vec::new(), Vec::new(), Vec::new()];
        let rep = allreduce(&mut nw, &mut bufs);
        assert_eq!(rep.total_bytes(), 0);
    }
}
