//! Dense ring all-reduce: scatter-reduce + allgather (Baidu/Gibiansky).
//!
//! With N nodes and V bytes of gradient, every node transmits
//! `2·(N−1)/N · V` bytes regardless of N — the constant-cost property
//! that makes rings the right substrate for large models, and the
//! baseline transport whose I/O trace is Fig. 7.

use super::{chunk_ranges, per_node_delta, snapshot, Executor, ReduceReport};
use crate::net::RingNet;

/// In-place dense all-reduce over every node's buffer. On return every
/// `bufs[i]` holds the element-wise **sum** across nodes (callers divide
/// by N for the average — Algorithm 1 line 12 averages after reduce).
pub fn allreduce(net: &mut RingNet, bufs: &mut [Vec<f32>]) -> ReduceReport {
    allreduce_exec(net, bufs, &Executor::sequential())
}

/// [`allreduce`] with per-node staging/accumulation fanned out over
/// `exec`'s worker threads. Bit-identical to the sequential path: every
/// round stages all senders' chunks first (reads), then applies all
/// receivers' accumulations (writes to disjoint `bufs[dst]`), so neither
/// phase has cross-node ordering effects.
pub fn allreduce_exec(
    net: &mut RingNet,
    bufs: &mut [Vec<f32>],
    exec: &Executor,
) -> ReduceReport {
    let n = net.n_nodes();
    assert_eq!(bufs.len(), n, "one buffer per node");
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    if len == 0 {
        return ReduceReport {
            bytes_per_node: vec![0; n],
            ..Default::default()
        };
    }

    let chunks = chunk_ranges(len, n);
    let before = snapshot(net);
    let t0 = net.clock();

    // Scatter-reduce: round r, node i sends chunk (i - r) mod n to i+1,
    // which accumulates it into its own copy.
    for r in 0..n - 1 {
        let sends: Vec<u64> = (0..n)
            .map(|i| {
                let c = (i + n - r) % n;
                (chunks[c].len() * 4) as u64
            })
            .collect();
        net.round(&sends);
        // Apply the data movement: receiver (i+1) accumulates sender i's
        // current copy of chunk (i - r). Use a staging copy so updates
        // within a round don't cascade.
        let staged: Vec<Vec<f32>> = exec.map_indexed(n, |i| {
            let c = (i + n - r) % n;
            bufs[i][chunks[c].clone()].to_vec()
        });
        exec.map_mut(bufs, |dst, buf| {
            let src = (dst + n - 1) % n;
            let c = (src + n - r) % n;
            let range = chunks[c].clone();
            for (k, idx) in range.enumerate() {
                buf[idx] += staged[src][k];
            }
        });
    }

    // After scatter-reduce, node i owns the fully-reduced chunk (i+1)%n.
    // Allgather: round r, node i sends chunk (i + 1 - r) mod n onward.
    for r in 0..n - 1 {
        let sends: Vec<u64> = (0..n)
            .map(|i| {
                let c = (i + 1 + n - r) % n;
                (chunks[c].len() * 4) as u64
            })
            .collect();
        net.round(&sends);
        let staged: Vec<Vec<f32>> = exec.map_indexed(n, |i| {
            let c = (i + 1 + n - r) % n;
            bufs[i][chunks[c].clone()].to_vec()
        });
        exec.map_mut(bufs, |dst, buf| {
            let src = (dst + n - 1) % n;
            let c = (src + 1 + n - r) % n;
            let range = chunks[c].clone();
            for (k, idx) in range.enumerate() {
                buf[idx] = staged[src][k];
            }
        });
    }

    ReduceReport {
        bytes_per_node: per_node_delta(net, &before),
        seconds: net.clock() - t0,
        density_per_hop: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;
    use crate::util::prop::forall;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    #[test]
    fn reduces_to_sum_small() {
        let mut nw = net(3);
        let mut bufs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0],
            vec![10.0, 20.0, 30.0, 40.0],
            vec![100.0, 200.0, 300.0, 400.0],
        ];
        allreduce(&mut nw, &mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0, 333.0, 444.0]);
        }
    }

    #[test]
    fn byte_cost_is_2_n_minus_1_over_n() {
        let n = 8;
        let len = 800usize;
        let mut nw = net(n);
        let mut bufs = vec![vec![1.0f32; len]; n];
        let rep = allreduce(&mut nw, &mut bufs);
        let expect = 2 * (n as u64 - 1) * (len as u64 * 4) / n as u64;
        for &b in &rep.bytes_per_node {
            assert_eq!(b, expect);
        }
    }

    #[test]
    fn allreduce_equals_direct_sum_property() {
        forall("ring dense allreduce == sum", 40, |g| {
            let n = g.usize_in(2, 9);
            let len = g.usize_in(1, 64);
            let bufs_orig: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_normal(len, 0.0, 1.0)).collect();
            let mut expect = vec![0.0f32; len];
            for b in &bufs_orig {
                for (e, &v) in expect.iter_mut().zip(b) {
                    *e += v;
                }
            }
            let mut nw = net(n);
            let mut bufs = bufs_orig.clone();
            allreduce(&mut nw, &mut bufs);
            for b in &bufs {
                for (x, e) in b.iter().zip(&expect) {
                    assert!(
                        (x - e).abs() <= 1e-3 * e.abs().max(1.0),
                        "node disagrees with direct sum: {x} vs {e}"
                    );
                }
            }
        });
    }

    #[test]
    fn len_smaller_than_ring_still_works() {
        let mut nw = net(5);
        let mut bufs = vec![vec![1.0f32, 2.0]; 5];
        allreduce(&mut nw, &mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![5.0, 10.0]);
        }
    }

    #[test]
    fn empty_buffers_are_noop() {
        let mut nw = net(3);
        let mut bufs = vec![Vec::new(), Vec::new(), Vec::new()];
        let rep = allreduce(&mut nw, &mut bufs);
        assert_eq!(rep.total_bytes(), 0);
    }
}
