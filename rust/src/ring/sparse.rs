//! Sparse ring all-reduce with **per-node supports** — DGC-on-a-ring.
//!
//! Each node contributes its own sparse gradient. During scatter-reduce a
//! travelling chunk segment accumulates the *union* of the supports it
//! passes through, so its nnz grows with every hop — the densification
//! the paper identifies as DGC's failure mode on rings (Sec. II: "as the
//! number of ring nodes increases, the gradient on each node becomes
//! denser as the ring reduce is performed").  `ReduceReport::
//! density_per_hop` quantifies it; `exp::density` plots it against N.

use std::ops::Range;

use super::{chunk_ranges_aligned_into, chunk_ranges_into, per_node_delta, snapshot};
use super::{Arena, Executor, ReduceReport};
use crate::net::RingNet;
use crate::sparse::SparseVec;

/// All-reduce of per-node sparse gradients. Returns the summed dense
/// result (identical on every node) plus wire accounting; the travelling
/// segments stay in sparse wire format the whole way.
pub fn allreduce(net: &mut RingNet, inputs: &[SparseVec]) -> (Vec<f32>, ReduceReport) {
    allreduce_exec(net, inputs, &Executor::sequential())
}

/// [`allreduce`] with the per-hop segment extraction and sparse merges
/// fanned out over `exec` (one travelling segment per node, disjoint
/// state). Densities and byte counts are reduced on the coordinating
/// thread in node order, so reports are bit-identical to sequential.
pub fn allreduce_exec(
    net: &mut RingNet,
    inputs: &[SparseVec],
    exec: &Executor,
) -> (Vec<f32>, ReduceReport) {
    allreduce_in(net, inputs, exec, &mut Arena::new())
}

/// [`allreduce_exec`] against a caller-owned [`Arena`]: the travelling
/// segments ping-pong between two arena slot tables and the per-hop
/// segment gathers/union-merges reuse their buffers, so the steady-state
/// hop loop allocates nothing once warm (DESIGN.md §9). Bit-identical to
/// the other entry points.
pub fn allreduce_in(
    net: &mut RingNet,
    inputs: &[SparseVec],
    exec: &Executor,
    arena: &mut Arena,
) -> (Vec<f32>, ReduceReport) {
    let n = net.n_nodes();
    assert_eq!(inputs.len(), n);
    let len = inputs[0].len;
    assert!(inputs.iter().all(|s| s.len == len));

    let Arena {
        grows,
        sp_held,
        sp_next,
        sp_segs,
        sp_sends,
        sp_chunks,
        ..
    } = arena;
    let grows: &std::sync::atomic::AtomicU64 = grows;
    let cap = sp_chunks.capacity();
    chunk_ranges_into(len, n, sp_chunks);
    Arena::note(grows, sp_chunks.capacity() != cap);
    let chunks: &[Range<usize>] = sp_chunks;
    Arena::slots(grows, sp_held, n, || SparseVec::empty(0));
    Arena::slots(grows, sp_next, n, || SparseVec::empty(0));
    Arena::slots(grows, sp_segs, n, || SparseVec::empty(0));

    let before = snapshot(net);
    let t0 = net.clock();

    // held[i] = the travelling segment node i currently holds.
    // Initially node i holds its own slice of chunk i.
    exec.map_mut(&mut sp_held[..n], |i, h| {
        Arena::note(grows, h.assign_window(&inputs[i], &chunks[i]));
    });
    let (mut held, mut next) = (sp_held, sp_next);
    let mut density_per_hop = Vec::with_capacity(n - 1);

    // Scatter-reduce: at round r node i holds the partial sum of chunk
    // (i - r); it sends it to i+1 which merges in its own slice.
    for r in 0..n - 1 {
        Arena::refill(grows, sp_sends, held[..n].iter().map(|s| s.wire_bytes()));
        net.round(sp_sends);
        {
            let held_ref: &[SparseVec] = held;
            exec.map_mut2(&mut next[..n], &mut sp_segs[..n], |dst, nx, seg| {
                let src = (dst + n - 1) % n;
                let c = (dst + n - (r + 1)) % n; // chunk arriving at dst
                Arena::note(grows, seg.assign_window(&inputs[dst], &chunks[c]));
                Arena::note(grows, held_ref[src].merge_add_into(seg, nx));
            });
        }
        std::mem::swap(&mut held, &mut next);
        // Mean density of travelling segments after this hop.
        let d = held[..n].iter().map(|s| s.density()).sum::<f64>() / n as f64;
        density_per_hop.push(d);
    }

    // Node i now holds the fully-reduced chunk (i + 1) % n.
    // Assemble the global dense result and run the allgather purely for
    // byte/time accounting (every node must end with every chunk).
    let mut result = vec![0.0f32; len];
    for (i, h) in held[..n].iter().enumerate() {
        let c = (i + 1) % n;
        let range = chunks[c].clone();
        for (&k, &v) in h.idx.iter().zip(&h.val) {
            result[range.start + k as usize] += v;
        }
    }
    for r in 0..n - 1 {
        Arena::refill(
            grows,
            sp_sends,
            (0..n).map(|i| {
                let c = (i + 1 + n - r) % n;
                // The reduced chunk c travels in sparse format.
                let seg_density: f64 = held[(c + n - 1) % n].density();
                let nnz = ((chunks[c].len() as f64 * seg_density).round() as usize)
                    .min(chunks[c].len());
                crate::sparse::wire_bytes(
                    crate::sparse::WireFormat::cheapest(chunks[c].len(), nnz),
                    chunks[c].len(),
                    nnz,
                )
            }),
        );
        net.round(sp_sends);
    }

    (
        result,
        ReduceReport {
            bytes_per_node: per_node_delta(net, &before),
            seconds: net.clock() - t0,
            density_per_hop,
        },
    )
}

/// Final density after a full scatter-reduce for per-node density `d0`
/// under the independence approximation: 1 - (1 - d0)^N. The paper's
/// "top 1% becomes 2%" worst case is the small-d0 linear regime.
pub fn expected_final_density(d0: f64, n: usize) -> f64 {
    1.0 - (1.0 - d0).powi(n as i32)
}

/// Support-only sparse ring all-reduce — the fast path for large-model
/// density/bandwidth sims (96 nodes x 25M+ params), where the exact
/// value-merging path is O(N^2 * nnz) and per-node f32 state would be
/// tens of GB. Only the *supports* travel: per hop, a chunk's support is
/// OR-ed with the local node's support (word-at-a-time); wire bytes are
/// modelled from each segment's nnz with the same codec chooser the
/// exact path uses. Cross-validated against `allreduce` in tests.
pub fn allreduce_support(net: &mut RingNet, supports: &[crate::sparse::BitMask]) -> ReduceReport {
    allreduce_support_exec(net, supports, &Executor::sequential())
}

/// [`allreduce_support`] with the per-hop word-OR merges and codec
/// sizing fanned out over `exec`. The hop-density reduction stays on the
/// coordinating thread (node order), so reports are bit-identical.
pub fn allreduce_support_exec(
    net: &mut RingNet,
    supports: &[crate::sparse::BitMask],
    exec: &Executor,
) -> ReduceReport {
    allreduce_support_in(net, supports, exec, &mut Arena::new())
}

/// [`allreduce_support_exec`] against a caller-owned [`Arena`]: the
/// travelling word blocks ping-pong between two arena slot tables and
/// the per-hop copies/ORs reuse their buffers — zero steady-state
/// allocations once warm (DESIGN.md §9). Bit-identical to the other
/// entry points.
pub fn allreduce_support_in(
    net: &mut RingNet,
    supports: &[crate::sparse::BitMask],
    exec: &Executor,
    arena: &mut Arena,
) -> ReduceReport {
    use crate::sparse::BitMask;
    let n = net.n_nodes();
    assert_eq!(supports.len(), n);
    let len = supports[0].len();
    assert!(supports.iter().all(|s| s.len() == len));

    let Arena {
        grows,
        su_held,
        su_next,
        su_sends,
        su_chunks,
        ..
    } = arena;
    let grows: &std::sync::atomic::AtomicU64 = grows;
    let cap = su_chunks.capacity();
    chunk_ranges_aligned_into(len, n, su_chunks);
    Arena::note(grows, su_chunks.capacity() != cap);
    let chunks: &[Range<usize>] = su_chunks;
    Arena::slots(grows, su_held, n, Vec::new);
    Arena::slots(grows, su_next, n, Vec::new);

    let before = super::snapshot(net);
    let t0 = net.clock();

    // held[i] = travelling support words for the chunk node i holds.
    exec.map_mut(&mut su_held[..n], |i, h| {
        Arena::note(
            grows,
            Arena::refill_slice(h, supports[i].word_slice(chunks[i].clone())),
        );
    });
    let (mut held, mut next) = (su_held, su_next);
    let mut density_per_hop = Vec::with_capacity(n - 1);

    let seg_bytes = |words: &[u64], chunk_len: usize| -> u64 {
        let nnz = BitMask::popcount_words(words);
        crate::sparse::wire_bytes(
            crate::sparse::WireFormat::cheapest(chunk_len, nnz),
            chunk_len,
            nnz,
        )
    };

    for r in 0..n - 1 {
        // Byte sizing is a per-node popcount — far too cheap to amortize
        // a thread spawn; only the word-OR merges below fan out.
        Arena::refill(
            grows,
            su_sends,
            (0..n).map(|i| {
                let c = (i + n - r) % n;
                seg_bytes(&held[i], chunks[c].len())
            }),
        );
        net.round(su_sends);
        {
            let held_ref: &[Vec<u64>] = held;
            exec.map_mut(&mut next[..n], |dst, nx| {
                let src = (dst + n - 1) % n;
                let c = (dst + n - (r + 1)) % n;
                let own = supports[dst].word_slice(chunks[c].clone());
                Arena::note(grows, Arena::refill_slice(nx, &held_ref[src]));
                for (m, o) in nx.iter_mut().zip(own) {
                    *m |= o;
                }
            });
        }
        std::mem::swap(&mut held, &mut next);
        let (mut nnz, mut tot) = (0usize, 0usize);
        for (i, h) in held[..n].iter().enumerate() {
            let c = (i + n - (r + 1)) % n;
            nnz += BitMask::popcount_words(h);
            tot += chunks[c].len();
        }
        density_per_hop.push(nnz as f64 / tot.max(1) as f64);
    }

    // Allgather accounting at final densities (sizing only — sequential
    // for the same reason as above).
    for r in 0..n - 1 {
        Arena::refill(
            grows,
            su_sends,
            (0..n).map(|i| {
                let c = (i + 1 + n - r) % n;
                let holder = (c + n - 1) % n;
                seg_bytes(&held[holder], chunks[c].len())
            }),
        );
        net.round(su_sends);
    }

    ReduceReport {
        bytes_per_node: super::per_node_delta(net, &before),
        seconds: net.clock() - t0,
        density_per_hop,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> SparseVec {
        let mut dense = vec![0.0f32; len];
        for v in dense.iter_mut() {
            if (rng.uniform() as f64) < density {
                *v = rng.normal();
            }
        }
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn result_equals_dense_sum_property() {
        forall("sparse ring allreduce == sum", 30, |g| {
            let n = g.usize_in(2, 7);
            let len = g.usize_in(n, 80);
            let mut rng = Rng::new(g.case as u64 + 77);
            let inputs: Vec<SparseVec> = (0..n)
                .map(|_| random_sparse(&mut rng, len, 0.3))
                .collect();
            let mut expect = vec![0.0f32; len];
            for s in &inputs {
                s.scatter_add(&mut expect);
            }
            let mut nw = net(n);
            let (got, _) = allreduce(&mut nw, &inputs);
            for (a, b) in got.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn density_grows_per_hop() {
        let n = 8;
        let len = 8000;
        let mut rng = Rng::new(42);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.01))
            .collect();
        let mut nw = net(n);
        let (_, rep) = allreduce(&mut nw, &inputs);
        assert_eq!(rep.density_per_hop.len(), n - 1);
        // Strictly (statistically) increasing density.
        assert!(
            rep.density_per_hop.last().unwrap() > &(rep.density_per_hop[0] * 2.0),
            "{:?}",
            rep.density_per_hop
        );
        // Close to the independence model.
        let model = expected_final_density(0.01, n);
        let got = *rep.density_per_hop.last().unwrap();
        assert!(
            (got - model).abs() < model * 0.5,
            "got {got}, model {model}"
        );
    }

    #[test]
    fn sparse_beats_dense_bytes_when_sparse_enough() {
        let n = 4;
        let len = 40_000;
        let mut rng = Rng::new(1);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.001))
            .collect();
        let mut nw = net(n);
        let (_, rep) = allreduce(&mut nw, &inputs);
        let dense_cost = 2 * (n as u64 - 1) * (len as u64 * 4) / n as u64;
        assert!(rep.mean_bytes_per_node() < dense_cost as f64 / 10.0);
    }

    #[test]
    fn support_path_matches_exact_path() {
        let n = 6;
        let len = 3000;
        let mut rng = Rng::new(9);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.02))
            .collect();
        let supports: Vec<crate::sparse::BitMask> = inputs
            .iter()
            .map(|s| {
                let mut m = crate::sparse::BitMask::zeros(len);
                for &i in &s.idx {
                    m.set(i as usize);
                }
                m
            })
            .collect();
        let mut net_a = net(n);
        let (_, exact) = allreduce(&mut net_a, &inputs);
        let mut net_b = net(n);
        let fast = allreduce_support(&mut net_b, &supports);
        // Same hop count; same final density (chunking differs slightly
        // by word alignment, so allow a small relative gap).
        assert_eq!(exact.density_per_hop.len(), fast.density_per_hop.len());
        let (de, df) = (
            *exact.density_per_hop.last().unwrap(),
            *fast.density_per_hop.last().unwrap(),
        );
        assert!((de - df).abs() < de * 0.25, "{de} vs {df}");
        // Byte totals within 30% (alignment + codec-boundary effects).
        let (be, bf) = (exact.total_bytes() as f64, fast.total_bytes() as f64);
        assert!((be - bf).abs() < be * 0.3, "{be} vs {bf}");
    }

    #[test]
    fn expected_density_model() {
        assert!((expected_final_density(0.01, 2) - 0.0199).abs() < 1e-4);
        assert!(expected_final_density(0.01, 96) > 0.6);
        assert!(expected_final_density(0.5, 96) > 0.999);
    }
}
