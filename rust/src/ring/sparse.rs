//! Sparse ring all-reduce with **per-node supports** — DGC-on-a-ring.
//!
//! Each node contributes its own sparse gradient. During scatter-reduce a
//! travelling chunk segment accumulates the *union* of the supports it
//! passes through, so its nnz grows with every hop — the densification
//! the paper identifies as DGC's failure mode on rings (Sec. II: "as the
//! number of ring nodes increases, the gradient on each node becomes
//! denser as the ring reduce is performed").  `ReduceReport::
//! density_per_hop` quantifies it; `exp::density` plots it against N.

use super::{chunk_ranges, per_node_delta, snapshot, Executor, ReduceReport};
use crate::net::RingNet;
use crate::sparse::SparseVec;

/// All-reduce of per-node sparse gradients. Returns the summed dense
/// result (identical on every node) plus wire accounting; the travelling
/// segments stay in sparse wire format the whole way.
pub fn allreduce(net: &mut RingNet, inputs: &[SparseVec]) -> (Vec<f32>, ReduceReport) {
    allreduce_exec(net, inputs, &Executor::sequential())
}

/// [`allreduce`] with the per-hop segment extraction and sparse merges
/// fanned out over `exec` (one travelling segment per node, disjoint
/// state). Densities and byte counts are reduced on the coordinating
/// thread in node order, so reports are bit-identical to sequential.
pub fn allreduce_exec(
    net: &mut RingNet,
    inputs: &[SparseVec],
    exec: &Executor,
) -> (Vec<f32>, ReduceReport) {
    let n = net.n_nodes();
    assert_eq!(inputs.len(), n);
    let len = inputs[0].len;
    assert!(inputs.iter().all(|s| s.len == len));

    let chunks = chunk_ranges(len, n);
    let before = snapshot(net);
    let t0 = net.clock();

    // Segment (node i, chunk c) = node i's sparse slice of chunk c.
    let segment = |s: &SparseVec, c: usize| -> SparseVec {
        let range = &chunks[c];
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (&i, &v) in s.idx.iter().zip(&s.val) {
            let i = i as usize;
            if range.contains(&i) {
                idx.push((i - range.start) as u32);
                val.push(v);
            }
        }
        SparseVec {
            len: range.len(),
            idx,
            val,
        }
    };

    // held[i] = the travelling segment node i currently holds.
    // Initially node i holds its own slice of chunk i.
    let mut held: Vec<SparseVec> = exec.map_indexed(n, |i| segment(&inputs[i], i));
    let mut density_per_hop = Vec::with_capacity(n - 1);

    // Scatter-reduce: at round r node i holds the partial sum of chunk
    // (i - r); it sends it to i+1 which merges in its own slice.
    for r in 0..n - 1 {
        let sends: Vec<u64> = held.iter().map(|s| s.wire_bytes()).collect();
        net.round(&sends);
        let next: Vec<SparseVec> = exec.map_indexed(n, |dst| {
            let src = (dst + n - 1) % n;
            let c = (dst + n - (r + 1)) % n; // chunk arriving at dst
            let own = segment(&inputs[dst], c);
            held[src].merge_add(&own)
        });
        held = next;
        // Mean density of travelling segments after this hop.
        let d = held.iter().map(|s| s.density()).sum::<f64>() / n as f64;
        density_per_hop.push(d);
    }

    // Node i now holds the fully-reduced chunk (i + 1) % n.
    // Assemble the global dense result and run the allgather purely for
    // byte/time accounting (every node must end with every chunk).
    let mut result = vec![0.0f32; len];
    for i in 0..n {
        let c = (i + 1) % n;
        let range = chunks[c].clone();
        for (&k, &v) in held[i].idx.iter().zip(&held[i].val) {
            result[range.start + k as usize] += v;
        }
    }
    for r in 0..n - 1 {
        let sends: Vec<u64> = (0..n)
            .map(|i| {
                let c = (i + 1 + n - r) % n;
                // The reduced chunk c travels in sparse format.
                let seg_density: f64 = held[(c + n - 1) % n].density();
                let nnz = (chunks[c].len() as f64 * seg_density).round() as usize;
                SparseVec {
                    len: chunks[c].len(),
                    idx: vec![0; nnz.min(chunks[c].len())],
                    val: vec![0.0; nnz.min(chunks[c].len())],
                }
                .wire_bytes()
            })
            .collect();
        net.round(&sends);
    }

    (
        result,
        ReduceReport {
            bytes_per_node: per_node_delta(net, &before),
            seconds: net.clock() - t0,
            density_per_hop,
        },
    )
}

/// Final density after a full scatter-reduce for per-node density `d0`
/// under the independence approximation: 1 - (1 - d0)^N. The paper's
/// "top 1% becomes 2%" worst case is the small-d0 linear regime.
pub fn expected_final_density(d0: f64, n: usize) -> f64 {
    1.0 - (1.0 - d0).powi(n as i32)
}

/// Support-only sparse ring all-reduce — the fast path for large-model
/// density/bandwidth sims (96 nodes x 25M+ params), where the exact
/// value-merging path is O(N^2 * nnz) and per-node f32 state would be
/// tens of GB. Only the *supports* travel: per hop, a chunk's support is
/// OR-ed with the local node's support (word-at-a-time); wire bytes are
/// modelled from each segment's nnz with the same codec chooser the
/// exact path uses. Cross-validated against `allreduce` in tests.
pub fn allreduce_support(
    net: &mut RingNet,
    supports: &[crate::sparse::BitMask],
) -> ReduceReport {
    allreduce_support_exec(net, supports, &Executor::sequential())
}

/// [`allreduce_support`] with the per-hop word-OR merges and codec
/// sizing fanned out over `exec`. The hop-density reduction stays on the
/// coordinating thread (node order), so reports are bit-identical.
pub fn allreduce_support_exec(
    net: &mut RingNet,
    supports: &[crate::sparse::BitMask],
    exec: &Executor,
) -> ReduceReport {
    use crate::sparse::BitMask;
    let n = net.n_nodes();
    assert_eq!(supports.len(), n);
    let len = supports[0].len();
    assert!(supports.iter().all(|s| s.len() == len));

    let chunks = super::chunk_ranges_aligned(len, n);
    let before = super::snapshot(net);
    let t0 = net.clock();

    // held[i] = travelling support words for the chunk node i holds.
    let mut held: Vec<Vec<u64>> =
        exec.map_indexed(n, |i| supports[i].word_slice(chunks[i].clone()).to_vec());
    let mut density_per_hop = Vec::with_capacity(n - 1);

    let seg_bytes = |words: &[u64], chunk_len: usize| -> u64 {
        let nnz = BitMask::popcount_words(words);
        crate::sparse::wire_bytes(
            crate::sparse::WireFormat::cheapest(chunk_len, nnz),
            chunk_len,
            nnz,
        )
    };

    for r in 0..n - 1 {
        // Byte sizing is a per-node popcount — far too cheap to amortize
        // a thread spawn; only the word-OR merges below fan out.
        let sends: Vec<u64> = (0..n)
            .map(|i| {
                let c = (i + n - r) % n;
                seg_bytes(&held[i], chunks[c].len())
            })
            .collect();
        net.round(&sends);
        let next: Vec<Vec<u64>> = exec.map_indexed(n, |dst| {
            let src = (dst + n - 1) % n;
            let c = (dst + n - (r + 1)) % n;
            let own = supports[dst].word_slice(chunks[c].clone());
            let mut merged = held[src].clone();
            for (m, o) in merged.iter_mut().zip(own) {
                *m |= o;
            }
            merged
        });
        held = next;
        let (mut nnz, mut tot) = (0usize, 0usize);
        for (i, h) in held.iter().enumerate() {
            let c = (i + n - (r + 1)) % n;
            nnz += BitMask::popcount_words(h);
            tot += chunks[c].len();
        }
        density_per_hop.push(nnz as f64 / tot.max(1) as f64);
    }

    // Allgather accounting at final densities (sizing only — sequential
    // for the same reason as above).
    for r in 0..n - 1 {
        let sends: Vec<u64> = (0..n)
            .map(|i| {
                let c = (i + 1 + n - r) % n;
                let holder = (c + n - 1) % n;
                seg_bytes(&held[holder], chunks[c].len())
            })
            .collect();
        net.round(&sends);
    }

    ReduceReport {
        bytes_per_node: super::per_node_delta(net, &before),
        seconds: net.clock() - t0,
        density_per_hop,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    fn random_sparse(rng: &mut Rng, len: usize, density: f64) -> SparseVec {
        let mut dense = vec![0.0f32; len];
        for v in dense.iter_mut() {
            if (rng.uniform() as f64) < density {
                *v = rng.normal();
            }
        }
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn result_equals_dense_sum_property() {
        forall("sparse ring allreduce == sum", 30, |g| {
            let n = g.usize_in(2, 7);
            let len = g.usize_in(n, 80);
            let mut rng = Rng::new(g.case as u64 + 77);
            let inputs: Vec<SparseVec> = (0..n)
                .map(|_| random_sparse(&mut rng, len, 0.3))
                .collect();
            let mut expect = vec![0.0f32; len];
            for s in &inputs {
                s.scatter_add(&mut expect);
            }
            let mut nw = net(n);
            let (got, _) = allreduce(&mut nw, &inputs);
            for (a, b) in got.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn density_grows_per_hop() {
        let n = 8;
        let len = 8000;
        let mut rng = Rng::new(42);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.01))
            .collect();
        let mut nw = net(n);
        let (_, rep) = allreduce(&mut nw, &inputs);
        assert_eq!(rep.density_per_hop.len(), n - 1);
        // Strictly (statistically) increasing density.
        assert!(
            rep.density_per_hop.last().unwrap() > &(rep.density_per_hop[0] * 2.0),
            "{:?}",
            rep.density_per_hop
        );
        // Close to the independence model.
        let model = expected_final_density(0.01, n);
        let got = *rep.density_per_hop.last().unwrap();
        assert!(
            (got - model).abs() < model * 0.5,
            "got {got}, model {model}"
        );
    }

    #[test]
    fn sparse_beats_dense_bytes_when_sparse_enough() {
        let n = 4;
        let len = 40_000;
        let mut rng = Rng::new(1);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.001))
            .collect();
        let mut nw = net(n);
        let (_, rep) = allreduce(&mut nw, &inputs);
        let dense_cost = 2 * (n as u64 - 1) * (len as u64 * 4) / n as u64;
        assert!(rep.mean_bytes_per_node() < dense_cost as f64 / 10.0);
    }

    #[test]
    fn support_path_matches_exact_path() {
        let n = 6;
        let len = 3000;
        let mut rng = Rng::new(9);
        let inputs: Vec<SparseVec> = (0..n)
            .map(|_| random_sparse(&mut rng, len, 0.02))
            .collect();
        let supports: Vec<crate::sparse::BitMask> = inputs
            .iter()
            .map(|s| {
                let mut m = crate::sparse::BitMask::zeros(len);
                for &i in &s.idx {
                    m.set(i as usize);
                }
                m
            })
            .collect();
        let mut net_a = net(n);
        let (_, exact) = allreduce(&mut net_a, &inputs);
        let mut net_b = net(n);
        let fast = allreduce_support(&mut net_b, &supports);
        // Same hop count; same final density (chunking differs slightly
        // by word alignment, so allow a small relative gap).
        assert_eq!(exact.density_per_hop.len(), fast.density_per_hop.len());
        let (de, df) = (
            *exact.density_per_hop.last().unwrap(),
            *fast.density_per_hop.last().unwrap(),
        );
        assert!((de - df).abs() < de * 0.25, "{de} vs {df}");
        // Byte totals within 30% (alignment + codec-boundary effects).
        let (be, bf) = (
            exact.total_bytes() as f64,
            fast.total_bytes() as f64,
        );
        assert!((be - bf).abs() < be * 0.3, "{be} vs {bf}");
    }

    #[test]
    fn expected_density_model() {
        assert!((expected_final_density(0.01, 2) - 0.0199).abs() < 1e-4);
        assert!(expected_final_density(0.01, 96) > 0.6);
        assert!(expected_final_density(0.5, 96) > 0.999);
    }
}
