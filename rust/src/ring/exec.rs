//! Node-parallel execution engine (DESIGN.md §4).
//!
//! Every ring schedule and both engines (`exp::simrun::SimEngine`,
//! `coordinator::Trainer`) do per-node work — importance scoring,
//! residual accumulation, DGC/TernGrad encoding, per-hop chunk merges —
//! that is embarrassingly parallel across nodes but was historically run
//! on one thread, making wall-clock scale linearly with ring size. The
//! [`Executor`] fans that work out over a small pool of scoped OS
//! threads (`std::thread::scope`; rayon is not available offline) while
//! keeping results **bit-identical** to the sequential path:
//!
//! * work is partitioned into contiguous per-worker blocks with
//!   [`super::chunk_ranges`], and outputs are concatenated in block
//!   order, so output order never depends on thread scheduling;
//! * each parallel region only mutates disjoint per-node state (one
//!   node's buffer/store/RNG per closure invocation);
//! * all cross-node reductions (float sums, stat merges, the virtual
//!   clock) stay on the coordinating thread, in node order, exactly as
//!   the sequential path performs them;
//! * wire accounting goes through `RingNet`'s per-node atomic counters,
//!   whose per-node totals are order-independent (u64 addition).
//!
//! `Executor::new(1)` is the sequential oracle: it runs every closure
//! inline on the caller's thread, with no pool, and is the reference the
//! equivalence tests (`tests/parallel_equivalence.rs`) compare against.

/// A fixed-width fork/join executor for per-node work.
///
/// Cheap to construct (no persistent pool: scoped threads are spawned
/// per region, which for the multi-millisecond regions of the 25M+
/// parameter sims is noise) and trivially `Clone`.
#[derive(Debug, Clone)]
pub struct Executor {
    workers: usize,
}

impl Executor {
    /// An executor running work on `parallelism` threads.
    /// `parallelism = 1` (or 0, clamped) executes inline — the
    /// deterministic sequential oracle.
    pub fn new(parallelism: usize) -> Self {
        Executor {
            workers: parallelism.max(1),
        }
    }

    /// The inline sequential oracle (`parallelism = 1`).
    pub fn sequential() -> Self {
        Executor::new(1)
    }

    /// Number of worker threads this executor fans out to.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether this executor runs inline (no threads spawned).
    pub fn is_sequential(&self) -> bool {
        self.workers == 1
    }

    /// Parallel map over indices `0..n`: returns `[f(0), f(1), …]` in
    /// index order regardless of scheduling.
    pub fn map_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.workers == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let blocks = super::chunk_ranges(n, self.workers.min(n));
        std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = blocks
                .into_iter()
                .filter(|r| !r.is_empty())
                .map(|r| scope.spawn(move || r.map(f).collect::<Vec<T>>()))
                .collect();
            let mut out = Vec::with_capacity(n);
            for h in handles {
                out.extend(h.join().expect("executor worker panicked"));
            }
            out
        })
    }

    /// Parallel mutate-and-map over a slice: each element is visited
    /// exactly once with its index, and the per-element results are
    /// returned in element order. The per-node reduce/compress loops use
    /// this to mutate disjoint node states (buffers, residual stores,
    /// RNG streams) concurrently.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        if self.workers == 1 || n <= 1 {
            return items
                .iter_mut()
                .enumerate()
                .map(|(i, item)| f(i, item))
                .collect();
        }
        let blocks = super::chunk_ranges(n, self.workers.min(n));
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = items;
            let mut handles = Vec::with_capacity(blocks.len());
            for r in blocks {
                let (head, tail) = rest.split_at_mut(r.len());
                rest = tail;
                if r.is_empty() {
                    continue;
                }
                let base = r.start;
                handles.push(scope.spawn(move || {
                    head.iter_mut()
                        .enumerate()
                        .map(|(k, item)| f(base + k, item))
                        .collect::<Vec<R>>()
                }));
            }
            let mut out = Vec::with_capacity(n);
            for h in handles {
                out.extend(h.join().expect("executor worker panicked"));
            }
            out
        })
    }

    /// Like [`Executor::map_mut`] but over two equal-length slices
    /// zipped: `f(i, &mut a[i], &mut b[i])`. Used where one node's step
    /// touches two state arrays at once (e.g. gradient buffer + RNG
    /// stream in `exp::simrun`).
    pub fn map_mut2<A, B, R, F>(&self, a: &mut [A], b: &mut [B], f: F) -> Vec<R>
    where
        A: Send,
        B: Send,
        R: Send,
        F: Fn(usize, &mut A, &mut B) -> R + Sync,
    {
        assert_eq!(a.len(), b.len(), "map_mut2 slices must zip exactly");
        let n = a.len();
        if self.workers == 1 || n <= 1 {
            return a
                .iter_mut()
                .zip(b.iter_mut())
                .enumerate()
                .map(|(i, (x, y))| f(i, x, y))
                .collect();
        }
        let blocks = super::chunk_ranges(n, self.workers.min(n));
        std::thread::scope(|scope| {
            let f = &f;
            let (mut rest_a, mut rest_b) = (a, b);
            let mut handles = Vec::with_capacity(blocks.len());
            for r in blocks {
                let (head_a, tail_a) = rest_a.split_at_mut(r.len());
                let (head_b, tail_b) = rest_b.split_at_mut(r.len());
                rest_a = tail_a;
                rest_b = tail_b;
                if r.is_empty() {
                    continue;
                }
                let base = r.start;
                handles.push(scope.spawn(move || {
                    head_a
                        .iter_mut()
                        .zip(head_b.iter_mut())
                        .enumerate()
                        .map(|(k, (x, y))| f(base + k, x, y))
                        .collect::<Vec<R>>()
                }));
            }
            let mut out = Vec::with_capacity(n);
            for h in handles {
                out.extend(h.join().expect("executor worker panicked"));
            }
            out
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_preserves_order() {
        for workers in [1, 2, 4, 8] {
            let exec = Executor::new(workers);
            let got = exec.map_indexed(100, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn map_mut_visits_each_once_in_order() {
        for workers in [1, 3, 7] {
            let exec = Executor::new(workers);
            let mut xs = vec![0u64; 57];
            let idx = exec.map_mut(&mut xs, |i, x| {
                *x += 1;
                i
            });
            assert!(xs.iter().all(|&x| x == 1), "workers={workers}");
            assert_eq!(idx, (0..57).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_mut2_zips_disjoint_state() {
        let exec = Executor::new(4);
        let mut a = vec![1u32; 33];
        let mut b = vec![2u32; 33];
        let sums = exec.map_mut2(&mut a, &mut b, |i, x, y| {
            *x += i as u32;
            *y += *x;
            *y
        });
        for (i, &s) in sums.iter().enumerate() {
            assert_eq!(s, 2 + 1 + i as u32);
        }
    }

    #[test]
    fn parallel_matches_sequential_bitwise() {
        // Float work partitioned per element is bit-identical across
        // worker counts (no cross-element reduction happens off the
        // coordinator).
        let inputs: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let seq = Executor::sequential().map_indexed(1000, |i| inputs[i].exp().to_bits());
        for workers in [2, 4, 8] {
            let par = Executor::new(workers).map_indexed(1000, |i| inputs[i].exp().to_bits());
            assert_eq!(seq, par, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_degenerate_sizes() {
        let exec = Executor::new(8);
        assert_eq!(exec.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(exec.map_indexed(1, |i| i), vec![0]);
        // More workers than items.
        assert_eq!(exec.map_indexed(3, |i| i), vec![0, 1, 2]);
        let mut xs: [u8; 0] = [];
        assert_eq!(exec.map_mut(&mut xs, |_, _| 0u8), Vec::<u8>::new());
    }

    #[test]
    fn zero_parallelism_clamps_to_sequential() {
        let exec = Executor::new(0);
        assert!(exec.is_sequential());
        assert_eq!(exec.workers(), 1);
    }

    #[test]
    #[should_panic(expected = "zip exactly")]
    fn map_mut2_rejects_length_mismatch() {
        let exec = Executor::new(2);
        let mut a = [0u8; 3];
        let mut b = [0u8; 4];
        let _ = exec.map_mut2(&mut a, &mut b, |_, _, _| ());
    }
}
