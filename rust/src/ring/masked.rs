//! Algorithm 1's transport: shared-mask ring all-reduce.
//!
//! ```text
//! choose random nodes r_1..r_k
//! Mask_{r_i} <- |∇w_r / w_r| > thr            (computed by the caller)
//! AllGather(encode_uint8(Mask_{r_i}))          (mask bytes on the wire)
//! Mask = OR_i Mask_{r_i}                       (identical on every node)
//! ring all-reduce of (∇w ⊙ Mask), compacted to the mask support
//! ```
//!
//! Because every node reduces the *same* support, the travelling chunks
//! never densify — the sparsity is invariant in N, which is the paper's
//! structural advantage over DGC on rings.

use super::{dense, Arena, Executor, ReduceReport};
use crate::net::RingNet;
use crate::sparse::{values_only_bytes, BitMask};

/// Byte cost of AllGather-ing `k` masks of `mask_bytes` each around an
/// N-ring: each blob crosses N-1 links.
pub fn mask_allgather_bytes(mask_bytes: u64, k: usize, n: usize) -> u64 {
    mask_bytes * k as u64 * (n as u64 - 1)
}

/// Shared-mask all-reduce.
///
/// * `masks` — the masks of the `r` randomly-chosen broadcaster nodes
///   (already computed from their local importance scores).
/// * `values` — per node, the residual values at *every* coordinate
///   (the schedule gathers the mask support itself).
///
/// Returns `(shared_mask, summed_masked_values_compacted, report)`:
/// the summed values are aligned with `shared_mask.iter_set()` order.
pub fn allreduce(
    net: &mut RingNet,
    masks: &[&BitMask],
    values: &[&[f32]],
) -> (BitMask, Vec<f32>, ReduceReport) {
    allreduce_exec(net, masks, values, &Executor::sequential())
}

/// [`allreduce`] with the per-node support compaction and the dense
/// value rounds fanned out over `exec`. Bit-identical to sequential:
/// compaction is a pure per-node gather and the dense schedule already
/// guarantees equivalence.
pub fn allreduce_exec(
    net: &mut RingNet,
    masks: &[&BitMask],
    values: &[&[f32]],
    exec: &Executor,
) -> (BitMask, Vec<f32>, ReduceReport) {
    allreduce_in(net, masks, values, exec, &mut Arena::new())
}

/// [`allreduce_exec`] against a caller-owned [`Arena`]: the mask blobs,
/// the shared-support index table, the per-node compacted value buffers,
/// and the dense value rounds' staging all live in the arena's reusable
/// buffers, so the per-round/per-hop loop allocates nothing once warm
/// (DESIGN.md §9). The *outputs* still allocate per call — the shared
/// mask, the report, and the returned summed vector (cloned out of the
/// arena slot so the warm buffer stays behind for the next call).
/// Bit-identical to the other entry points.
pub fn allreduce_in(
    net: &mut RingNet,
    masks: &[&BitMask],
    values: &[&[f32]],
    exec: &Executor,
    arena: &mut Arena,
) -> (BitMask, Vec<f32>, ReduceReport) {
    let n = net.n_nodes();
    assert_eq!(values.len(), n);
    assert!(!masks.is_empty(), "need at least one mask broadcaster");
    let len = masks[0].len();
    assert!(values.iter().all(|v| v.len() == len));

    let Arena {
        grows,
        mk_blobs,
        mk_support,
        mk_compact,
        ag_sends,
        dense_staging,
        dense_sends,
        dense_chunks,
        ..
    } = arena;
    let grows: &std::sync::atomic::AtomicU64 = grows;

    // Phase 1 — mask AllGather (Alg. 1 line 7): each broadcaster's
    // encoded mask travels N-1 hops. We account it as an allgather of k
    // blobs; non-broadcasters contribute zero-byte blobs.
    let mask_bytes = masks[0].wire_bytes();
    let k = masks.len().min(n);
    let t0 = net.clock();
    let before: Vec<u64> = (0..n).map(|i| net.node_tx_bytes(i)).collect();
    let blob_sizes = (0..n).map(|i| if i < k { mask_bytes } else { 0 });
    Arena::allgather_into(net, grows, mk_blobs, ag_sends, blob_sizes);

    // Phase 2 — OR-combine (identical on every node).
    let mut shared = BitMask::zeros(len);
    for m in masks {
        assert_eq!(m.len(), len);
        shared.or_assign(m);
    }

    // Phase 3 — compact every node's values to the shared support and
    // dense-ring-allreduce the compacted vectors (values only: the
    // support is known to all).
    Arena::refill(grows, mk_support, shared.iter_set());
    Arena::slots(grows, mk_compact, n, Vec::new);
    {
        let support: &[usize] = mk_support;
        exec.map_mut(&mut mk_compact[..n], |node, c| {
            let cap = c.capacity();
            c.clear();
            c.extend(support.iter().map(|&i| values[node][i]));
            Arena::note(grows, c.capacity() != cap);
        });
    }
    let dense_rep = dense::allreduce_parts(
        net,
        &mut mk_compact[..n],
        exec,
        grows,
        dense_staging,
        dense_sends,
        dense_chunks,
    );

    // Validate accounting matches the values-only wire model (loosely:
    // the dense schedule moves 2(N-1)/N of the compact payload).
    debug_assert!({
        let expect =
            2.0 * (n as f64 - 1.0) / n as f64 * values_only_bytes(mk_support.len()) as f64;
        dense_rep.mean_bytes_per_node() <= expect + 64.0 * n as f64 + 1.0
    });

    let report = ReduceReport {
        bytes_per_node: (0..n)
            .map(|i| net.node_tx_bytes(i) - before[i])
            .collect(),
        seconds: net.clock() - t0,
        density_per_hop: vec![shared.density(); n.saturating_sub(1)],
    };
    (shared, mk_compact[0].clone(), report)
}

/// Accounting-only variant of [`allreduce`] for large-scale bandwidth
/// sims: performs the mask AllGather + OR and models the compacted value
/// rounds' bytes/time on the net, without moving value data (the callers
/// — `exp::simrun` at 96 nodes x 25M+ params — discard the summed values
/// anyway). Byte accounting is identical to the exact path.
pub fn allreduce_bytes_only(net: &mut RingNet, masks: &[&BitMask]) -> (BitMask, ReduceReport) {
    allreduce_bytes_only_in(net, masks, &mut Arena::new())
}

/// [`allreduce_bytes_only`] against a caller-owned [`Arena`] — the big
/// sims' per-step hot path, zero steady-state allocations once warm
/// (DESIGN.md §9). Bit-identical to [`allreduce_bytes_only`].
pub fn allreduce_bytes_only_in(
    net: &mut RingNet,
    masks: &[&BitMask],
    arena: &mut Arena,
) -> (BitMask, ReduceReport) {
    let n = net.n_nodes();
    assert!(!masks.is_empty());
    let len = masks[0].len();

    let mask_bytes = masks[0].wire_bytes();
    let k = masks.len().min(n);
    let t0 = net.clock();
    let before: Vec<u64> = (0..n).map(|i| net.node_tx_bytes(i)).collect();
    {
        let Arena {
            grows,
            mk_blobs,
            ag_sends,
            ..
        } = &mut *arena;
        let blob_sizes = (0..n).map(|i| if i < k { mask_bytes } else { 0 });
        Arena::allgather_into(net, grows, mk_blobs, ag_sends, blob_sizes);
    }

    let mut shared = BitMask::zeros(len);
    for m in masks {
        assert_eq!(m.len(), len);
        shared.or_assign(m);
    }

    // Dense-equivalent rounds over the compacted support (bytes/time
    // only) — the same rotation sequence as the exact schedule, shared
    // with the Baseline arm's accounting engine.
    dense::rounds_bytes_only(net, shared.count(), arena);

    let report = ReduceReport {
        bytes_per_node: (0..n)
            .map(|i| net.node_tx_bytes(i) - before[i])
            .collect(),
        seconds: net.clock() - t0,
        density_per_hop: vec![shared.density(); n.saturating_sub(1)],
    };
    (shared, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::LinkSpec;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn net(n: usize) -> RingNet {
        RingNet::new(n, LinkSpec::new(1e9, 0.0), 1.0)
    }

    #[test]
    fn reduces_masked_sum() {
        let n = 3;
        let len = 6;
        let mut m = BitMask::zeros(len);
        m.set(1);
        m.set(4);
        let vals: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..len).map(|j| (i * 10 + j) as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = vals.iter().map(|v| v.as_slice()).collect();
        let mut nw = net(n);
        let (shared, summed, _) = allreduce(&mut nw, &[&m], &refs);
        assert_eq!(shared.iter_set().collect::<Vec<_>>(), vec![1, 4]);
        // coord1: 1 + 11 + 21 = 33 ; coord4: 4 + 14 + 24 = 42
        assert_eq!(summed, vec![33.0, 42.0]);
    }

    #[test]
    fn or_of_multiple_masks() {
        let len = 10;
        let mut a = BitMask::zeros(len);
        a.set(0);
        let mut b = BitMask::zeros(len);
        b.set(9);
        let vals = vec![vec![1.0f32; len]; 2];
        let refs: Vec<&[f32]> = vals.iter().map(|v| v.as_slice()).collect();
        let mut nw = net(2);
        let (shared, summed, _) = allreduce(&mut nw, &[&a, &b], &refs);
        assert_eq!(shared.count(), 2);
        assert_eq!(summed, vec![2.0, 2.0]);
    }

    #[test]
    fn sparsity_invariant_in_ring_size() {
        // The paper's key claim: unlike DGC, density does not grow with N.
        let len = 10_000;
        let mut rng = Rng::new(3);
        let mut mask = BitMask::zeros(len);
        for _ in 0..100 {
            mask.set(rng.below(len));
        }
        let d0 = mask.density();
        for n in [4, 16, 64] {
            let vals = vec![vec![1.0f32; len]; n];
            let refs: Vec<&[f32]> = vals.iter().map(|v| v.as_slice()).collect();
            let mut nw = net(n);
            let (_, _, rep) = allreduce(&mut nw, &[&mask], &refs);
            for &d in &rep.density_per_hop {
                assert!((d - d0).abs() < 1e-12, "density changed with n={n}");
            }
        }
    }

    #[test]
    fn matches_dense_on_masked_coords_property() {
        forall("masked reduce == dense sum on support", 30, |g| {
            let n = g.usize_in(2, 6);
            let len = g.usize_in(4, 60);
            let mut mask = BitMask::zeros(len);
            for i in 0..len {
                if g.bool() {
                    mask.set(i);
                }
            }
            let vals: Vec<Vec<f32>> =
                (0..n).map(|_| g.vec_normal(len, 0.0, 1.0)).collect();
            let refs: Vec<&[f32]> = vals.iter().map(|v| v.as_slice()).collect();
            let mut nw = net(n);
            let (shared, summed, _) = allreduce(&mut nw, &[&mask], &refs);
            for (k, i) in shared.iter_set().enumerate() {
                let direct: f32 = vals.iter().map(|v| v[i]).sum();
                assert!(
                    (summed[k] - direct).abs() < 1e-3,
                    "coord {i}: {} vs {direct}",
                    summed[k]
                );
            }
        });
    }

    #[test]
    fn wire_bytes_scale_with_support_not_len() {
        let len = 100_000;
        let mut mask = BitMask::zeros(len);
        for i in 0..100 {
            mask.set(i * 997 % len);
        }
        let n = 8;
        let vals = vec![vec![1.0f32; len]; n];
        let refs: Vec<&[f32]> = vals.iter().map(|v| v.as_slice()).collect();
        let mut nw = net(n);
        let (_, _, rep) = allreduce(&mut nw, &[&mask], &refs);
        // Mask allgather dominates here: ~12.5 KB * (n-1). Value rounds are
        // ~100 floats. Total far below dense 2(N-1)/N * 400KB = 700KB.
        assert!(
            rep.mean_bytes_per_node() < 40_000.0,
            "{}",
            rep.mean_bytes_per_node()
        );
    }

    #[test]
    fn bytes_only_matches_exact_path_accounting() {
        let n = 5;
        let len = 4000;
        let mut rng = Rng::new(11);
        let mut mask = BitMask::zeros(len);
        for _ in 0..200 {
            mask.set(rng.below(len));
        }
        let vals = vec![vec![0.5f32; len]; n];
        let refs: Vec<&[f32]> = vals.iter().map(|v| v.as_slice()).collect();
        let mut net_a = net(n);
        let (shared_a, _, rep_a) = allreduce(&mut net_a, &[&mask], &refs);
        let mut net_b = net(n);
        let (shared_b, rep_b) = allreduce_bytes_only(&mut net_b, &[&mask]);
        assert_eq!(shared_a, shared_b);
        assert_eq!(rep_a.total_bytes(), rep_b.total_bytes());
    }

    #[test]
    fn mask_allgather_byte_model() {
        assert_eq!(mask_allgather_bytes(1000, 3, 5), 1000 * 3 * 4);
    }
}
