//! Staging arena for the ring hot paths (DESIGN.md §9).
//!
//! Every ring schedule does per-hop buffer work: the dense schedule
//! stages one chunk copy per node per round, the sparse schedule
//! extracts and union-merges one travelling segment per node per hop,
//! the support-only path clones one word block per node per hop, and
//! the masked schedule compacts every node's values to the shared
//! support. Before this arena existed each of those was a fresh `Vec`
//! per hop — O(N) allocations per round, O(N²) per all-reduce — which
//! dominated the steady-state loop of the big sims.
//!
//! The [`Arena`] owns all of that scratch as preallocated per-node
//! buffers. The `*_in` schedule variants (`ring::dense::allreduce_in`
//! and friends) thread a caller-owned arena through every hop and refill
//! buffers in place, so once the arena is warm the sequential reduce
//! loop performs **zero heap allocations** (with `parallelism > 1` the
//! executor's fork/join still spawns scoped threads and allocates its
//! block/handle tables per region — see `ring::exec`; the arena removes
//! the *data-buffer* churn in every configuration). Reuse is observable:
//! [`Arena::grows`] counts every internal buffer (re)allocation, and
//! `tests/parallel_equivalence.rs` pins the counter flat across
//! steady-state iterations.
//!
//! The arena is scratch, not state: no schedule reads a value another
//! call left behind, so one arena can serve every schedule of an engine
//! (`SimEngine` and `Trainer` each own exactly one). Buffers are only
//! ever filled on the coordinating thread or through the executor's
//! disjoint per-node closures, so the bit-identical parallel contract
//! (DESIGN.md §4) is unchanged.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::sparse::SparseVec;

/// Reusable per-node scratch for the ring schedules (DESIGN.md §9).
///
/// Construct once per engine ([`Arena::for_nodes`] pre-sizes the
/// per-node slot tables) and pass to the `*_in` schedule entry points.
/// [`Arena::grows`] exposes the internal (re)allocation count so tests
/// and benches can assert the steady state allocates nothing.
#[derive(Debug, Default)]
pub struct Arena {
    pub(crate) grows: AtomicU64,
    // -- dense schedule (also the masked schedule's value rounds) --
    pub(crate) dense_staging: Vec<Vec<f32>>,
    pub(crate) dense_sends: Vec<u64>,
    pub(crate) dense_chunks: Vec<Range<usize>>,
    // -- sparse exact schedule --
    pub(crate) sp_held: Vec<SparseVec>,
    pub(crate) sp_next: Vec<SparseVec>,
    pub(crate) sp_segs: Vec<SparseVec>,
    pub(crate) sp_sends: Vec<u64>,
    pub(crate) sp_chunks: Vec<Range<usize>>,
    // -- support-only sparse schedule --
    pub(crate) su_held: Vec<Vec<u64>>,
    pub(crate) su_next: Vec<Vec<u64>>,
    pub(crate) su_sends: Vec<u64>,
    pub(crate) su_chunks: Vec<Range<usize>>,
    // -- masked schedule + ring allgathers --
    pub(crate) mk_blobs: Vec<u64>,
    pub(crate) mk_support: Vec<usize>,
    pub(crate) mk_compact: Vec<Vec<f32>>,
    pub(crate) mk_chunk_bytes: Vec<u64>,
    pub(crate) ag_sends: Vec<u64>,
    // -- topology schedules (`net::topo`, DESIGN.md §10) --
    // Chunk partitions: full-size intra-group, ragged last group, and
    // the inter-group leader partition.
    pub(crate) tp_chunks_a: Vec<Range<usize>>,
    pub(crate) tp_chunks_b: Vec<Range<usize>>,
    pub(crate) tp_chunks_c: Vec<Range<usize>>,
    // Hierarchical sparse: per-group assembled sums and the leader-ring
    // travelling-segment ping-pong tables.
    pub(crate) tp_sums: Vec<SparseVec>,
    pub(crate) tp_lheld: Vec<SparseVec>,
    pub(crate) tp_lnext: Vec<SparseVec>,
    // Hierarchical support-only: word-block mirrors of the above.
    pub(crate) tp_wsums: Vec<Vec<u64>>,
    pub(crate) tp_wheld: Vec<Vec<u64>>,
    pub(crate) tp_wnext: Vec<Vec<u64>>,
    // Layer-pipelined wrapper (`net::topo::pipeline`, DESIGN.md §11):
    // per-node chunk staging the wrapper hands to the inner topology's
    // schedule while the rest of the arena stays free for that schedule.
    pub(crate) pl_bufs: Vec<Vec<f32>>,
}

impl Arena {
    /// An empty arena; every buffer warms up on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// An arena with the per-node slot tables pre-sized for an `n`-node
    /// ring (the inner data buffers still size themselves on the first
    /// pass — their lengths are payload-dependent). Slot pre-sizing here
    /// does not count toward [`Arena::grows`].
    pub fn for_nodes(n: usize) -> Self {
        let mut a = Arena::new();
        a.dense_staging.resize_with(n, Vec::new);
        a.sp_held.resize_with(n, || SparseVec::empty(0));
        a.sp_next.resize_with(n, || SparseVec::empty(0));
        a.sp_segs.resize_with(n, || SparseVec::empty(0));
        a.su_held.resize_with(n, Vec::new);
        a.su_next.resize_with(n, Vec::new);
        a.mk_compact.resize_with(n, Vec::new);
        a.dense_sends.reserve(n);
        a.sp_sends.reserve(n);
        a.su_sends.reserve(n);
        a.mk_blobs.reserve(n);
        a.mk_chunk_bytes.reserve(n);
        a.ag_sends.reserve(n);
        a.dense_chunks.reserve(n);
        a.sp_chunks.reserve(n);
        a.su_chunks.reserve(n);
        a.tp_chunks_a.reserve(n);
        a.tp_chunks_b.reserve(n);
        a.tp_chunks_c.reserve(n);
        a.tp_sums.resize_with(n, || SparseVec::empty(0));
        a.tp_lheld.resize_with(n, || SparseVec::empty(0));
        a.tp_lnext.resize_with(n, || SparseVec::empty(0));
        a.tp_wsums.resize_with(n, Vec::new);
        a.tp_wheld.resize_with(n, Vec::new);
        a.tp_wnext.resize_with(n, Vec::new);
        a.pl_bufs.resize_with(n, Vec::new);
        a
    }

    /// Number of internal buffer (re)allocations so far. Flat across
    /// iterations of a warmed steady-state loop — the zero-alloc
    /// contract the arena tests pin.
    pub fn grows(&self) -> u64 {
        self.grows.load(Ordering::Relaxed)
    }

    /// Record a (re)allocation event when `grew` is set. Callable from
    /// executor workers (`&AtomicU64`).
    #[inline]
    pub(crate) fn note(grows: &AtomicU64, grew: bool) {
        if grew {
            grows.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Refill `buf` from an iterator, reusing its capacity; notes growth.
    pub(crate) fn refill<T>(grows: &AtomicU64, buf: &mut Vec<T>, src: impl Iterator<Item = T>) {
        let cap = buf.capacity();
        buf.clear();
        buf.extend(src);
        Self::note(grows, buf.capacity() != cap);
    }

    /// Refill `buf` from a slice, reusing its capacity. Returns whether
    /// the buffer had to reallocate (callers inside executor closures
    /// note it themselves).
    pub(crate) fn refill_slice<T: Copy>(buf: &mut Vec<T>, src: &[T]) -> bool {
        let cap = buf.capacity();
        buf.clear();
        buf.extend_from_slice(src);
        buf.capacity() != cap
    }

    /// Ensure `v` has at least `n` slots (constructed with `mk`),
    /// keeping any existing slots' warm buffers; notes growth.
    pub(crate) fn slots<T>(grows: &AtomicU64, v: &mut Vec<T>, n: usize, mk: impl FnMut() -> T) {
        let cap = v.capacity();
        if v.len() < n {
            v.resize_with(n, mk);
        }
        Self::note(grows, v.capacity() != cap);
    }

    /// Ring-allgather `src`'s per-node blob sizes on `net` through the
    /// arena's blob/send buffers, owning the refill and the growth
    /// accounting in one place (four call sites share this exact dance).
    pub(crate) fn allgather_into(
        net: &mut crate::net::RingNet,
        grows: &AtomicU64,
        blobs: &mut Vec<u64>,
        sends: &mut Vec<u64>,
        src: impl Iterator<Item = u64>,
    ) {
        Self::refill(grows, blobs, src);
        let cap = sends.capacity();
        net.allgather_with(blobs, sends);
        Self::note(grows, sends.capacity() != cap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refill_reuses_capacity_and_counts_growth() {
        let grows = AtomicU64::new(0);
        let mut buf: Vec<u64> = Vec::new();
        Arena::refill(&grows, &mut buf, 0..16);
        assert_eq!(buf.len(), 16);
        let after_warmup = grows.load(Ordering::Relaxed);
        assert!(after_warmup >= 1, "first fill must count as growth");
        for _ in 0..10 {
            Arena::refill(&grows, &mut buf, 0..16);
        }
        assert_eq!(grows.load(Ordering::Relaxed), after_warmup);
        // A strictly larger refill grows again.
        Arena::refill(&grows, &mut buf, 0..64);
        assert_eq!(grows.load(Ordering::Relaxed), after_warmup + 1);
    }

    #[test]
    fn refill_slice_reports_growth_exactly_once() {
        let mut buf: Vec<f32> = Vec::new();
        let src = [1.0f32, 2.0, 3.0];
        assert!(Arena::refill_slice(&mut buf, &src));
        assert_eq!(buf, src);
        assert!(!Arena::refill_slice(&mut buf, &src));
        assert!(!Arena::refill_slice(&mut buf, &src[..1]));
        assert_eq!(buf, [1.0]);
    }

    #[test]
    fn slots_keeps_existing_and_never_shrinks() {
        let grows = AtomicU64::new(0);
        let mut v: Vec<Vec<u8>> = Vec::new();
        Arena::slots(&grows, &mut v, 4, Vec::new);
        assert_eq!(v.len(), 4);
        v[2].push(7); // warm one slot
        Arena::slots(&grows, &mut v, 2, Vec::new);
        assert_eq!(v.len(), 4, "slots never shrink");
        assert_eq!(v[2], vec![7], "warm buffers survive");
        let g = grows.load(Ordering::Relaxed);
        Arena::slots(&grows, &mut v, 4, Vec::new);
        assert_eq!(grows.load(Ordering::Relaxed), g);
    }

    #[test]
    fn for_nodes_presizes_without_counting_growth() {
        let a = Arena::for_nodes(8);
        assert_eq!(a.grows(), 0);
        assert_eq!(a.dense_staging.len(), 8);
        assert_eq!(a.sp_held.len(), 8);
        assert_eq!(a.mk_compact.len(), 8);
        assert!(a.dense_sends.capacity() >= 8);
    }
}
