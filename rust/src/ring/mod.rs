//! Ring all-reduce schedules over the virtual network.
//!
//! Three schedules, one per gradient representation:
//!
//! * [`dense`] — classic scatter-reduce + allgather on raw f32 chunks
//!   (Gibiansky/Baidu; the paper's baseline transport).
//! * [`sparse`] — per-node sparse supports (DGC on a ring): chunk
//!   segments *union* as they travel, demonstrating the densification
//!   the paper argues makes DGC lose "the meaning of spreading the
//!   sparse gradient" (Sec. II).
//! * [`masked`] — Algorithm 1: a shared mask is OR-built from `r`
//!   randomly chosen nodes via AllGather, then values ride the dense
//!   schedule *compacted to the mask support* — sparsity is ring-size
//!   invariant, which is the paper's key structural fix.
//!
//! All schedules move real data (the reduce is exact, tested against
//! direct summation) *and* account every wire byte on the `RingNet`.
//!
//! Each schedule has two entry points: the plain function (sequential)
//! and an `_exec` variant taking a [`exec::Executor`] that fans the
//! per-node work (staging copies, sparse merges, mask compaction) out
//! across worker threads with bit-identical results (DESIGN.md §4).
//!
//! These are the **flat-ring** schedules. The topology subsystem
//! (`net::topo`, DESIGN.md §10) wraps them behind the
//! [`Topology`](crate::net::Topology) trait alongside hierarchical and
//! binomial-tree implementations; `FlatRing` delegates here verbatim,
//! so the flat topology stays bit-identical to these entry points.
//!
//! They are also the specification for the **real** transport: the
//! socket ring (`net::wire`, DESIGN.md §13) frames and relays each
//! schedule's traveling payloads over actual UDS/TCP connections, and
//! the transport-equivalence oracle pins its step reports bit-exact
//! to the virtual schedules here.

pub mod arena;
pub mod dense;
pub mod exec;
pub mod masked;
pub mod sparse;

pub use arena::Arena;
pub use exec::Executor;

use crate::net::RingNet;

/// Outcome of one all-reduce: per-node wire accounting plus timing.
#[derive(Debug, Clone, Default)]
pub struct ReduceReport {
    /// Bytes transmitted by each node during this all-reduce.
    pub bytes_per_node: Vec<u64>,
    /// Virtual seconds the all-reduce took.
    pub seconds: f64,
    /// For sparse schedules: density of the travelling chunks after each
    /// scatter-reduce hop (the §II density-growth measurement).
    pub density_per_hop: Vec<f64>,
}

impl ReduceReport {
    /// Total bytes transmitted across all nodes during this all-reduce.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_per_node.iter().sum()
    }

    /// Mean per-node transmitted bytes (0 for an empty report).
    pub fn mean_bytes_per_node(&self) -> f64 {
        if self.bytes_per_node.is_empty() {
            0.0
        } else {
            self.total_bytes() as f64 / self.bytes_per_node.len() as f64
        }
    }
}

/// Split `len` coordinates into `n` contiguous chunks (ring ownership).
/// Chunk sizes differ by at most 1.
pub fn chunk_ranges(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::with_capacity(n);
    chunk_ranges_into(len, n, &mut out);
    out
}

/// [`chunk_ranges`] into a caller-owned buffer (arena reuse; the
/// steady-state engines recompute the same partition every step).
pub fn chunk_ranges_into(len: usize, n: usize, out: &mut Vec<std::ops::Range<usize>>) {
    assert!(n > 0);
    let base = len / n;
    let extra = len % n;
    out.clear();
    let mut start = 0;
    for i in 0..n {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
}

/// Like [`chunk_ranges`] but with boundaries aligned to 64-coordinate
/// words (except the last), so chunk supports are direct `u64`-word
/// slices of a `BitMask` — the support-only fast path depends on this.
pub fn chunk_ranges_aligned(len: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::with_capacity(n);
    chunk_ranges_aligned_into(len, n, &mut out);
    out
}

/// [`chunk_ranges_aligned`] into a caller-owned buffer (arena reuse).
pub fn chunk_ranges_aligned_into(len: usize, n: usize, out: &mut Vec<std::ops::Range<usize>>) {
    assert!(n > 0);
    let words = len.div_ceil(64);
    chunk_ranges_into(words, n, out);
    for wr in out.iter_mut() {
        *wr = (wr.start * 64).min(len)..(wr.end * 64).min(len);
    }
}

/// Snapshot byte counters before/after an operation on the net.
pub(crate) fn per_node_delta(net: &RingNet, before: &[u64]) -> Vec<u64> {
    (0..net.n_nodes())
        .map(|i| net.node_tx_bytes(i) - before[i])
        .collect()
}

pub(crate) fn snapshot(net: &RingNet) -> Vec<u64> {
    (0..net.n_nodes()).map(|i| net.node_tx_bytes(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_tile_exactly() {
        let r = chunk_ranges(10, 3);
        assert_eq!(r, vec![0..4, 4..7, 7..10]);
        let r = chunk_ranges(9, 3);
        assert_eq!(r, vec![0..3, 3..6, 6..9]);
    }

    #[test]
    fn aligned_chunks_tile_and_align() {
        let r = chunk_ranges_aligned(1000, 3);
        assert_eq!(r.iter().map(|r| r.len()).sum::<usize>(), 1000);
        for w in &r[..r.len() - 1] {
            assert_eq!(w.end % 64, 0, "{w:?} not word-aligned");
        }
        assert_eq!(r.last().unwrap().end, 1000);
    }

    #[test]
    fn chunks_handle_len_smaller_than_n() {
        let r = chunk_ranges(2, 4);
        assert_eq!(r.iter().map(|r| r.len()).sum::<usize>(), 2);
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn aligned_chunks_len_zero_is_all_empty() {
        let r = chunk_ranges_aligned(0, 5);
        assert_eq!(r.len(), 5);
        assert!(r.iter().all(|c| c.is_empty()));
    }

    #[test]
    fn aligned_chunks_len_smaller_than_word_times_n() {
        // Fewer 64-bit words than chunks: trailing chunks collapse to
        // empty, leading ones stay word-aligned, and the tiling is exact.
        let r = chunk_ranges_aligned(100, 4); // 2 words, 4 chunks
        assert_eq!(r.iter().map(|c| c.len()).sum::<usize>(), 100);
        assert_eq!(r[0], 0..64);
        assert_eq!(r[1], 64..100);
        assert!(r[2].is_empty() && r[3].is_empty());
    }

    #[test]
    fn aligned_chunks_exact_single_word_edge() {
        // len exactly one word: the word goes to chunk 0, the rest empty.
        let r = chunk_ranges_aligned(64, 3);
        assert_eq!(r[0], 0..64);
        assert!(r[1].is_empty() && r[2].is_empty());
    }

    #[test]
    fn aligned_chunks_tile_property() {
        use crate::util::prop::forall;
        forall("aligned chunks tile [0, len) word-aligned", 100, |g| {
            let len = g.usize_in(0, 5000);
            let n = g.usize_in(1, 12);
            let r = chunk_ranges_aligned(len, n);
            assert_eq!(r.len(), n);
            let mut cursor = 0;
            for c in &r {
                assert_eq!(c.start, cursor, "chunks must tile contiguously");
                assert!(c.start % 64 == 0 || c.start == len);
                cursor = c.end;
            }
            assert_eq!(cursor, len);
        });
    }
}
